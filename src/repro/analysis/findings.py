"""Finding / report model + the committed-baseline mechanism.

A finding is identified across commits by its *fingerprint*: a digest of
(rule, path, normalized source line). Line numbers shift every edit, so the
baseline matches on content, not position — a grandfathered finding stays
grandfathered when unrelated lines move, and resurfaces the moment the
offending line itself changes.
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass, field


@dataclass(frozen=True)
class Finding:
    """One rule violation: where, what, and how to fix it."""

    rule: str  # rule id ("taxonomy", "env", ...)
    code: str  # sub-check id ("taxonomy.bare-raise", ...)
    path: str  # posix path relative to the scan root
    line: int  # 1-based
    message: str
    hint: str = ""  # fix hint shown in the report
    snippet: str = ""  # stripped source line (fingerprint input)

    @property
    def fingerprint(self) -> str:
        blob = f"{self.rule}|{self.path}|{' '.join(self.snippet.split())}"
        return hashlib.sha1(blob.encode()).hexdigest()[:16]

    def to_dict(self) -> dict:
        return {
            "rule": self.rule,
            "code": self.code,
            "path": self.path,
            "line": self.line,
            "message": self.message,
            "hint": self.hint,
            "snippet": self.snippet,
            "fingerprint": self.fingerprint,
        }

    def render(self) -> str:
        out = f"{self.path}:{self.line}: [{self.code}] {self.message}"
        if self.hint:
            out += f"\n    fix: {self.hint}"
        return out


@dataclass
class Report:
    """The outcome of one analysis run, split by disposition.

    ``new`` findings fail the gate; ``suppressed`` carry an inline
    ``# repro: allow[RULE]``; ``baselined`` match the committed baseline.
    """

    root: str
    rules: list[str] = field(default_factory=list)
    new: list[Finding] = field(default_factory=list)
    suppressed: list[Finding] = field(default_factory=list)
    baselined: list[Finding] = field(default_factory=list)
    stats: dict = field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return not self.new

    def to_dict(self) -> dict:
        return {
            "root": self.root,
            "rules": self.rules,
            "ok": self.ok,
            "counts": {
                "new": len(self.new),
                "suppressed": len(self.suppressed),
                "baselined": len(self.baselined),
            },
            "new": [f.to_dict() for f in self.new],
            "suppressed": [f.to_dict() for f in self.suppressed],
            "baselined": [f.to_dict() for f in self.baselined],
            "stats": self.stats,
        }


BASELINE_VERSION = 1


def load_baseline(path) -> set[str]:
    """Fingerprint set from a committed ``analysis/baseline.json``.

    A missing file is an *empty* baseline (the strict default); a malformed
    one is a loud error — silently ignoring a corrupt baseline would let
    every grandfathered finding back through the gate as "new", or worse,
    mask a bad merge.
    """
    try:
        with open(path) as f:
            payload = json.load(f)
    except FileNotFoundError:
        return set()
    if not isinstance(payload, dict) or payload.get("version") != BASELINE_VERSION:
        raise ValueError(
            f"{path}: not a repro.analysis baseline (want "
            f"{{'version': {BASELINE_VERSION}, 'findings': [...]}})")
    out = set()
    for entry in payload.get("findings", []):
        fp = entry.get("fingerprint")
        if not fp:
            raise ValueError(f"{path}: baseline entry without fingerprint: "
                             f"{entry!r}")
        out.add(fp)
    return out


def save_baseline(path, findings: list[Finding]) -> None:
    """Write every given finding as grandfathered (``--update-baseline``)."""
    payload = {
        "version": BASELINE_VERSION,
        "findings": [
            {
                "rule": f.rule,
                "path": f.path,
                "snippet": f.snippet,
                "fingerprint": f.fingerprint,
            }
            for f in sorted(findings, key=lambda f: (f.path, f.line, f.code))
        ],
    }
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=2, sort_keys=False)
        fh.write("\n")
