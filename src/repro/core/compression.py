"""Matrix compression for the symbolic phase (paper §3.2).

The graph of B is binary, so 32 columns pack into one uint32: a row's columns
become (CSI = col >> 5, CS = 1 << (col & 31)) pairs, merged per-CSI with
BITWISE-OR. Row unions in the symbolic phase then operate on the compressed
rows, cutting f_m by the compression factor CF. The paper's rule: compress
only when CF <= 0.85 (>= 15% flop reduction); we keep the constant verbatim.

This transfers to TPU unchanged — uint32 lanes OR on the VPU, and
``lax.population_count`` recovers set sizes.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.utils import segmented_scan, segment_ends
from repro.sparse.formats import CSR, csr_row_ids

COMPRESSION_CF_CUTOFF = 0.85  # paper §3.2: apply compression iff CF <= 0.85
BITS = 32


class CompressedMatrix(NamedTuple):
    """B_c: CSR over (row, CSI) with OR-merged CS bitmask payloads."""

    indptr: jax.Array  # (m+1,) int32
    csi: jax.Array  # (nnz_cap,) int32 — column-set index (col >> 5)
    cs: jax.Array  # (nnz_cap,) uint32 — column-set bitmask
    shape: tuple  # (m, k) of the *original* matrix

    @property
    def k_compressed(self) -> int:
        return -(-self.shape[1] // BITS)

    def row_nnz(self) -> jax.Array:
        return self.indptr[1:] - self.indptr[:-1]


@partial(jax.jit, static_argnames=("nnz_cap",))
def compress_matrix(b: CSR, nnz_cap: int | None = None) -> CompressedMatrix:
    """Build B_c. Output capacity defaults to B's (compression never grows).

    Entries within a CSR row are deduped by CSI via sort + segmented OR-scan;
    because column ids within a row are unique, bits within a (row, CSI) group
    are distinct.
    """
    cap = b.nnz_cap if nnz_cap is None else nnz_cap
    rows = csr_row_ids(b.indptr, b.nnz_cap)
    valid = b.valid_mask()
    csi = (b.indices >> 5).astype(jnp.int32)
    cs = (jnp.uint32(1) << (b.indices & 31).astype(jnp.uint32)).astype(jnp.uint32)
    # Sort by (valid desc implicitly handled by pushing invalid to the end
    # via a large key), then (row, csi).
    big = jnp.int32(b.shape[0] + 1)
    sort_rows = jnp.where(valid, rows, big)
    order = jnp.lexsort((csi, sort_rows))
    rows_s = sort_rows[order]  # invalid slots carry row=big -> own trailing group
    csi_s = csi[order]
    cs_s = cs[order]
    valid_s = valid[order]

    heads = jnp.concatenate(
        [
            jnp.ones((1,), jnp.bool_),
            (rows_s[1:] != rows_s[:-1]) | (csi_s[1:] != csi_s[:-1]),
        ]
    )
    or_scan = segmented_scan(cs_s, heads, jnp.bitwise_or)
    ends = segment_ends(heads) & valid_s

    # Compact the group representatives to the front (stable): order by
    # (not end) so ends come first in (row, csi) order.
    comp_order = jnp.lexsort((jnp.arange(cap, dtype=jnp.int32), ~ends))
    out_csi = jnp.where(ends, csi_s, 0)[comp_order]
    out_cs = jnp.where(ends, or_scan, jnp.uint32(0))[comp_order]
    out_rows = jnp.where(ends, rows_s, big)[comp_order]

    n_groups = jnp.sum(ends.astype(jnp.int32))
    m = b.shape[0]
    counts = jnp.zeros((m,), jnp.int32).at[jnp.minimum(out_rows, m - 1)].add(
        (jnp.arange(cap) < n_groups).astype(jnp.int32), mode="drop"
    )
    indptr = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(counts).astype(jnp.int32)]
    )
    return CompressedMatrix(indptr=indptr, csi=out_csi, cs=out_cs, shape=b.shape)


@jax.jit
def flops_stats(a: CSR, b_row_nnz: jax.Array):
    """(f_m total, per-row flops, MAXRF) for C = A*B given B's row sizes.

    f_m is the paper's multiplication count; MAXRF its max-row upper bound
    used to size L2 accumulator chunks (memory pool CHUNKSIZE).
    """
    rows = csr_row_ids(a.indptr, a.nnz_cap)
    valid = a.valid_mask()
    contrib = jnp.where(valid, b_row_nnz[jnp.minimum(a.indices, b_row_nnz.shape[0] - 1)], 0)
    row_flops = jnp.zeros((a.m,), jnp.int64 if contrib.dtype == jnp.int64 else jnp.int32)
    row_flops = row_flops.at[rows].add(contrib, mode="drop")
    return jnp.sum(row_flops), row_flops, jnp.max(row_flops)


def compression_decision(a: CSR, b: CSR, bc: CompressedMatrix):
    """Host-facing: (CF, CMRF, use_compression). Mirrors the 15% rule."""
    fm, _, maxrf = flops_stats(a, b.row_nnz())
    fm_c, _, maxrf_c = flops_stats(a, bc.row_nnz())
    fm = max(int(fm), 1)
    maxrf = max(int(maxrf), 1)
    cf = float(int(fm_c)) / fm
    cmrf = float(int(maxrf_c)) / maxrf
    return cf, cmrf, cf <= COMPRESSION_CF_CUTOFF


def bitmask_rows(b: CSR) -> jax.Array:
    """(m, ceil(k/32)) uint32 dense bitmask of B's structure (KKDENSE symbolic
    feed). Distinct column bits per row ⇒ scatter-add == scatter-or."""
    k32 = -(-b.k // BITS)
    rows = csr_row_ids(b.indptr, b.nnz_cap)
    valid = b.valid_mask()
    csi = jnp.where(valid, (b.indices >> 5).astype(jnp.int32), 0)
    cs = jnp.where(
        valid, (jnp.uint32(1) << (b.indices & 31).astype(jnp.uint32)), jnp.uint32(0)
    )
    rows = jnp.where(valid, rows, 0)
    out = jnp.zeros((b.m, k32), jnp.uint32)
    return out.at[rows, csi].add(cs)
