"""Rule ``telemetry-key`` — counter keys follow the documented grammars.

Every subsystem keeps a module-level ``Counter`` and the key shapes are a
documented contract (``core/telemetry.py`` ``KEY_FAMILIES``): dashboards,
the serving tier's retry-rate math, and the tests all parse these strings.
A typo'd key (``nan_guard:re-run``) silently creates a new series nothing
reads.

Sub-checks:

  * ``telemetry-key.grammar`` — a literal or f-string key written into a
    ``*_COUNTS`` counter does not match any template of its family.
    F-strings check their literal fragments (dynamic pieces map onto
    ``{}`` wildcards); a dynamic piece that is a *parameter* of the
    enclosing function is expanded from literal same-module call-site
    arguments, so ``BREAKER_COUNTS[f"{self.name}:{event}"]`` is checked
    against the actual events passed to ``_count(...)``.
  * ``telemetry-key.unknown-family`` — a write to a ``*_COUNTS`` name with
    no ``KEY_FAMILIES`` entry.
  * ``telemetry-key.unregistered`` — a module-level ``*_COUNTS = Counter()``
    definition whose name is absent from ``telemetry.ALL_COUNTERS`` (it
    would dodge ``snapshot()``/``reset_all()`` and leak state across
    tests).
  * ``telemetry-key.reset-drift`` — ``ALL_COUNTERS`` and ``_RESETS`` have
    different sizes (a counter registered for snapshots but not cleared by
    ``reset_all``, or vice versa).
"""
from __future__ import annotations

import ast
import itertools
import re

from repro.analysis.asthelpers import calls_in, dotted, string_value
from repro.analysis.context import TELEMETRY_MODULE, ModuleInfo, Project
from repro.analysis.findings import Finding
from repro.analysis.registry import rule

RULE = "telemetry-key"

_MAX_EXPANSION = 64
_SENTINEL = "\x00"


def _family_of(counter_name: str) -> str:
    return counter_name.removesuffix("_COUNTS").lower()


def _template_matches(template: str, key: str) -> bool:
    pattern = "^" + ".+".join(
        re.escape(part) for part in template.split("{}")) + "$"
    return re.match(pattern, key, flags=re.DOTALL) is not None


def _param_index(fn: ast.FunctionDef | ast.AsyncFunctionDef, name: str) -> int | None:
    """Positional index of ``name`` at *call sites* (self/cls stripped)."""
    args = [a.arg for a in fn.args.posonlyargs + fn.args.args]
    if args and args[0] in {"self", "cls"}:
        args = args[1:]
    try:
        return args.index(name)
    except ValueError:
        return None


def _callsite_values(mod: ModuleInfo, fname: str, index: int) -> list[str] | None:
    """Literal strings passed at position ``index`` to same-module calls of
    ``fname``; None when any call site is non-literal (can't expand)."""
    vals: list[str] = []
    for call in calls_in(mod.tree):
        last = dotted(call.func).rsplit(".", 1)[-1]
        if last != fname:
            continue
        if index < len(call.args):
            s = string_value(call.args[index])
            if s is None:
                return None
            vals.append(s)
        else:
            return None
    return vals or None


def _key_candidates(node: ast.expr,
                    fn: ast.FunctionDef | ast.AsyncFunctionDef | None,
                    mod: ModuleInfo) -> list[str] | None:
    """Concrete key strings a write could produce (dynamic → sentinel).

    None means the key is fully dynamic with no literal fragment —
    statically unchecked (counted in stats, not flagged).
    """
    s = string_value(node)
    if s is not None:
        return [s]
    if isinstance(node, ast.JoinedStr):
        pieces: list[list[str]] = []
        for part in node.values:
            if isinstance(part, ast.Constant) and isinstance(part.value, str):
                pieces.append([part.value])
            elif isinstance(part, ast.FormattedValue) and fn is not None \
                    and isinstance(part.value, ast.Name):
                idx = _param_index(fn, part.value.id)
                vals = (_callsite_values(mod, fn.name, idx)
                        if idx is not None else None)
                pieces.append(vals if vals else [_SENTINEL])
            else:
                pieces.append([_SENTINEL])
        if all(v == [_SENTINEL] for v in pieces):
            return None
        combos = list(itertools.islice(
            itertools.product(*pieces), _MAX_EXPANSION))
        return ["".join(c) for c in combos]
    return None


def _counter_writes(mod: ModuleInfo):
    """Yield (counter_name, key_expr, enclosing_fn, lineno) for every
    subscript write into a ``*_COUNTS`` name."""

    class V(ast.NodeVisitor):
        def __init__(self):
            self.stack: list[ast.FunctionDef | ast.AsyncFunctionDef] = []
            self.hits = []

        def visit_FunctionDef(self, node):
            self.stack.append(node)
            self.generic_visit(node)
            self.stack.pop()

        visit_AsyncFunctionDef = visit_FunctionDef

        def _check_target(self, target):
            if isinstance(target, ast.Subscript):
                base = dotted(target.value).rsplit(".", 1)[-1]
                if base.endswith("_COUNTS"):
                    fn = self.stack[-1] if self.stack else None
                    self.hits.append(
                        (base, target.slice, fn, target.lineno))

        def visit_AugAssign(self, node):
            self._check_target(node.target)
            self.generic_visit(node)

        def visit_Assign(self, node):
            for t in node.targets:
                self._check_target(t)
            self.generic_visit(node)

    v = V()
    v.visit(mod.tree)
    return v.hits


@rule(RULE, "counter keys match KEY_FAMILIES grammars; every counter registered")
def check(project: Project):
    families = project.key_families()
    registered = project.registered_counters()
    telemetry = project.module(TELEMETRY_MODULE)

    if telemetry is not None and families is None:
        yield Finding(
            rule=RULE, code=f"{RULE}.no-registry",
            path=TELEMETRY_MODULE, line=1,
            message="core/telemetry.py has no KEY_FAMILIES literal dict",
            hint="define KEY_FAMILIES: dict[str, tuple[str, ...]] mapping "
                 "family -> grammar templates ('{}' is a wildcard segment)",
            snippet=telemetry.snippet(1))
        families = {}
    elif families is None:
        return  # no telemetry module under this root: nothing to check

    unchecked = 0
    for mod in project.modules:
        for counter, key_expr, fn, lineno in _counter_writes(mod):
            family = _family_of(counter)
            if family not in families:
                yield Finding(
                    rule=RULE, code=f"{RULE}.unknown-family",
                    path=mod.rel, line=lineno,
                    message=(f"write to {counter} but family '{family}' has "
                             f"no KEY_FAMILIES grammar"),
                    hint="add the family's templates to "
                         "core/telemetry.py KEY_FAMILIES",
                    snippet=mod.snippet(lineno))
                continue
            candidates = _key_candidates(key_expr, fn, mod)
            if candidates is None:
                unchecked += 1
                continue
            templates = families[family]
            for key in candidates:
                if not any(_template_matches(t, key) for t in templates):
                    shown = key.replace(_SENTINEL, "{…}")
                    yield Finding(
                        rule=RULE, code=f"{RULE}.grammar",
                        path=mod.rel, line=lineno,
                        message=(f"key '{shown}' does not match any "
                                 f"'{family}' grammar template "
                                 f"{list(templates)}"),
                        hint="use a documented key shape or extend "
                             "KEY_FAMILIES in the same commit",
                        snippet=mod.snippet(lineno))
                    break

        # module-level Counter definitions must be registered
        if registered is not None:
            for node in mod.tree.body:
                targets = []
                if isinstance(node, ast.Assign):
                    targets = node.targets
                    value = node.value
                elif isinstance(node, ast.AnnAssign) and node.value is not None:
                    targets = [node.target]
                    value = node.value
                else:
                    continue
                if not (isinstance(value, ast.Call)
                        and dotted(value.func).rsplit(".", 1)[-1] == "Counter"):
                    continue
                for t in targets:
                    if isinstance(t, ast.Name) and t.id.endswith("_COUNTS") \
                            and t.id not in registered:
                        yield Finding(
                            rule=RULE, code=f"{RULE}.unregistered",
                            path=mod.rel, line=node.lineno,
                            message=(f"{t.id} is a module-level Counter not "
                                     f"registered in telemetry.ALL_COUNTERS"),
                            hint="add it to ALL_COUNTERS and wire a reset "
                                 "into _RESETS so reset_all() clears it",
                            snippet=mod.snippet(node.lineno))

    if telemetry is not None:
        resets = project.reset_registered()
        all_counters = registered
        if resets is not None and all_counters is not None \
                and len(resets) != len(all_counters):
            yield Finding(
                rule=RULE, code=f"{RULE}.reset-drift",
                path=TELEMETRY_MODULE, line=1,
                message=(f"ALL_COUNTERS has {len(all_counters)} counters but "
                         f"_RESETS wires {len(resets)} reset functions"),
                hint="every registered counter needs a reset in _RESETS",
                snippet="ALL_COUNTERS/_RESETS size mismatch")

    # surfaced in stats by the runner via function attribute
    check.unchecked = unchecked  # type: ignore[attr-defined]
