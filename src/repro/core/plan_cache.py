"""Structure-keyed LRU cache of SpGEMM numeric plans (the paper's Reuse case).

Nagasaka et al. and the source paper both make the two-phase split pay off by
*reusing* the symbolic structures across numeric calls. This module automates
that: ``spgemm()`` hashes the structural identity of ``(A, B)`` — row
pointers, live column indices, shapes, and the bucketed static capacities —
and keeps the resulting ``SpgemmPlan`` in a bounded LRU. A repeated structure
(same graph, new values) takes the ``numeric_reuse`` fast path with zero
recompiles and zero caller bookkeeping.

The key deliberately covers everything that determines the compiled
executable and the plan's array contents:

  * A's and B's ``indptr`` and the live prefix of ``indices`` (padding slots
    beyond ``nnz`` are excluded — they don't affect the product),
  * both shapes and both (bucketed) nnz capacities,
  * the bucketed ``fm_cap`` and the pad policy that produced it.

Hashing pulls the structure arrays to the host once per call; the driver
already synchronizes on nnz(C), so this adds no extra device round-trips on
the miss path and replaces them all on the hit path.
"""
from __future__ import annotations

import hashlib
import threading
from collections import Counter, OrderedDict
from typing import Any

import jax
import numpy as np

# Hash telemetry: ``structure_key`` bumps this on every call. The executor's
# contract ("one structure hash, ever — zero re-hashes on replay") is asserted
# against these counts, mirroring spgemm.TRACE_COUNTS for recompiles.
HASH_COUNTS: Counter = Counter()


def reset_hash_counts() -> None:
    HASH_COUNTS.clear()


# Eviction telemetry, keyed by cache *name*: every LRU/bytes-bound eviction
# bumps EVICT_COUNTS[cache.name], so the serving tier's plan-cache warmer and
# bench_serve can detect thrash (a warm set that exceeds the cache bound shows
# up as a nonzero eviction rate, not as mysteriously cold replays). clear()
# does NOT count — it is an explicit reset, not capacity pressure.
EVICT_COUNTS: Counter = Counter()


def reset_evict_counts() -> None:
    EVICT_COUNTS.clear()


def plan_nbytes(plan) -> int:
    """Device bytes pinned by a cached plan (sum over its array leaves).

    Works for any pytree of arrays — ``SpgemmPlan`` and the sharded
    ``repro.dist`` plans alike — so every cache flavor shares one accounting
    rule.
    """
    return sum(
        leaf.nbytes for leaf in jax.tree_util.tree_leaves(plan)
        if hasattr(leaf, "nbytes")
    )


class PlanCache:
    """Bounded LRU mapping structure keys -> SpgemmPlan.

    Thread-safe for the host-driver use case (benchmarks run serving loops
    from multiple threads). Tracks hit/miss/eviction counters so benchmarks
    can report cache efficiency alongside recompile counts.

    Two bounds compose: ``capacity`` (entry count) and ``max_bytes`` (device
    memory pinned by cached plans, measured with ``plan_nbytes``). The bytes
    bound matters because a v2 plan holds three fm_cap-length int32 arrays
    (seg_ids + precomposed slot maps), so one entry for a multiply with
    f_m ~ 1e7 pins ~120 MB of device memory until evicted — and executors
    additionally pin plans *outside* the cache, so the cache must not hoard
    what the executors already hold. The most recent entry is always kept,
    even when it alone exceeds ``max_bytes`` (a cache that refuses the plan
    it was just asked to store would silently disable reuse).
    """

    def __init__(self, capacity: int = 16, max_bytes: int | None = None,
                 name: str = "plan"):
        if capacity < 1 or (max_bytes is not None and max_bytes < 1):
            from repro.runtime.validate import SpgemmConfigError  # cycle-free
            if capacity < 1:
                raise SpgemmConfigError(
                    f"capacity must be >= 1, got {capacity}")
            raise SpgemmConfigError(
                f"max_bytes must be >= 1, got {max_bytes}")
        self.capacity = capacity
        self.max_bytes = max_bytes
        self.name = name  # EVICT_COUNTS key; distinguishes cache instances
        self._entries: OrderedDict[str, Any] = OrderedDict()
        self._nbytes: dict[str, int] = {}
        # Per-entry sidecar metadata (e.g. the autotuner's measured replay
        # winner, keyed by dtype-qualified meta keys). Lives and dies with
        # the entry: eviction and clear() drop it.
        self._meta: dict[str, dict] = {}
        self._lock = threading.Lock()
        self.total_bytes = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: str) -> bool:
        return key in self._entries

    def get(self, key: str):
        """Return the cached plan (refreshing recency) or None."""
        with self._lock:
            plan = self._entries.get(key)
            if plan is None:
                self.misses += 1
                return None
            self._entries.move_to_end(key)
            self.hits += 1
            return plan

    def put(self, key: str, plan) -> None:
        with self._lock:
            if key in self._entries:
                self._entries.move_to_end(key)
                self.total_bytes -= self._nbytes.pop(key)
            nbytes = plan_nbytes(plan)
            self._entries[key] = plan
            self._nbytes[key] = nbytes
            self.total_bytes += nbytes
            while len(self._entries) > self.capacity or (
                self.max_bytes is not None
                and self.total_bytes > self.max_bytes
                and len(self._entries) > 1
            ):
                old_key, _ = self._entries.popitem(last=False)
                self.total_bytes -= self._nbytes.pop(old_key)
                self._meta.pop(old_key, None)
                self.evictions += 1
                EVICT_COUNTS[self.name] += 1

    def set_meta(self, key: str, meta_key, value) -> bool:
        """Attach sidecar metadata to a *cached* entry.

        Returns False (and stores nothing) when ``key`` is not resident —
        metadata must never outlive, or predate, the plan it annotates.
        ``meta_key`` should qualify everything the structure key does not
        cover (the autotuner uses ``("tuned_backend", a_dtype, b_dtype)``
        because the structure key deliberately excludes value dtypes).
        """
        with self._lock:
            if key not in self._entries:
                return False
            self._meta.setdefault(key, {})[meta_key] = value
            return True

    def get_meta(self, key: str, meta_key, default=None):
        """Sidecar metadata for a cached entry, or ``default``."""
        with self._lock:
            return self._meta.get(key, {}).get(meta_key, default)

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self._nbytes.clear()
            self._meta.clear()
            self.total_bytes = 0
            self.hits = self.misses = self.evictions = 0

    def stats(self) -> dict:
        total = self.hits + self.misses
        return {
            "name": self.name,
            "size": len(self._entries),
            "capacity": self.capacity,
            "bytes": self.total_bytes,
            "max_bytes": self.max_bytes,
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "hit_rate": (self.hits / total) if total else 0.0,
        }


def structure_key(a, b, fm_cap: int, pad_policy: str) -> str:
    """Hash the structural identity of a multiply (values excluded).

    Two calls share a key iff they produce byte-identical plans *and* hit the
    same compiled executables: live structure, shapes, capacities, and the
    bucketing that sized them all feed the digest.
    """
    HASH_COUNTS["structure_key"] += 1
    h = hashlib.blake2b(digest_size=16)
    for mat in (a, b):
        indptr = np.asarray(mat.indptr)
        nnz = int(indptr[-1])
        h.update(indptr.tobytes())
        h.update(np.asarray(mat.indices)[:nnz].tobytes())
        h.update(repr((tuple(mat.shape), mat.nnz_cap)).encode())
    h.update(repr((int(fm_cap), pad_policy)).encode())
    return h.hexdigest()


_DEFAULT_CACHE = PlanCache(name="default")


def default_plan_cache() -> PlanCache:
    """The module-level cache used by ``spgemm()`` when none is passed."""
    return _DEFAULT_CACHE
