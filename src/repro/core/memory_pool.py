"""Thread-scalable memory pool (paper §3.1.2), adapted to XLA's static world.

The paper's pool services dynamic L2-accumulator allocations from thousands
of threads: NUMCHUNKS chunks of CHUNKSIZE = MAXRF entries, with ONE2ONE
(CPU/KNL: chunk i belongs to thread i, NUMA-local reuse) and MANY2MANY
(GPU: scan from the thread index for a free chunk, spin on exhaustion).

XLA cannot allocate inside a kernel, so the pool becomes a *statically
pre-allocated* chunk table whose sizing logic is the paper's: CHUNKSIZE from
the (compressed) MAXRF upper bound, NUMCHUNKS from the architecture's
concurrency. Acquisition maps grid steps to chunks:

* ONE2ONE   — chunk id == grid step id (our Pallas grids schedule one
  row-block per step, so ownership is exclusive by construction);
* MANY2MANY — chunk id == grid step id mod NUMCHUNKS, valid because Mosaic
  executes TPU grid steps sequentially per core — a chunk is always released
  (row finished) before the next step that maps to it begins. This is the
  paper's "release as soon as the thread releases the chunk" invariant,
  enforced by scheduling instead of locks.

``acquire_release_sim`` keeps a faithful lock-bitmap simulation of the
MANY2MANY scan for the data-structure tests.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class PoolConfig:
    num_chunks: int
    chunk_size: int  # entries per chunk == MAXRF bound
    mode: str  # "one2one" | "many2many"

    @property
    def total_entries(self) -> int:
        return self.num_chunks * self.chunk_size


def size_pool(maxrf: int, concurrency: int, mode: str = "one2one",
              bytes_budget: int | None = None, entry_bytes: int = 8) -> PoolConfig:
    """Size the pool exactly as §3.1.2: CHUNKSIZE = MAXRF (guarantees any row
    fits), NUMCHUNKS = concurrency; shrink NUMCHUNKS if the allocation would
    blow the budget (the paper's GPU fallback)."""
    chunk = max(int(maxrf), 1)
    chunks = max(int(concurrency), 1)
    if bytes_budget is not None:
        max_chunks = max(bytes_budget // max(chunk * entry_bytes, 1), 1)
        chunks = min(chunks, int(max_chunks))
    return PoolConfig(num_chunks=chunks, chunk_size=chunk, mode=mode)


def chunk_for_step(cfg: PoolConfig, step) :
    """Chunk index owned by a grid step (see module docstring)."""
    if cfg.mode == "one2one":
        return step
    return step % cfg.num_chunks


@partial(jax.jit, static_argnames=("num_chunks",))
def acquire_release_sim(thread_ids: jax.Array, release_after: jax.Array,
                        num_chunks: int):
    """Faithful MANY2MANY semantics check: process a timeline of acquire
    events (thread_ids) with per-event hold durations; each acquire scans
    from ``tid % num_chunks`` for the first free chunk. Returns the chunk
    each event received. Sequential — test-scale only."""
    n = thread_ids.shape[0]

    def step(i, carry):
        locks, got = carry  # locks[j] = timestep when chunk j frees
        tid = thread_ids[i]

        # release everything whose time has passed
        locks = jnp.where(locks <= i, jnp.int32(-1), locks)

        def scan_cond(s):
            j, found = s
            return (found == -1) & (j < num_chunks * 2)

        def scan_body(s):
            j, _ = s
            idx = (tid + j) % num_chunks
            free = locks[idx] == -1
            return j + 1, jnp.where(free, idx, -1)

        _, chunk = jax.lax.while_loop(
            scan_cond, scan_body, (jnp.int32(0), jnp.int32(-1))
        )
        chunk = jnp.maximum(chunk, 0)  # spin-exhaustion clamps (test sizes small)
        locks = locks.at[chunk].set(i + release_after[i])
        got = got.at[i].set(chunk)
        return locks, got

    locks = jnp.full((num_chunks,), -1, jnp.int32)
    got = jnp.zeros((n,), jnp.int32)
    _, got = jax.lax.fori_loop(0, n, step, (locks, got))
    return got
