from repro.train.optim import AdamWConfig, OptState, adamw_init, adamw_update, zero1_shardings
from repro.train.step import cross_entropy_loss, make_train_step, train_step

__all__ = [
    "AdamWConfig",
    "OptState",
    "adamw_init",
    "adamw_update",
    "zero1_shardings",
    "cross_entropy_loss",
    "train_step",
    "make_train_step",
]
