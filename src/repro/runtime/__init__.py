"""repro.runtime — host-side robustness layer for the execution stack.

validate: typed error taxonomy + opt-in CSR/plan validation modes.
faults:   deterministic fault injection (data faults + kernel failpoints).
retry:    bounded jittered backoff with typed give-up.
watchdog: liveness heartbeat + per-step/per-replay straggler deadlines.
"""
from repro.runtime.faults import (FAULTS, FaultSpec, InjectedFault, failpoint,
                                  inject_csr, reset_failpoints)
from repro.runtime.retry import RetryExhaustedError, backoff_schedule, retry_call
from repro.runtime.validate import (VALIDATE_MODES, AdmissionRejected,
                                    CapacityOverflowError, DeadlineExceeded,
                                    KernelFallbackError, PlanGuard,
                                    PlanMismatchError, SpgemmConfigError,
                                    SpgemmError, SpgemmInputError,
                                    TrainingDivergedError, check_csr,
                                    resolve_mode)
from repro.runtime.watchdog import Heartbeat, StepWatchdog, StragglerDetected

__all__ = [
    "StepWatchdog",
    "Heartbeat",
    "StragglerDetected",
    "SpgemmError",
    "SpgemmInputError",
    "SpgemmConfigError",
    "TrainingDivergedError",
    "PlanMismatchError",
    "CapacityOverflowError",
    "KernelFallbackError",
    "AdmissionRejected",
    "DeadlineExceeded",
    "RetryExhaustedError",
    "InjectedFault",
    "FaultSpec",
    "FAULTS",
    "PlanGuard",
    "VALIDATE_MODES",
    "check_csr",
    "resolve_mode",
    "failpoint",
    "inject_csr",
    "reset_failpoints",
    "retry_call",
    "backoff_schedule",
]
