"""Partitioning-layer round-trips (core/distributed.py under repro.dist).

Host-level coverage for the 1-D row decomposition against the numpy
oracles: indivisible row counts, shards beyond the row count (empty
shards), empty rows, value-map consistency, the all-gather B placement,
and the round_capacity bucketing contract (satellites of the repro.dist
issue). Runs on a single device — the mesh-wide paths live in
tests/test_dist_executor.py.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import round_capacity
from repro.core.distributed import (
    allgather_value_perm,
    concat_csr_shards,
    distributed_spgemm,
    merge_shards,
    partition_rows,
    partition_value_map,
    row_block_bounds,
    shard_cap,
)
from repro.sparse import CSR, random_csr
from repro.sparse.oracle import dense_spgemm_oracle


def _dense(c: CSR) -> np.ndarray:
    return np.asarray(c.to_dense())


def _with_empty_rows(m: int, k: int, seed: int) -> CSR:
    """Matrix whose even rows are empty (plus a fully-empty tail block)."""
    rng = np.random.default_rng(seed)
    dense = rng.standard_normal((m, k)).astype(np.float32)
    dense[::2] = 0.0
    dense[m - max(m // 4, 1):] = 0.0
    return CSR.from_dense(dense)


@pytest.mark.parametrize("m,num_shards", [
    (96, 8),   # divisible
    (97, 8),   # m % S != 0: last shard padded
    (91, 8),   # last shard several padded rows
    (5, 8),    # S > m: shards 5..7 completely empty
    (1, 4),    # single row
])
def test_partition_merge_roundtrip(m, num_shards):
    a = random_csr(m, 40, 3.0, seed=m + num_shards)
    a_sh = partition_rows(a, num_shards)
    back = merge_shards(a_sh, m)
    np.testing.assert_array_equal(_dense(back), _dense(a))
    np.testing.assert_array_equal(np.asarray(back.indptr), np.asarray(a.indptr))


def test_partition_merge_roundtrip_empty_rows():
    a = _with_empty_rows(37, 23, seed=3)
    a_sh = partition_rows(a, 6)
    back = merge_shards(a_sh, a.m)
    np.testing.assert_array_equal(_dense(back), _dense(a))


def test_partition_caps_are_bucketed():
    """Satellite: shard caps come from round_capacity, not ad-hoc -(-x//8)*8,
    so shards land in the same capacity buckets as the single-device path."""
    a = random_csr(100, 50, 3.0, seed=11)
    for policy in ("pow2", "exact8"):
        cap = shard_cap(a, 8, policy)
        bounds = row_block_bounds(a, 8)
        assert cap == round_capacity(int(np.max(np.diff(bounds))), policy)
        assert partition_rows(a, 8, policy).indices.shape[1] == cap


def test_concat_csr_shards_roundtrip():
    """Jittable concat of row shards == the original matrix (padded rows of
    the last shard become empty trailing rows)."""
    a = random_csr(91, 33, 2.5, seed=5)
    S = 8
    a_sh = partition_rows(a, S)
    glob = concat_csr_shards(a_sh.indptr, a_sh.indices, a_sh.values, a.k)
    m_pad = S * a_sh.m_loc
    assert glob.shape == (m_pad, a.k)
    want = np.zeros((m_pad, a.k), np.float32)
    want[: a.m] = _dense(a)
    np.testing.assert_array_equal(_dense(glob), want)


def test_concat_csr_shards_empty_shards():
    a = _with_empty_rows(10, 12, seed=9)
    S = 8
    a_sh = partition_rows(a, S)
    glob = concat_csr_shards(a_sh.indptr, a_sh.indices, a_sh.values, a.k)
    want = np.zeros((S * a_sh.m_loc, a.k), np.float32)
    want[: a.m] = _dense(a)
    np.testing.assert_array_equal(_dense(glob), want)


def test_partition_value_map_matches_partition_rows():
    """values[perm] must reproduce partition_rows' value sharding on every
    live slot — the invariant the pinned replay relies on."""
    a = random_csr(57, 31, 3.0, seed=21)
    S = 8
    a_sh = partition_rows(a, S)
    perm = partition_value_map(a, S)
    assert perm.shape == a_sh.values.shape
    got = np.asarray(a.values)[perm]
    ip = np.asarray(a_sh.indptr)
    for s in range(S):
        nnz_s = ip[s, -1]
        np.testing.assert_array_equal(got[s, :nnz_s],
                                      np.asarray(a_sh.values)[s, :nnz_s])


def test_allgather_value_perm_matches_concat():
    """Stacked shard values routed through the perm == concat_csr_shards'
    value layout on every live slot (the hoisted-structure contract)."""
    b = random_csr(43, 29, 2.0, seed=31)
    S = 8
    b_sh = partition_rows(b, S)
    glob = concat_csr_shards(b_sh.indptr, b_sh.indices, b_sh.values, b.k)
    perm = allgather_value_perm(b_sh)
    got = np.asarray(b_sh.values).reshape(-1)[perm]
    nnz = int(np.asarray(glob.indptr)[-1])
    np.testing.assert_array_equal(got[:nnz], np.asarray(glob.values)[:nnz])


@pytest.mark.parametrize("placement", ["replicated", "allgather"])
@pytest.mark.parametrize("m", [96, 91, 5])
def test_distributed_spgemm_host_mesh(placement, m):
    """Full driver vs the dense oracle on the whole host mesh: indivisible
    row counts and empty shards, both B placements. Under tier-1 this is a
    1-device mesh; the CI dist job forces 8 host devices, so the same test
    exercises the shard_map paths mesh-wide in-process (the subprocess
    versions live in tests/test_distributed.py / test_dist_executor.py)."""
    from repro.launch.mesh import make_data_mesh

    a = random_csr(m, 64, 4.0, seed=m)
    b = random_csr(64, 48, 3.0, seed=m + 1)
    c = distributed_spgemm(a, b, make_data_mesh(), b_placement=placement)
    np.testing.assert_allclose(_dense(c), dense_spgemm_oracle(a, b),
                               rtol=1e-4, atol=1e-4)


def test_distributed_spgemm_empty_rows_oracle():
    from repro.launch.mesh import make_data_mesh

    a = _with_empty_rows(29, 16, seed=41)
    b = random_csr(16, 20, 2.0, seed=42)
    c = distributed_spgemm(a, b, make_data_mesh())
    np.testing.assert_allclose(_dense(c), dense_spgemm_oracle(a, b),
                               rtol=1e-4, atol=1e-4)
