from repro.serve.engine import ServeEngine, prefill_to_cache

__all__ = ["ServeEngine", "prefill_to_cache"]
