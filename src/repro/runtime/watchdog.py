"""Fault-tolerance runtime: heartbeats + straggler watchdog.

On a 1000+-node cluster the failure model is: (a) hard node loss — the
runner reschedules, the trainer resumes from the latest atomic checkpoint
with exact data skip-ahead; (b) stragglers — a step exceeding the deadline
flags the node; the policy (checkpoint-and-requeue) avoids dragging the
whole synchronous step at the slowest node's pace.

These are host-side utilities (no device code): Heartbeat writes a
liveness file the cluster runner monitors; StepWatchdog wraps each step and
triggers the straggler policy.
"""
from __future__ import annotations

import contextlib
import json
import os
import threading
import time


class Heartbeat:
    """Background thread writing {step, time} to a liveness file."""

    def __init__(self, path: str, interval_s: float = 10.0):
        self.path = path
        self.interval_s = interval_s
        self.step = 0
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    def start(self):
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()
        return self

    def _run(self):
        while not self._stop.is_set():
            tmp = self.path + ".tmp"
            with open(tmp, "w") as f:
                json.dump({"step": self.step, "time": time.time()}, f)
            os.replace(tmp, self.path)
            self._stop.wait(self.interval_s)

    def stop(self):
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=2 * self.interval_s)


class StragglerDetected(RuntimeError):
    pass


class StepWatchdog:
    """Flags steps that exceed a deadline (straggler mitigation hook).

    policy="raise"  -> raise StragglerDetected (caller checkpoints + exits
                       for reschedule; the default requeue-style policy)
    policy="warn"   -> print and continue (collect telemetry)
    """

    def __init__(self, deadline_s: float = 300.0, policy: str = "warn"):
        self.deadline_s = deadline_s
        self.policy = policy
        self.slow_steps: list[tuple[int, float]] = []

    @contextlib.contextmanager
    def step(self, step_idx: int):
        t0 = time.time()
        yield
        dt = time.time() - t0
        if dt > self.deadline_s:
            self.slow_steps.append((step_idx, dt))
            msg = (f"step {step_idx} took {dt:.1f}s "
                   f"(deadline {self.deadline_s:.1f}s)")
            if self.policy == "raise":
                raise StragglerDetected(msg)
            print("WATCHDOG:", msg)
