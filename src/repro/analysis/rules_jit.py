"""Rule ``jit-boundary`` — the ladder catches *outside* jit, jit stays pure.

The stack's central execution contract (ROADMAP, "hardened execution"):
failures must be caught outside ``jax.jit`` so a failed trace is never
cached, and traced code must never host-sync (that turns one kernel launch
into a device round-trip per call).

Sub-checks:

  * ``jit-boundary.try-in-traced`` — a ``try`` statement inside a function
    that is jit/Pallas-traced (directly decorated, wrapped via
    ``jax.jit(f)`` / ``pallas_call`` / ``partial``, or reachable by plain
    call from a traced function in the same module). Exceptions do not
    propagate out of a trace the way the ladder expects; catch at the
    dispatch site instead.
  * ``jit-boundary.host-sync`` — ``np.asarray`` / ``.item()`` /
    ``.block_until_ready()`` / ``float(...)`` / ``.tolist()`` inside a
    traced function. These force a device sync (or fail on tracers).
  * ``jit-boundary.silent-catch`` — an ``except Exception``/bare ``except``
    whose ``try`` body touches jit machinery (``.lower()``/``.compile()``,
    a jit-wrapped callable, ``pallas_call``) but whose handler neither
    re-raises, constructs a typed taxonomy error, nor records telemetry.
    That swallows a trace failure invisibly — the one thing the degradation
    ladder exists to make loud.
"""
from __future__ import annotations

import ast

from repro.analysis.asthelpers import (
    call_name_targets,
    calls_in,
    dotted,
    walk_functions,
)
from repro.analysis.context import ModuleInfo, Project
from repro.analysis.findings import Finding
from repro.analysis.registry import rule

RULE = "jit-boundary"

HOST_SYNC_ATTRS = {"item", "block_until_ready", "tolist"}
HOST_SYNC_CALLS = {"np.asarray", "numpy.asarray", "jax.device_get"}
HOST_SYNC_BUILTINS = {"float"}

_TRACE_WRAPPERS = ("jit", "pallas_call")


def _is_trace_wrapper(name: str) -> bool:
    last = name.rsplit(".", 1)[-1]
    return last in _TRACE_WRAPPERS


def _decorator_traced(fn: ast.FunctionDef | ast.AsyncFunctionDef) -> bool:
    for dec in fn.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        if _is_trace_wrapper(dotted(target)):
            return True
        # functools.partial(jax.jit, ...) as a decorator factory
        if isinstance(dec, ast.Call):
            for arg in dec.args:
                if _is_trace_wrapper(dotted(arg)):
                    return True
    return False


def traced_functions(mod: ModuleInfo) -> dict[str, ast.FunctionDef | ast.AsyncFunctionDef]:
    """Name → def for every function in ``mod`` that jit/Pallas traces,
    including same-module transitive callees (over-approximate on purpose:
    a helper called from traced code is traced code)."""
    defs: dict[str, ast.FunctionDef | ast.AsyncFunctionDef] = {}
    for fn in walk_functions(mod.tree):
        defs.setdefault(fn.name, fn)

    roots: set[str] = set()
    for name, fn in defs.items():
        if _decorator_traced(fn):
            roots.add(name)
    # f passed into jax.jit(...) / pallas_call(...) anywhere in the module,
    # including jitted = jax.jit(f) assignments and partial(f, ...) wrapping.
    for call in calls_in(mod.tree):
        if _is_trace_wrapper(dotted(call.func)):
            for target in call_name_targets(call):
                if target in defs:
                    roots.add(target)

    # same-module reachability by plain-Name call
    traced = set(roots)
    frontier = list(roots)
    while frontier:
        fn = defs[frontier.pop()]
        for call in calls_in(fn):
            if isinstance(call.func, ast.Name) and call.func.id in defs:
                callee = call.func.id
                if callee not in traced:
                    traced.add(callee)
                    frontier.append(callee)
    return {name: defs[name] for name in traced}


def _direct_jit_touch(node: ast.AST, jit_names: set[str]) -> bool:
    """Does ``node`` itself call into jit machinery?"""
    for call in calls_in(node):
        name = dotted(call.func)
        last = name.rsplit(".", 1)[-1]
        if last in {"lower", "compile"} or _is_trace_wrapper(name):
            return True
        if isinstance(call.func, ast.Name) and call.func.id in jit_names:
            return True
        # jitted-callable dict dispatch: _apply_donated[key](...)
        if isinstance(call.func, ast.Subscript):
            base = dotted(call.func.value)
            if base in jit_names:
                return True
    return False


def _jit_touching_functions(mod: ModuleInfo, jit_names: set[str]) -> set[str]:
    """Functions that touch jit machinery, directly or through same-module
    callees (a try around ``run_cell(...)`` wraps the compile inside it)."""
    defs = {fn.name: fn for fn in walk_functions(mod.tree)}
    touching = {name for name, fn in defs.items()
                if _direct_jit_touch(fn, jit_names)}
    changed = True
    while changed:
        changed = False
        for name, fn in defs.items():
            if name in touching:
                continue
            for call in calls_in(fn):
                if isinstance(call.func, ast.Name) and call.func.id in touching:
                    touching.add(name)
                    changed = True
                    break
    return touching


def _jit_touching(try_body: list[ast.stmt], jit_names: set[str],
                  touching_fns: set[str]) -> bool:
    """Does this try body reach jit machinery (directly or one same-module
    call away)?"""
    for stmt in try_body:
        if _direct_jit_touch(stmt, jit_names):
            return True
        for call in calls_in(stmt):
            if isinstance(call.func, ast.Name) and call.func.id in touching_fns:
                return True
    return False


def _handler_is_loud(handler: ast.ExceptHandler, taxonomy: frozenset[str]) -> bool:
    """A handler is acceptable when it re-raises, constructs a typed
    taxonomy error, or records to telemetry (counter augassign,
    ``recorder.note_error``/``record``, ``_count``)."""
    for node in ast.walk(handler):
        if isinstance(node, ast.Raise):
            return True
        if isinstance(node, ast.Call):
            name = dotted(node.func)
            last = name.rsplit(".", 1)[-1]
            if last in taxonomy:
                return True
            if last in {"note_error", "record", "_count"}:
                return True
        if isinstance(node, ast.AugAssign) and isinstance(node.target, ast.Subscript):
            base = dotted(node.target.value)
            if base.endswith("_COUNTS"):
                return True
    return False


def _broad(handler: ast.ExceptHandler) -> bool:
    t = handler.type
    if t is None:
        return True
    names = {dotted(t)} if not isinstance(t, ast.Tuple) else {
        dotted(e) for e in t.elts}
    return any(n.rsplit(".", 1)[-1] in {"Exception", "BaseException"}
               for n in names)


@rule(RULE, "failures caught outside jit; no try/host-sync inside traced code")
def check(project: Project):
    taxonomy = project.taxonomy_classes()
    for mod in project.modules:
        traced = traced_functions(mod)

        # names bound to jitted callables in this module (X = jax.jit(f))
        jit_names: set[str] = set(traced)
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
                if _is_trace_wrapper(dotted(node.value.func)):
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            jit_names.add(t.id)

        for name, fn in traced.items():
            for node in ast.walk(fn):
                if isinstance(node, ast.Try):
                    finding = Finding(
                        rule=RULE, code=f"{RULE}.try-in-traced",
                        path=mod.rel, line=node.lineno,
                        message=(f"try/except inside jit-traced function "
                                 f"'{name}' — the degradation ladder must "
                                 f"catch outside jit so a failed trace is "
                                 f"never cached"),
                        hint=("move the try to the dispatch site (see "
                              "kernels/ops.numeric_values) and keep the "
                              "traced body pure"),
                        snippet=mod.snippet(node.lineno))
                    yield finding
                if isinstance(node, ast.Call):
                    cname = dotted(node.func)
                    last = cname.rsplit(".", 1)[-1]
                    hit = None
                    if cname in HOST_SYNC_CALLS:
                        hit = cname
                    elif isinstance(node.func, ast.Attribute) and last in HOST_SYNC_ATTRS:
                        hit = f".{last}()"
                    elif isinstance(node.func, ast.Name) and last in HOST_SYNC_BUILTINS:
                        hit = f"{last}()"
                    if hit:
                        yield Finding(
                            rule=RULE, code=f"{RULE}.host-sync",
                            path=mod.rel, line=node.lineno,
                            message=(f"host-sync call {hit} inside "
                                     f"jit-traced function '{name}'"),
                            hint=("hoist the sync out of the traced body; "
                                  "pass concrete values in as arguments"),
                            snippet=mod.snippet(node.lineno))

        touching_fns = _jit_touching_functions(mod, jit_names)
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Try):
                continue
            if not _jit_touching(node.body, jit_names, touching_fns):
                continue
            for handler in node.handlers:
                if _broad(handler) and not _handler_is_loud(handler, taxonomy):
                    yield Finding(
                        rule=RULE, code=f"{RULE}.silent-catch",
                        path=mod.rel, line=handler.lineno,
                        message=("broad except around jit-touching code "
                                 "that neither re-raises typed, constructs "
                                 "a taxonomy error, nor records telemetry"),
                        hint=("re-raise a runtime.validate error, bump a "
                              "telemetry counter, or annotate with "
                              "# repro: allow[jit-boundary] and a why"),
                        snippet=mod.snippet(handler.lineno))
