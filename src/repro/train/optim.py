"""AdamW with ZeRO-1 optimizer-state sharding and gradient clipping.

Implemented directly (no external deps): moments are stored f32 and sharded
over the data axes in addition to the parameter's TP sharding wherever a
dimension divides (``zero1_shardings``) — the standard optimizer-state
partitioning that keeps the 2x-f32 moment memory off the TP-replicated axis.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100


class OptState(NamedTuple):
    mu: Any
    nu: Any
    step: jax.Array


def adamw_init(params) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
        step=jnp.zeros((), jnp.int32),
    )


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves)
    )


def adamw_update(grads, state: OptState, params, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = _schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (standard)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    new_p, new_m, new_v = [], [], []
    for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p):
        np_, nm, nv = upd(g, m, v, p)
        new_p.append(np_)
        new_m.append(nm)
        new_v.append(nv)
    return (
        jax.tree.unflatten(treedef, new_p),
        OptState(
            mu=jax.tree.unflatten(treedef, new_m),
            nu=jax.tree.unflatten(treedef, new_v),
            step=step,
        ),
        {"grad_norm": gnorm, "lr": lr},
    )


def zero1_shardings(param_shardings, dp_axes: tuple, mesh_shape: dict,
                    param_specs) -> Any:
    """Optimizer-moment shardings: param TP sharding + the data axes on the
    first dimension that is unsharded and divides by the DP size."""
    dp_size = 1
    for ax in dp_axes:
        dp_size *= mesh_shape[ax]
    dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]

    def shard_one(spec: P, sds) -> P:
        dims = list(spec) + [None] * (len(sds.shape) - len(spec))
        # skip leaves already using the data axes (e.g. FSDP'd experts)
        used = set()
        for s in dims:
            for name in (s if isinstance(s, tuple) else (s,)):
                used.add(name)
        if any(ax in used for ax in dp_axes):
            return P(*dims)
        for i, (s, n) in enumerate(zip(dims, sds.shape)):
            if s is None and n % dp_size == 0 and n > 0:
                dims[i] = dp
                return P(*dims)
        return P(*dims)

    return jax.tree.map(
        shard_one, param_shardings, param_specs,
        is_leaf=lambda x: isinstance(x, P),
    )
