"""Logical sharding rules: DP / TP / EP / SP mapping for every tensor role.

Axis conventions (DESIGN.md §6):
  * batch  -> ('pod', 'data')   (pod acts as outer data parallelism)
  * TP     -> 'model' (attention heads + FFN columns + vocab, Megatron-style)
  * EP     -> 'model' (MoE experts, via shard_map in models/moe.py)
  * SP     -> 'model' on the sequence dim of the residual stream (train), and
              on the KV-cache sequence dim for long-context decode.

Head-count divisibility: attention heads are TP-sharded only when
num_heads % tp == 0 (all assigned archs except qwen2-7b's 28 heads); the
fallback is row-parallel projections (contraction-dim sharding -> psum) with
model-replicated attention math. The dry-run roofline exposes the cost of
that fallback (MODEL_FLOPS / HLO_FLOPs ratio) — see EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import dataclasses

import jax
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Resolves tensor roles to PartitionSpecs for a concrete mesh shape."""

    dp_axes: tuple = ("data",)  # ('pod','data') on the multi-pod mesh
    tp_axis: str | None = "model"
    tp_size: int = 16
    dp_size: int = 1  # product of the data-axis sizes (for FSDP divisibility)
    enabled: bool = True
    # sequence-parallel residuals (train/prefill)
    sp_residual: bool = True
    # decode mode: KV caches stay sequence-sharded; q heads replicate
    # (sequence-parallel decode attention — tiny stat collectives instead of
    # an all-gather of the cache every token)
    decode: bool = False
    long_context: bool = False

    # ---- helpers -------------------------------------------------------
    def _tp_if(self, n: int):
        """tp axis if divisible, else None (replicated)."""
        return self.tp_axis if (self.tp_axis and n % self.tp_size == 0) else None

    @property
    def dp(self):
        return self.dp_axes if len(self.dp_axes) > 1 else self.dp_axes[0]

    def constraint(self, x, spec):
        if not self.enabled:
            return x
        return jax.lax.with_sharding_constraint(x, spec)

    # ---- parameter specs ----------------------------------------------
    def embed(self, vocab: int, d: int):
        return P(self._tp_if(vocab), None)

    def lm_head(self, d: int, vocab: int):
        return P(None, self._tp_if(vocab))

    def norm(self):
        return P(None)

    def wq(self, d: int, h: int, hd: int):
        tp = self._tp_if(h)
        if tp:
            return P(None, tp, None)
        # non-divisible head count (qwen2's 28 heads): replicate the (small)
        # attention weights; activations are query-sequence-sharded instead
        # (§Perf iteration: replaces 16x-replicated attention compute).
        return P(None, None, None)

    def wkv(self, d: int, h: int, hd: int):
        tp = self._tp_if(h)
        if tp:
            return P(None, tp, None)
        return P(None, None, None)

    def wo(self, h: int, hd: int, d: int):
        tp = self._tp_if(h)
        if tp:
            return P(tp, None, None)  # row-parallel: psum after
        return P(None, None, None)

    def ffn_in(self, d: int, f: int):
        return P(None, self._tp_if(f))

    def ffn_out(self, f: int, d: int):
        return P(self._tp_if(f), None)

    def moe_experts(self, e: int, *dims):
        """Experts over model (EP) + FSDP over the data axes on the first
        inner dim (at-rest sharding; models/moe.py all-gathers per layer).
        Grads inherit the FSDP sharding — without it, a 235B expert grad
        tree materializes model-sharded only (56 GB/chip)."""
        ep = self._tp_if(e)
        inner = [None] * len(dims)
        if dims and self.dp_size > 1 and dims[0] % self.dp_size == 0:
            inner[0] = self.dp
        return P(ep, *inner)

    def ssm_inproj(self, d: int, out: int):
        return P(None, self._tp_if(out))

    def ssm_outproj(self, d_in: int, d: int):
        return P(self._tp_if(d_in), None)

    # ---- role dispatch (param templates carry a role string per leaf) ----
    def spec_for(self, role: str, shape: tuple):
        if role == "wq":
            return self.wq(*shape)
        if role == "wkv":
            return self.wkv(*shape)
        if role == "wo":
            return self.wo(*shape)
        if role == "ffn_in":
            return self.ffn_in(*shape)
        if role == "ffn_out":
            return self.ffn_out(*shape)
        if role == "moe":
            return self.moe_experts(shape[0], *shape[1:])
        if role == "embed":
            return self.embed(*shape)
        if role == "lm_head":
            return self.lm_head(*shape)
        if role == "conv_ch":  # (K, C): channel dim TP
            return P(None, self._tp_if(shape[1]))
        if role == "conv_ch1":  # (C,)
            return P(self._tp_if(shape[0]))
        if role == "gate_block":  # (H, bw, bw): heads TP
            return P(self._tp_if(shape[0]), None, None)
        if role == "norm":
            return P(*([None] * len(shape)))
        from repro.runtime.validate import SpgemmConfigError  # cycle-free
        raise SpgemmConfigError(f"unknown param role {role!r}")

    # ---- activation constraints ----------------------------------------
    def residual(self, x):
        """(B, T, d) residual stream: batch over DP, seq over model (SP).
        Seq sharding is dropped when T doesn't divide (e.g. decode T=1)."""
        if x.ndim != 3:
            return x
        seq = self.tp_axis if self.sp_residual else None
        if seq is not None and x.shape[1] % self.tp_size:
            seq = None
        return self.constraint(x, P(self.dp, seq, None))

    def attn_activations(self, x, n_heads: int):
        """(B, T, H, hd) q/out activations. In decode mode q/out replicate
        over heads (the cache keeps the model axis on its seq dim —
        sequence-parallel decode attention). Non-divisible head counts fall
        back to query-sequence sharding (each model shard owns a q range;
        KV is replicated by attn_kv) — zero attention collectives."""
        if self.decode:
            dp = None if self.long_context else self.dp
            return self.constraint(x, P(dp, None, None, None))
        tp = self._tp_if(n_heads)
        if tp:
            return self.constraint(x, P(self.dp, None, tp, None))
        if self.tp_axis and x.shape[1] % self.tp_size == 0:
            return self.constraint(x, P(self.dp, self.tp_axis, None, None))
        return self.constraint(x, P(self.dp, None, None, None))

    def attn_kv(self, x, n_heads: int):
        """(B, T, H, hd) repeated KV: head-sharded when divisible, else
        fully replicated over model (full-T KV feeds every q shard)."""
        if self.decode:
            dp = None if self.long_context else self.dp
            return self.constraint(x, P(dp, None, None, None))
        tp = self._tp_if(n_heads)
        return self.constraint(x, P(self.dp, None, tp, None))

    def kv_cache_constraint(self, x):
        """(B, S, H, hd) decode cache tensors: pin seq-dim sharding so the
        attention einsum runs where the cache lives."""
        if not self.decode:
            return x
        spec = self.kv_cache_spec(x.shape[0], x.shape[2],
                                  long_context=self.long_context)
        return self.constraint(x, spec)

    def kv_cache_spec(self, batch: int, hkv: int, *, long_context: bool = False):
        """(B, S, Hkv, hd) cache. Long-context (batch < dp size): shard the
        sequence dim over every axis; else batch over DP, seq over model."""
        if long_context:
            axes = tuple(self.dp_axes) + ((self.tp_axis,) if self.tp_axis else ())
            return P(None, axes, None, None)
        return P(self.dp, self.tp_axis, None, None)

    def logits(self, x):
        """(B, T, V) vocab-sharded logits."""
        return self.constraint(x, P(self.dp, None, self._tp_if(x.shape[-1])))


NO_SHARDING = ShardingRules(enabled=False, tp_axis=None, tp_size=1)
