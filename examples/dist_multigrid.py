"""Sharded multigrid setup: a pinned ShardedPlan replayed across a V-cycle.

The distributed version of examples/multigrid_reuse.py — the paper's
headline Reuse scenario composed with the 1-D row decomposition of
``repro.dist``. The Galerkin products A_coarse = R*(A*P) pin one sharded
plan per multiply at setup; every timestep then replays both numeric
phases across the whole mesh as two shard_map dispatches — zero structure
hashing, zero re-partitioning, zero retraces (the printed telemetry proves
it). P stays ``replicated`` (it is small and read ~delta_A times); swap
``B_PLACEMENT`` to "allgather" to trade that memory for a values-only
all-gather per replay.

Forces an 8-device host platform, so it runs mesh-wide on any CPU box:

    PYTHONPATH=src python examples/dist_multigrid.py
"""
import os

_flags = os.environ.get("XLA_FLAGS", "")
if "host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        f"{_flags} --xla_force_host_platform_device_count=8".strip())

import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import HASH_COUNTS, ReuseExecutor, reset_hash_counts  # noqa: E402
from repro.core.spgemm import TRACE_COUNTS, reset_trace_counts  # noqa: E402
from repro.dist import ShardedReuseExecutor  # noqa: E402
from repro.launch.mesh import make_data_mesh  # noqa: E402
from repro.sparse import CSR, galerkin_triple  # noqa: E402

B_PLACEMENT = "replicated"


def main():
    mesh = make_data_mesh()
    shards = mesh.devices.size
    r, a, p = galerkin_triple(96, 96, agg_size=4)
    print(f"mesh: {shards} devices | fine grid: {a.shape[0]} dofs, "
          f"nnz={int(a.nnz())}")

    # --- setup: pin both sharded plans (one structure hash each, ever) ----
    reset_hash_counts()
    t0 = time.perf_counter()
    ex_ap = ShardedReuseExecutor.from_matrices(a, p, mesh,
                                               b_placement=B_PLACEMENT)
    ap_vals = ex_ap.apply(a.values, p.values)
    ap = ex_ap.merge(ap_vals)
    ex_rap = ShardedReuseExecutor.from_matrices(r, ap, mesh,
                                                b_placement=B_PLACEMENT)
    jax.block_until_ready(ex_rap.apply(r.values, ap.values))
    setup_s = time.perf_counter() - t0
    print(f"setup (partition+symbolic+pin x2): {setup_s * 1e3:.1f} ms, "
          f"structure hashes={sum(HASH_COUNTS.values())}")

    # --- V-cycle time stepping: values change, structure fixed ------------
    rng = np.random.default_rng(0)
    reset_trace_counts()
    reset_hash_counts()
    warm = None
    times = []
    for step in range(8):
        new_vals = jnp.asarray(rng.standard_normal(a.nnz_cap), jnp.float32)
        t0 = time.perf_counter()
        ap_v = ex_ap.apply(new_vals, p.values)
        # coarse-level operand: AP values routed into the pinned RAP layout
        # by one device-side gather (merge_values) — no host round-trip
        rap_v = ex_rap.apply(r.values, ex_ap.merge_values(ap_v))
        jax.block_until_ready(rap_v)
        times.append(time.perf_counter() - t0)
        if warm is None:
            warm = times[-1]
    reuse_ms = float(np.mean(times[1:])) * 1e3
    print(f"sharded reuse per timestep: {reuse_ms:.1f} ms "
          f"({setup_s * 1e3 / reuse_ms:.1f}x faster than setup); "
          f"retraces={sum(TRACE_COUNTS.values())}, "
          f"hashes={sum(HASH_COUNTS.values())} across {len(times)} steps")

    # --- ensemble: a batch of timesteps, ONE dispatch per product ---------
    batch = 8
    a_batch = jnp.asarray(rng.standard_normal((batch, a.nnz_cap)), jnp.float32)
    jax.block_until_ready(ex_ap.apply_batched(a_batch, p.values))  # warm
    t0 = time.perf_counter()
    ap_b = ex_ap.apply_batched(a_batch, p.values)  # (batch, S, nnz_cap)
    jax.block_until_ready(ap_b)
    batch_ms = (time.perf_counter() - t0) * 1e3
    print(f"batched sharded replay, {batch} timesteps in 1 dispatch: "
          f"{batch_ms:.1f} ms total, {batch_ms / batch:.2f} ms/timestep")

    # --- validate: sharded replay == single-device executor, bitwise ------
    ex_ref = ReuseExecutor.from_matrices(a, p)
    want = np.asarray(ex_ref.to_csr(ex_ref.apply(new_vals, p.values)).values)
    got = ex_ap.merge(ex_ap.apply(new_vals, p.values))
    nnz = int(got.indptr[-1])
    np.testing.assert_array_equal(np.asarray(got.values)[:nnz], want[:nnz])
    np.testing.assert_array_equal(np.asarray(ap_b[-1]),
                                  np.asarray(ex_ap.apply(a_batch[-1], p.values)))
    print("sharded == single-device (bitwise) validated. OK")


if __name__ == "__main__":
    main()
