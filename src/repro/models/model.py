"""Model assembly: param templates, init, forward (train/prefill), decode.

The layer stack is organized as the config's repeating ``pattern`` scanned
over ``pattern_repeats`` (stacked params, lax.scan — compile-time friendly
for 94-layer models) plus an unstacked ``tail``. Each pattern position may
be a different layer kind (attn / local / global / rec / ssm / moe).

Caches mirror the same structure; 'local' attention caches are ring buffers
of the window size when max_len exceeds the window (the long_500k enabler
for gemma2/recurrentgemma).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    AttnCache,
    attention_layer,
    attn_params_template,
    ffn_layer,
    ffn_params_template,
    rms_norm,
)
from repro.models.sharding import NO_SHARDING, ShardingRules
from repro.runtime.validate import SpgemmConfigError

COMPUTE_DTYPE = jnp.bfloat16
MAX_ENCODER_POS = 32_768  # learned positions for encoder-only archs

ATTN_KINDS = ("attn", "local", "global", "moe")


# --------------------------------------------------------------------------
# templates
# --------------------------------------------------------------------------


def layer_template(cfg: ModelConfig, kind: str) -> dict:
    if kind in ("attn", "local", "global"):
        return {"attn": attn_params_template(cfg), "ffn": ffn_params_template(cfg)}
    if kind == "moe":
        return {"attn": attn_params_template(cfg), "moe": moe_mod.moe_params_template(cfg)}
    if kind == "rec":
        return {"rec": rglru_mod.rglru_params_template(cfg), "ffn": ffn_params_template(cfg)}
    if kind == "ssm":
        return {"ssm": ssm_mod.ssm_params_template(cfg)}
    raise SpgemmConfigError(f"unknown block kind {kind!r}")


def model_template(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    t: dict[str, Any] = {
        "embed": ((cfg.vocab_size, d), "embed"),
        "final_norm": ((d,), "norm"),
    }
    if not cfg.tie_embeddings:
        t["lm_head"] = ((d, cfg.vocab_size), "lm_head")
    if cfg.frontend == "vision":
        t["frontend_proj"] = ((cfg.frontend_dim, d), "norm")
    elif cfg.frontend == "audio":
        t["frontend_proj"] = ((cfg.frontend_dim, d), "norm")
    if cfg.is_encoder:
        t["pos_embed"] = ((MAX_ENCODER_POS, d), "norm")

    def stack(template, n):
        return jax.tree.map(
            lambda leaf: ((n,) + leaf[0], leaf[1]),
            template,
            is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
            and isinstance(x[0], tuple),
        )

    t["blocks"] = [
        stack(layer_template(cfg, kind), cfg.pattern_repeats)
        for kind in cfg.pattern
    ]
    t["tail"] = [layer_template(cfg, kind) for kind in cfg.tail]
    return t


def _is_template_leaf(x):
    return (
        isinstance(x, tuple) and len(x) == 2 and isinstance(x[0], tuple)
        and isinstance(x[1], str)
    )


def param_specs(cfg: ModelConfig, rules: ShardingRules, dtype=jnp.float32):
    """ShapeDtypeStruct tree (for .lower) without allocating anything."""
    t = model_template(cfg)
    return jax.tree.map(
        lambda leaf: jax.ShapeDtypeStruct(leaf[0], dtype),
        t, is_leaf=_is_template_leaf,
    )


def param_shardings(cfg: ModelConfig, rules: ShardingRules):
    """PartitionSpec tree matching param_specs. Stacked (pattern) leaves get
    a leading None for the repeat dim."""
    t = model_template(cfg)
    from jax.sharding import PartitionSpec as P

    out: dict[str, Any] = {}
    for key, sub in t.items():
        if key == "blocks":
            out["blocks"] = [
                jax.tree.map(
                    lambda leaf: P(None, *rules.spec_for(leaf[1], leaf[0][1:])),
                    blk, is_leaf=_is_template_leaf,
                )
                for blk in sub
            ]
        elif key == "tail":
            out["tail"] = [
                jax.tree.map(
                    lambda leaf: rules.spec_for(leaf[1], leaf[0]),
                    blk, is_leaf=_is_template_leaf,
                )
                for blk in sub
            ]
        else:
            out[key] = rules.spec_for(sub[1], sub[0])
    return out


def init_params(cfg: ModelConfig, rng: jax.Array, dtype=jnp.float32):
    t = model_template(cfg)
    leaves, treedef = jax.tree.flatten(t, is_leaf=_is_template_leaf)
    keys = jax.random.split(rng, len(leaves))

    def init_leaf(leaf, key):
        shape, role = leaf
        if role == "norm" or len(shape) == 1:
            return jnp.zeros(shape, dtype)
        scale = 0.02
        return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)

    return jax.tree.unflatten(
        treedef, [init_leaf(l, k) for l, k in zip(leaves, keys)]
    )


# --------------------------------------------------------------------------
# caches
# --------------------------------------------------------------------------


def _cache_len(cfg: ModelConfig, kind: str, max_len: int) -> int:
    if kind == "local" and cfg.window is not None:
        return min(max_len, cfg.window)
    return max_len


def _kind_cache_template(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                         dtype):
    hd = cfg.resolved_head_dim
    if kind in ATTN_KINDS:
        s = _cache_len(cfg, kind, max_len)
        shp = (batch, s, cfg.num_kv_heads, hd)
        return AttnCache(
            k=jax.ShapeDtypeStruct(shp, dtype), v=jax.ShapeDtypeStruct(shp, dtype)
        )
    if kind == "rec":
        w = cfg.lru_width or cfg.d_model
        return rglru_mod.RGLRUCache(
            state=jax.ShapeDtypeStruct((batch, w), jnp.float32),
            conv=jax.ShapeDtypeStruct((batch, cfg.conv_width - 1, w), dtype),
        )
    if kind == "ssm":
        d_in = cfg.ssm_expand * cfg.d_model
        n_heads = d_in // cfg.ssm_head_dim
        return ssm_mod.SSMCache(
            state=jax.ShapeDtypeStruct(
                (batch, n_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32
            ),
            conv_x=jax.ShapeDtypeStruct((batch, cfg.conv_width - 1, d_in), dtype),
            conv_bc=jax.ShapeDtypeStruct(
                (batch, cfg.conv_width - 1, 2 * cfg.ssm_state), dtype
            ),
        )
    raise SpgemmConfigError(f"unknown block kind {kind!r}")


def cache_template(cfg: ModelConfig, batch: int, max_len: int,
                   dtype=COMPUTE_DTYPE):
    """ShapeDtypeStruct tree of the decode cache (stacked like params)."""
    def stack(tmpl, n):
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), tmpl
        )

    return {
        "blocks": [
            stack(_kind_cache_template(cfg, kind, batch, max_len, dtype),
                  cfg.pattern_repeats)
            for kind in cfg.pattern
        ],
        "tail": [
            _kind_cache_template(cfg, kind, batch, max_len, dtype)
            for kind in cfg.tail
        ],
    }


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=COMPUTE_DTYPE):
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), cache_template(cfg, batch, max_len, dtype)
    )


def cache_shardings(cfg: ModelConfig, rules: ShardingRules, batch: int,
                    max_len: int, *, long_context: bool = False):
    from jax.sharding import PartitionSpec as P

    def kind_spec(kind, stacked: bool):
        lead = (None,) if stacked else ()
        if kind in ATTN_KINDS:
            kv = rules.kv_cache_spec(batch, cfg.num_kv_heads,
                                     long_context=long_context)
            return AttnCache(k=P(*lead, *kv), v=P(*lead, *kv))
        if kind == "rec":
            w_tp = rules._tp_if((cfg.lru_width or cfg.d_model))
            return rglru_mod.RGLRUCache(
                state=P(*lead, rules.dp if not long_context else None, w_tp),
                conv=P(*lead, rules.dp if not long_context else None, None, w_tp),
            )
        if kind == "ssm":
            d_in = cfg.ssm_expand * cfg.d_model
            n_heads = d_in // cfg.ssm_head_dim
            h_tp = rules._tp_if(n_heads)
            dp = rules.dp if not long_context else None
            return ssm_mod.SSMCache(
                state=P(*lead, dp, h_tp, None, None),
                conv_x=P(*lead, dp, None, rules._tp_if(d_in)),
                conv_bc=P(*lead, dp, None, None),
            )
        raise SpgemmConfigError(f"unknown block kind {kind!r}")

    return {
        "blocks": [kind_spec(kind, True) for kind in cfg.pattern],
        "tail": [kind_spec(kind, False) for kind in cfg.tail],
    }


# --------------------------------------------------------------------------
# layer application
# --------------------------------------------------------------------------


def apply_layer(kind: str, p, x, cfg: ModelConfig, rules: ShardingRules, *,
                positions, mesh=None, cache=None, pos=None, max_len=None,
                return_cache: bool = False):
    """One block of the given kind. Returns (x, new_cache)."""
    window = cfg.window if kind == "local" else None
    if kind in ATTN_KINDS:
        ring = (
            kind == "local" and cfg.window is not None and max_len is not None
            and max_len > cfg.window
        )
        delta, new_c = attention_layer(
            p["attn"], x, cfg, rules, window=window, positions=positions,
            cache=cache, pos=pos, ring=ring, return_cache=return_cache,
        )
        x = rules.residual(x + delta)
        if kind == "moe":
            x = rules.residual(x + moe_mod.moe_layer(p["moe"], x, cfg, rules, mesh=mesh))
        else:
            x = rules.residual(x + ffn_layer(p["ffn"], x, cfg, rules))
        return x, new_c
    if kind == "rec":
        delta, new_c = rglru_mod.rglru_layer(
            p["rec"], x, cfg, rules, cache=cache, return_cache=return_cache
        )
        x = rules.residual(x + delta)
        x = rules.residual(x + ffn_layer(p["ffn"], x, cfg, rules))
        return x, new_c
    if kind == "ssm":
        delta, new_c = ssm_mod.ssm_layer(
            p["ssm"], x, cfg, rules, cache=cache, return_cache=return_cache
        )
        x = rules.residual(x + delta)
        return x, new_c
    raise SpgemmConfigError(f"unknown block kind {kind!r}")


# --------------------------------------------------------------------------
# embedding / head
# --------------------------------------------------------------------------


def embed_inputs(params, batch: dict, cfg: ModelConfig, rules: ShardingRules):
    """batch: {'tokens': (B,T) int32, optional 'patches'/'frames'}.
    Returns (x (B,T,d) compute-dtype, positions (T,))."""
    emb = params["embed"]
    if cfg.frontend == "audio":
        frames = batch["frames"]  # (B, T, frontend_dim)
        x = frames.astype(COMPUTE_DTYPE) @ params["frontend_proj"].astype(COMPUTE_DTYPE)
        t = x.shape[1]
        x = x + params["pos_embed"][:t].astype(COMPUTE_DTYPE)[None] if cfg.is_encoder else x
        return x, jnp.arange(t, dtype=jnp.int32)
    tokens = batch["tokens"]
    x = emb[tokens].astype(COMPUTE_DTYPE)
    if cfg.frontend == "vision" and "patches" in batch:
        patches = batch["patches"]  # (B, P, frontend_dim)
        pe = patches.astype(COMPUTE_DTYPE) @ params["frontend_proj"].astype(COMPUTE_DTYPE)
        npatch = pe.shape[1]
        x = jnp.concatenate([pe, x[:, npatch:]], axis=1)
    t = x.shape[1]
    return x, jnp.arange(t, dtype=jnp.int32)


def lm_logits(params, x, cfg: ModelConfig, rules: ShardingRules):
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head.astype(x.dtype)
    if cfg.final_softcap is not None:
        logits = (jnp.tanh(logits.astype(jnp.float32) / cfg.final_softcap)
                  * cfg.final_softcap).astype(logits.dtype)
    return rules.logits(logits)


# --------------------------------------------------------------------------
# forward (train / prefill) and decode
# --------------------------------------------------------------------------


def forward(params, batch: dict, cfg: ModelConfig, rules: ShardingRules, *,
            mesh=None, return_caches: bool = False, max_len: int | None = None,
            remat: bool = True):
    """Full-sequence forward. Returns (logits, caches|None)."""
    x, positions = embed_inputs(params, batch, cfg, rules)
    x = rules.residual(x)
    max_len = max_len or x.shape[1]

    def block_step(x, block_params):
        caches = []
        for pos_i, kind in enumerate(cfg.pattern):
            x, c = apply_layer(
                kind, block_params[pos_i], x, cfg, rules, positions=positions,
                mesh=mesh, max_len=max_len, return_cache=return_caches,
            )
            caches.append(c)
        return x, tuple(caches)

    step = jax.checkpoint(block_step) if remat else block_step
    x, stacked_caches = jax.lax.scan(step, x, tuple(params["blocks"]))

    tail_caches = []
    for blk_params, kind in zip(params["tail"], cfg.tail):
        x, c = apply_layer(
            kind, blk_params, x, cfg, rules, positions=positions, mesh=mesh,
            max_len=max_len, return_cache=return_caches,
        )
        tail_caches.append(c)

    logits = lm_logits(params, x, cfg, rules)
    caches = None
    if return_caches:
        caches = {"blocks": list(stacked_caches), "tail": tail_caches}
    return logits, caches


def decode_step(params, caches, tokens, pos, cfg: ModelConfig,
                rules: ShardingRules, *, mesh=None, max_len: int):
    """One decode step. tokens: (B, 1); pos: () int32 absolute position.
    Returns (logits (B, 1, V), new caches)."""
    x = params["embed"][tokens].astype(COMPUTE_DTYPE)
    positions = pos[None] if pos.ndim == 0 else pos
    x = rules.constraint(x, jax.sharding.PartitionSpec(rules.dp, None, None)) \
        if rules.enabled else x

    def block_step(x, xs):
        block_params, block_caches = xs
        new_caches = []
        for pos_i, kind in enumerate(cfg.pattern):
            x, c = apply_layer(
                kind, block_params[pos_i], x, cfg, rules, positions=positions,
                mesh=mesh, cache=block_caches[pos_i], pos=pos, max_len=max_len,
            )
            new_caches.append(c)
        return x, tuple(new_caches)

    x, new_stacked = jax.lax.scan(
        block_step, x, (tuple(params["blocks"]), tuple(caches["blocks"]))
    )

    new_tail = []
    for blk_params, kind, c in zip(params["tail"], cfg.tail, caches["tail"]):
        x, nc = apply_layer(
            kind, blk_params, x, cfg, rules, positions=positions, mesh=mesh,
            cache=c, pos=pos, max_len=max_len,
        )
        new_tail.append(nc)

    logits = lm_logits(params, x, cfg, rules)
    return logits, {"blocks": list(new_stacked), "tail": new_tail}
