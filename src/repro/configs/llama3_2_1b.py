"""llama3.2-1b [dense] — hf:meta-llama/Llama-3.2-1B (unverified tier).

16L, d_model=2048, 32 heads (GQA kv=8), d_ff=8192, vocab=128256.
SpGEMM applicability: none (dense matmul path) — DESIGN.md §Arch-applicability.
long_500k: skipped (pure full attention).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=32,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=128_256,
    head_dim=64,
    rope_theta=500_000.0,
    tie_embeddings=True,
    act="silu",
)

SMOKE = ModelConfig(
    name="llama3.2-1b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    head_dim=16,
    rope_theta=500_000.0,
    tie_embeddings=True,
)

SKIP_SHAPES = {"long_500k": "pure full-attention arch (per-spec skip)"}
