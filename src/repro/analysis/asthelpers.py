"""Shared AST utilities for the rule modules."""
from __future__ import annotations

import ast
from typing import Iterator


def dotted(node: ast.expr) -> str:
    """Best-effort dotted name for a call target: ``jax.jit`` → "jax.jit"."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = dotted(node.value)
        return f"{base}.{node.attr}" if base else node.attr
    return ""


def walk_functions(tree: ast.Module) -> Iterator[ast.FunctionDef | ast.AsyncFunctionDef]:
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def calls_in(node: ast.AST) -> Iterator[ast.Call]:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            yield sub


def call_name_targets(call: ast.Call) -> list[str]:
    """Plain-Name function arguments of a call (``jax.jit(f)`` → ["f"]),
    looking through ``functools.partial(f, ...)`` one level."""
    out = []
    for arg in call.args:
        if isinstance(arg, ast.Name):
            out.append(arg.id)
        elif isinstance(arg, ast.Call) and dotted(arg.func).endswith("partial"):
            for inner in arg.args[:1]:
                if isinstance(inner, ast.Name):
                    out.append(inner.id)
    for kw in call.keywords:
        if isinstance(kw.value, ast.Name):
            out.append(kw.value.id)
    return out


def is_string(node: ast.expr) -> bool:
    return isinstance(node, ast.Constant) and isinstance(node.value, str)


def string_value(node: ast.expr) -> str | None:
    if is_string(node):
        return node.value
    return None


def fstring_template(node: ast.JoinedStr) -> str:
    """Render an f-string with dynamic parts as a ``\\x00`` sentinel:
    ``f"fault:{a}->{b}"`` → ``"fault:\\x00->\\x00"``."""
    parts = []
    for piece in node.values:
        if isinstance(piece, ast.Constant) and isinstance(piece.value, str):
            parts.append(piece.value)
        else:
            parts.append("\x00")
    return "".join(parts)


def module_import_time_nodes(tree: ast.Module) -> Iterator[ast.stmt]:
    """Statements executed at import time: module body plus class bodies,
    recursing through ``if``/``try`` at module level, but never into
    function bodies."""

    def visit(stmts):
        for node in stmts:
            yield node
            if isinstance(node, ast.ClassDef):
                yield from visit(node.body)
            elif isinstance(node, ast.If):
                yield from visit(node.body)
                yield from visit(node.orelse)
            elif isinstance(node, ast.Try):
                yield from visit(node.body)
                for h in node.handlers:
                    yield from visit(h.body)
                yield from visit(node.orelse)
                yield from visit(node.finalbody)
            elif isinstance(node, (ast.With, ast.For, ast.While)):
                yield from visit(node.body)
                yield from visit(getattr(node, "orelse", []))

    yield from visit(tree.body)


def enclosing_main_guard(tree: ast.Module, target: ast.stmt) -> bool:
    """Is ``target`` (a module-level statement) under ``if __name__ == ...``?"""
    for node in tree.body:
        if isinstance(node, ast.If):
            test = node.test
            names = {dotted(c) for c in ast.walk(test) if isinstance(c, ast.Name)}
            if "__name__" in names:
                for sub in ast.walk(node):
                    if sub is target:
                        return True
    return False
