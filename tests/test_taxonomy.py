"""Regression tests for the PR-10 typed-taxonomy conversions.

Pre-taxonomy modules raised bare ``ValueError``/``RuntimeError``; the
analysis pass (rule ``taxonomy``) forced them onto the typed classes. These
tests pin both halves of the contract: the *typed* class is raised, and it
still subclasses the original builtin so no pre-existing caller breaks.
"""
import numpy as np
import pytest

from repro.core.executor import ReuseExecutor
from repro.core.meta import round_capacity
from repro.core.spgemm import spgemm
from repro.dist.plan import build_sharded_plan
from repro.runtime.validate import (CapacityOverflowError, SpgemmConfigError,
                                    SpgemmError, TrainingDivergedError,
                                    resolve_mode)
from repro.serve.breaker import CircuitBreaker
from repro.sparse import CSR, random_csr


def test_new_classes_slot_into_the_taxonomy():
    assert issubclass(SpgemmConfigError, SpgemmError)
    assert issubclass(SpgemmConfigError, ValueError)
    assert issubclass(TrainingDivergedError, SpgemmError)
    assert issubclass(TrainingDivergedError, RuntimeError)


def test_from_dense_overflow_is_typed():
    dense = np.eye(4, dtype=np.float32)
    with pytest.raises(CapacityOverflowError, match="nnz_cap=2 < nnz=4"):
        CSR.from_dense(dense, nnz_cap=2)
    # legacy callers that caught ValueError still work
    with pytest.raises(ValueError):
        CSR.from_dense(dense, nnz_cap=2)


def test_build_sharded_plan_bad_placement_is_typed():
    a = random_csr(8, 8, 2.0, seed=0)
    b = random_csr(8, 8, 2.0, seed=1)
    with pytest.raises(SpgemmConfigError, match="b_placement"):
        build_sharded_plan(a, b, mesh=None, b_placement="broadcast")


def test_resolve_mode_typo_is_typed():
    with pytest.raises(SpgemmConfigError, match="unknown validate mode"):
        resolve_mode("hots")
    with pytest.raises(ValueError):  # legacy catch still works
        resolve_mode("hots")


def test_spgemm_bad_method_is_typed():
    a = random_csr(8, 8, 2.0, seed=0)
    b = random_csr(8, 8, 2.0, seed=1)
    with pytest.raises(SpgemmConfigError, match="unknown method"):
        spgemm(a, b, method="dense_acc")


def test_executor_bad_backend_is_typed():
    a = random_csr(8, 8, 2.0, seed=0)
    b = random_csr(8, 8, 2.0, seed=1)
    res = spgemm(a, b, method="sparse")
    with pytest.raises(SpgemmConfigError, match="unknown backend"):
        ReuseExecutor(res.plan, backend="cuda")


def test_round_capacity_bad_policy_is_typed():
    with pytest.raises(SpgemmConfigError, match="unknown pad_policy"):
        round_capacity(7, "exact-ish")


def test_breaker_bad_threshold_is_typed():
    with pytest.raises(SpgemmConfigError, match="failure_threshold"):
        CircuitBreaker("b", failure_threshold=0)
