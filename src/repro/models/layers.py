"""Core transformer layers: norms, RoPE, blockwise attention, FFN.

Attention is implemented blockwise in pure XLA (flash-style online softmax,
python loop over static query chunks + lax.scan over KV blocks) so that 32k
prefill never materializes a (T, T) score matrix — this is the dry-run /
CPU path; the Pallas flash kernel (kernels/flash_attention.py) is the
real-TPU option behind ``attention_impl``.

GQA is computed in full query-head space (KV repeated to Hq) so every
attention einsum carries one explicit head dim that the GSPMD partitioner
shards cleanly (models/sharding.py); the repeat is sharded too, so its
memory cost is q-sized per shard.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.sharding import ShardingRules

COMPUTE_DTYPE = jnp.bfloat16
NEG_INF = -2.0 ** 30


# --------------------------------------------------------------------------
# primitives
# --------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, T, H, hd); positions: (T,) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half
    )
    angles = positions.astype(jnp.float32)[:, None] * freqs  # (T, half)
    cos = cos_b = jnp.cos(angles)[None, :, None, :]  # (1, T, 1, half)
    sin = jnp.sin(angles)[None, :, None, :]
    x1 = x[..., :half].astype(jnp.float32)
    x2 = x[..., half:].astype(jnp.float32)
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos_b + x1 * sin], axis=-1
    ).astype(x.dtype)


def _softcap(s: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return s
    return jnp.tanh(s / cap) * cap


def repeat_kv(k: jax.Array, group: int) -> jax.Array:
    """(B, T, Hkv, hd) -> (B, T, Hq, hd)."""
    if group == 1:
        return k
    return jnp.repeat(k, group, axis=2)


# --------------------------------------------------------------------------
# blockwise attention (train / prefill)
# --------------------------------------------------------------------------


def blockwise_attention(q, k, v, *, causal: bool, window: Optional[int],
                        softcap: Optional[float], q_chunk: int = 1024,
                        k_block: int = 1024) -> jax.Array:
    """q/k/v: (B, T, H, hd), same H (KV pre-repeated) -> (B, Tq, H, hd).

    Static python loop over query chunks — each chunk's KV extent is static,
    so causal/window block skipping is free (compiled FLOPs ~= true masked
    FLOPs). lax.scan + online softmax over KV blocks bounds peak memory to a
    (B, H, q_chunk, k_block) score tile.
    """
    b, tq, h, hd = q.shape
    tk = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    q_chunk = min(q_chunk, tq)
    k_block = min(k_block, tk)

    out_chunks = []
    n_chunks = -(-tq // q_chunk)
    for ci in range(n_chunks):
        s_q = ci * q_chunk
        e_q = min(s_q + q_chunk, tq)
        cq = e_q - s_q
        kv_end = tk if not causal else min(tk, e_q)
        kv_start = 0
        if window is not None:
            kv_start = (max(0, s_q - window + 1) // k_block) * k_block
        nb = max(-(-(kv_end - kv_start) // k_block), 1)

        qc = q[:, s_q:e_q].astype(jnp.float32) * scale  # (B,cq,H,hd)
        end = min(kv_start + nb * k_block, tk)
        k_sl = k[:, kv_start:end]
        v_sl = v[:, kv_start:end]
        pad = nb * k_block - k_sl.shape[1]
        if pad:
            k_sl = jnp.pad(k_sl, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v_sl = jnp.pad(v_sl, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kb = k_sl.reshape(b, nb, k_block, h, hd).transpose(1, 0, 2, 3, 4)
        vb = v_sl.reshape(b, nb, k_block, h, hd).transpose(1, 0, 2, 3, 4)

        qpos = s_q + jnp.arange(cq, dtype=jnp.int32)

        def body(carry, blk):
            m_prev, l_prev, acc = carry
            kblk, vblk, bi = blk  # (B,k_block,H,hd) x2, ()
            s = jnp.einsum(
                "bqhd,bkhd->bhqk", qc, kblk.astype(jnp.float32),
                preferred_element_type=jnp.float32,
            )
            s = _softcap(s, softcap)
            kpos = kv_start + bi * k_block + jnp.arange(k_block, dtype=jnp.int32)
            mask = jnp.ones((cq, k_block), jnp.bool_)
            mask &= kpos[None, :] < tk  # padding
            if causal:
                mask &= qpos[:, None] >= kpos[None, :]
            if window is not None:
                mask &= qpos[:, None] - kpos[None, :] < window
            s = jnp.where(mask[None, None], s, NEG_INF)
            m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_cur[..., None])
            alpha = jnp.exp(m_prev - m_cur)
            l_cur = l_prev * alpha + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p, vblk.astype(jnp.float32),
                preferred_element_type=jnp.float32,
            )
            return (m_cur, l_cur, acc), None

        init = (
            jnp.full((b, h, cq), NEG_INF, jnp.float32),
            jnp.zeros((b, h, cq), jnp.float32),
            jnp.zeros((b, h, cq, hd), jnp.float32),
        )
        (m_f, l_f, acc), _ = jax.lax.scan(
            body, init, (kb, vb, jnp.arange(nb, dtype=jnp.int32))
        )
        l_f = jnp.where(l_f == 0.0, 1.0, l_f)
        oc = (acc / l_f[..., None]).transpose(0, 2, 1, 3)  # (B,cq,H,hd)
        out_chunks.append(oc.astype(q.dtype))
    return jnp.concatenate(out_chunks, axis=1)


def decode_attention(q, k_cache, v_cache, pos, *, window: Optional[int],
                     softcap: Optional[float], ring: bool = False) -> jax.Array:
    """One-token attention against a (possibly ring-buffer) cache.

    q: (B, 1, H, hd); k_cache/v_cache: (B, S, H, hd) (KV pre-repeated);
    pos: () int32 — query's absolute position (cache holds pos' <= pos).
    ring=True: S == window and slot i holds absolute position
    pos - ((pos - i) mod S).
    """
    b, s, h, hd = k_cache.shape
    scale = 1.0 / math.sqrt(hd)
    qs = q.astype(jnp.float32) * scale
    scores = jnp.einsum(
        "bqhd,bshd->bhqs", qs, k_cache.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )  # (B,H,1,S)
    scores = _softcap(scores, softcap)
    idx = jnp.arange(s, dtype=jnp.int32)
    if ring:
        abs_pos = pos - jnp.mod(pos - idx, s)
        mask = (abs_pos >= 0) & (abs_pos <= pos)
        if window is not None:
            mask &= pos - abs_pos < window
    else:
        mask = idx <= pos
        if window is not None:
            mask &= pos - idx < window
    scores = jnp.where(mask[None, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum(
        "bhqs,bshd->bqhd", p, v_cache.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    return out.astype(q.dtype)


# --------------------------------------------------------------------------
# attention layer (QKV/O + rope + norm)
# --------------------------------------------------------------------------


class AttnCache(NamedTuple):
    k: jax.Array  # (B, S, Hkv, hd)
    v: jax.Array


def attn_params_template(cfg: ModelConfig):
    d, hq, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    t = {
        "wq": ((d, hq, hd), "wq"),
        "wk": ((d, hkv, hd), "wkv"),
        "wv": ((d, hkv, hd), "wkv"),
        "wo": ((hq, hd, d), "wo"),
        "norm": ((d,), "norm"),
    }
    if cfg.qkv_bias:
        t["bq"] = ((hq, hd), "norm")
        t["bk"] = ((hkv, hd), "norm")
        t["bv"] = ((hkv, hd), "norm")
    if cfg.qk_norm:
        t["q_norm"] = ((hd,), "norm")
        t["k_norm"] = ((hd,), "norm")
    return t


def attention_layer(p, x, cfg: ModelConfig, rules: ShardingRules, *,
                    window: Optional[int], positions: jax.Array,
                    cache: Optional[AttnCache] = None,
                    pos: Optional[jax.Array] = None,
                    ring: bool = False,
                    return_cache: bool = False):
    """Pre-norm attention block. Returns (residual_delta, new_cache|None).

    Prefill/train: cache None -> full-sequence blockwise attention; with
    return_cache=True the fresh (k, v) are handed back (prefill serving).
    Decode: cache given, x is (B, 1, d), ``pos`` the absolute position.
    """
    group = cfg.num_heads // cfg.num_kv_heads
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    q = jnp.einsum("btd,dhk->bthk", h, p["wq"].astype(h.dtype))
    k = jnp.einsum("btd,dhk->bthk", h, p["wk"].astype(h.dtype))
    v = jnp.einsum("btd,dhk->bthk", h, p["wv"].astype(h.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(h.dtype)
        k = k + p["bk"].astype(h.dtype)
        v = v + p["bv"].astype(h.dtype)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.causal:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    q = rules.attn_activations(q, cfg.num_heads)

    new_cache = None
    if cache is None:
        kr = rules.attn_kv(repeat_kv(k, group), cfg.num_heads)
        vr = rules.attn_kv(repeat_kv(v, group), cfg.num_heads)
        out = blockwise_attention(
            q, kr, vr, causal=cfg.causal, window=window,
            softcap=cfg.attn_softcap,
        )
        if return_cache:
            new_cache = AttnCache(k=k, v=v)
    else:
        s = cache.k.shape[1]
        slot = jnp.mod(pos, s) if ring else pos
        k_c = jax.lax.dynamic_update_slice(
            cache.k, k.astype(cache.k.dtype), (0, slot, 0, 0)
        )
        v_c = jax.lax.dynamic_update_slice(
            cache.v, v.astype(cache.v.dtype), (0, slot, 0, 0)
        )
        k_c = rules.kv_cache_constraint(k_c)
        v_c = rules.kv_cache_constraint(v_c)
        out = decode_attention(
            q, repeat_kv(k_c, group), repeat_kv(v_c, group), pos,
            window=window, softcap=cfg.attn_softcap, ring=ring,
        )
        new_cache = AttnCache(k=k_c, v=v_c)
    out = rules.attn_activations(out, cfg.num_heads)
    delta = jnp.einsum("bthk,hkd->btd", out, p["wo"].astype(out.dtype))
    return delta, new_cache


# --------------------------------------------------------------------------
# FFN
# --------------------------------------------------------------------------


def ffn_params_template(cfg: ModelConfig):
    d, f = cfg.d_model, cfg.d_ff
    if cfg.act == "gelu2":  # plain 2-matrix FFN (hubert)
        return {
            "w1": ((d, f), "ffn_in"),
            "w2": ((f, d), "ffn_out"),
            "norm": ((d,), "norm"),
        }
    return {
        "w1": ((d, f), "ffn_in"),
        "w3": ((d, f), "ffn_in"),
        "w2": ((f, d), "ffn_out"),
        "norm": ((d,), "norm"),
    }


def ffn_layer(p, x, cfg: ModelConfig, rules: ShardingRules):
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    if cfg.act == "gelu2":
        u = jax.nn.gelu(h @ p["w1"].astype(h.dtype))
        return u @ p["w2"].astype(h.dtype)
    gate_act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    u = gate_act(h @ p["w1"].astype(h.dtype)) * (h @ p["w3"].astype(h.dtype))
    return u @ p["w2"].astype(h.dtype)
