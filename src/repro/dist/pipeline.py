"""GPipe-style pipeline parallelism over a mesh axis.

``pipeline_forward`` runs a stack of identical layers whose weights are
sharded one-stage-per-device over ``axis``, streaming microbatches through
the ring: at step t, stage 0 ingests microbatch t while stage s processes
the activation it received from stage s-1, and every stage forwards its
output with one ``ppermute``. After ``n_microbatches + n_stages - 1`` steps
every microbatch has crossed every stage — the classic pipeline fill/drain
schedule, expressed as a ``fori_loop`` inside one ``shard_map``.

This is the third decomposition the scaling story needs next to the row
sharding of ``repro.dist`` (data/plan parallel) and the expert parallelism
in ``models/moe.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map


def pipeline_forward(layer, weights: jax.Array, x: jax.Array, mesh,
                     axis: str = "pipe") -> jax.Array:
    """Apply ``n_stages`` layers to microbatched ``x`` through the pipeline.

    layer:    ``(w, h) -> h`` — one stage's computation.
    weights:  (n_stages, ...) stage weights, sharded over ``axis``.
    x:        (n_microbatches, ...) microbatches, replicated.
    Returns the replicated (n_microbatches, ...) outputs, equal to applying
    the stages serially.
    """
    n_stages = mesh.shape[axis]
    n_mb = x.shape[0]
    ring = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def fn(w_loc, x_all):
        w = w_loc[0]
        idx = jax.lax.axis_index(axis)

        def step(t, carry):
            buf, outs = carry
            inp = jnp.where(idx == 0, x_all[jnp.clip(t, 0, n_mb - 1)], buf)
            out = layer(w, inp)
            mb = t - (n_stages - 1)  # microbatch draining at the last stage
            write = (idx == n_stages - 1) & (mb >= 0)
            slot = jnp.clip(mb, 0, n_mb - 1)
            outs = outs.at[slot].set(jnp.where(write, out, outs[slot]))
            buf = jax.lax.ppermute(out, axis, ring)
            return buf, outs

        buf0 = jnp.zeros_like(x_all[0])
        _, outs = jax.lax.fori_loop(
            0, n_mb + n_stages - 1, step, (buf0, jnp.zeros_like(x_all)))
        # results live on the last stage only; psum replicates them
        return jax.lax.psum(
            jnp.where(idx == n_stages - 1, outs, jnp.zeros_like(outs)), axis)

    return shard_map(
        fn, mesh=mesh, in_specs=(P(axis), P()), out_specs=P(),
    )(weights, x)
