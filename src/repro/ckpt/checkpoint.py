"""Sharded checkpointing with elastic re-shard on restore.

Layout: <dir>/step_<n>/manifest.json + one .npy per pytree leaf (keyed by
its tree path). The manifest records step, leaf paths/shapes/dtypes, and the
logical shardings that were in use — restore may target a *different* mesh:
arrays are rebuilt host-side and device_put with the new shardings (elastic
scaling across restarts; tested in tests/test_distributed.py).

Writes are atomic (tmp dir + rename) so a mid-write failure never corrupts
the latest checkpoint — the fault-tolerance contract of runtime/.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile

import jax
import numpy as np


def _leaf_key(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        elif hasattr(p, "name"):
            out.append(str(p.name))
        else:
            out.append(str(p))
    return "__".join(out) or "root"


def save(ckpt_dir: str, step: int, tree, extra: dict | None = None) -> str:
    """Write pytree ``tree`` at ``step``. Returns the checkpoint path."""
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    manifest = {"step": step, "leaves": [], "extra": extra or {}}
    for path, leaf in leaves:
        key = _leaf_key(path)
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, key + ".npy"), arr)
        manifest["leaves"].append(
            {"key": key, "shape": list(arr.shape), "dtype": str(arr.dtype)}
        )
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(m.group(1))
        for d in os.listdir(ckpt_dir)
        if (m := re.fullmatch(r"step_(\d+)", d))
    ]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, tree_like, shardings=None):
    """Rebuild ``tree_like``-structured pytree from disk.

    ``shardings``: optional matching pytree of jax.sharding.Sharding /
    PartitionSpec-resolved shardings — arrays are placed directly onto the
    (possibly different) target mesh: elastic re-shard on restart.
    """
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    by_key = {e["key"]: e for e in manifest["leaves"]}

    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    shard_leaves = (
        jax.tree_util.tree_flatten(shardings)[0] if shardings is not None
        else [None] * len(leaves_with_path)
    )
    out = []
    for (lpath, like), shard in zip(leaves_with_path, shard_leaves):
        key = _leaf_key(lpath)
        if key not in by_key:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = np.load(os.path.join(path, key + ".npy"))
        if shard is not None:
            out.append(jax.device_put(arr, shard))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest
