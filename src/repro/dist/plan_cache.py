"""Mesh-aware plan cache: sharded plans keyed by structure AND decomposition.

A ``ShardedPlan``'s arrays depend on exactly three things: the structural
identity of the multiply (``core.plan_cache.structure_key`` — row pointers,
live columns, bucketed caps, pad policy), the shard count of the mesh axis
it was partitioned over, and the B placement (the concat layout and value
perms differ between ``replicated`` and ``allgather``). ``dist_plan_key``
composes those into one cache key, so repeated structures on the same
decomposition never re-shard or rebuild — and the same structure on a
*different* mesh shape correctly misses.

Storage reuses ``core.plan_cache.PlanCache`` unchanged: the entry-count and
``max_bytes`` LRU bounds apply to sharded plans too (``plan_nbytes`` sums
array leaves generically). The default cache carries a 256 MiB bytes bound —
sharded plans pin S-times-stacked replay maps, so unbounded hoarding costs
memory S times faster than the single-device cache.
"""
from __future__ import annotations

from repro.core.plan_cache import PlanCache

DEFAULT_DIST_CACHE_BYTES = 256 << 20


def dist_plan_key(structure_key: str, num_shards: int,
                  b_placement: str) -> str:
    """Compose the mesh-aware cache key.

    Only the shard count (not device ids or axis name) joins the key: the
    plan's arrays are a pure function of (structure, S, placement), so two
    meshes with the same axis size share one entry — the replay jit retraces
    per concrete mesh, the plan does not rebuild.
    """
    return f"{structure_key}:S{num_shards}:{b_placement}"


_DEFAULT_DIST_CACHE = PlanCache(capacity=16,
                                max_bytes=DEFAULT_DIST_CACHE_BYTES,
                                name="dist")


def default_dist_plan_cache() -> PlanCache:
    """The module-level mesh-aware cache used when none is passed."""
    return _DEFAULT_DIST_CACHE
