"""Rule ``env`` — env-var discipline and import hygiene.

Configuration resolution is confined to two documented call sites
(``runtime/validate.resolve_mode`` and ``obs/trace.resolve_trace_mode``)
so "off means off" stays auditable: grep two functions and you have seen
every knob. And importing a module must never reconfigure the process —
no env mutation, no device enumeration — because import order is not a
contract anyone tests.

Sub-checks:

  * ``env.import-time-mutation`` — ``os.environ[...] = ...`` /
    ``setdefault`` / ``pop`` / ``update`` / ``os.putenv`` executed at
    module import time (outside any function; ``if __name__ == "__main__"``
    blocks are exempt — that's entrypoint code, not import code).
  * ``env.unsanctioned-read`` — ``os.environ[...]`` / ``.get`` /
    ``os.getenv`` outside the two sanctioned resolution functions.
  * ``env.import-time-device-work`` — ``jax.devices()`` /
    ``device_count`` / ``default_backend`` at import time (forces backend
    init as a side effect of ``import``).
"""
from __future__ import annotations

import ast

from repro.analysis.asthelpers import dotted, enclosing_main_guard
from repro.analysis.context import ModuleInfo, Project
from repro.analysis.findings import Finding
from repro.analysis.registry import rule

RULE = "env"

# (module path, function name) pairs allowed to read os.environ
SANCTIONED_READS = frozenset({
    ("runtime/validate.py", "resolve_mode"),
    ("obs/trace.py", "resolve_trace_mode"),
})

_ENV_NAMES = {"os.environ", "environ"}
_MUTATING_METHODS = {"setdefault", "pop", "update", "clear"}
_DEVICE_CALLS = {"jax.devices", "jax.local_devices", "jax.device_count",
                 "jax.local_device_count", "jax.default_backend"}


def _is_env(node: ast.expr) -> bool:
    return dotted(node) in _ENV_NAMES


def _function_lines(tree: ast.Module) -> set[int]:
    """Lines inside any function/lambda body (call-time, not import-time)."""
    lines: set[int] = set()
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
            lines.update(
                range(node.lineno, (node.end_lineno or node.lineno) + 1))
    return lines


def _main_guard_lines(tree: ast.Module) -> set[int]:
    lines: set[int] = set()
    for node in tree.body:
        if isinstance(node, ast.If) and enclosing_main_guard(tree, node):
            lines.update(
                range(node.lineno, (node.end_lineno or node.lineno) + 1))
    return lines


def _env_mutations(mod: ModuleInfo):
    """Yield (lineno, description) for every env mutation in the module."""
    for sub in ast.walk(mod.tree):
        if isinstance(sub, ast.Assign):
            for t in sub.targets:
                if isinstance(t, ast.Subscript) and _is_env(t.value):
                    yield sub.lineno, "os.environ[...] = ..."
        elif isinstance(sub, ast.Delete):
            for t in sub.targets:
                if isinstance(t, ast.Subscript) and _is_env(t.value):
                    yield sub.lineno, "del os.environ[...]"
        elif isinstance(sub, ast.Call):
            name = dotted(sub.func)
            if name == "os.putenv":
                yield sub.lineno, "os.putenv(...)"
            elif isinstance(sub.func, ast.Attribute) \
                    and sub.func.attr in _MUTATING_METHODS \
                    and _is_env(sub.func.value):
                yield sub.lineno, f"os.environ.{sub.func.attr}(...)"


def _env_reads(mod: ModuleInfo):
    for sub in ast.walk(mod.tree):
        if isinstance(sub, ast.Subscript) and _is_env(sub.value) \
                and isinstance(sub.ctx, ast.Load):
            yield sub.lineno
        elif isinstance(sub, ast.Call):
            name = dotted(sub.func)
            if name == "os.getenv":
                yield sub.lineno
            elif isinstance(sub.func, ast.Attribute) \
                    and sub.func.attr == "get" and _is_env(sub.func.value):
                yield sub.lineno


@rule(RULE, "env reads only at the two resolution points; clean imports")
def check(project: Project):
    for mod in project.modules:
        fn_lines = _function_lines(mod.tree)
        guard_lines = _main_guard_lines(mod.tree)
        import_time = lambda ln: ln not in fn_lines and ln not in guard_lines  # noqa: E731

        for lineno, what in _env_mutations(mod):
            if not import_time(lineno):
                continue
            yield Finding(
                rule=RULE, code=f"{RULE}.import-time-mutation",
                path=mod.rel, line=lineno,
                message=(f"{what} at module import time — importing this "
                         f"module reconfigures the process"),
                hint="move it into an explicit helper the entrypoint calls "
                     "(see launch/dryrun.force_host_devices)",
                snippet=mod.snippet(lineno))

        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call) \
                    and dotted(node.func) in _DEVICE_CALLS \
                    and import_time(node.lineno):
                yield Finding(
                    rule=RULE, code=f"{RULE}.import-time-device-work",
                    path=mod.rel, line=node.lineno,
                    message=(f"{dotted(node.func)}() at import time forces "
                             f"backend init as an import side effect"),
                    hint="query devices lazily inside the function that "
                         "needs them",
                    snippet=mod.snippet(node.lineno))

        # --- env reads anywhere outside the sanctioned functions --------
        sanctioned = {fn for (path, fn) in SANCTIONED_READS
                      if path == mod.rel}
        allowed_lines: set[int] = set()
        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node.name in sanctioned:
                allowed_lines.update(
                    range(node.lineno, (node.end_lineno or node.lineno) + 1))
        for lineno in _env_reads(mod):
            if lineno in allowed_lines:
                continue
            yield Finding(
                rule=RULE, code=f"{RULE}.unsanctioned-read",
                path=mod.rel, line=lineno,
                message=("os.environ read outside the two documented "
                         "resolution points"),
                hint="route the knob through runtime.validate.resolve_mode "
                     "or obs.trace.resolve_trace_mode",
                snippet=mod.snippet(lineno))
