"""Shared fixtures. NOTE: no XLA device-count flags here by design — smoke
tests and benches must see 1 CPU device; only launch/dryrun.py (separate
process) forces 512 placeholder devices."""
import jax
import numpy as np
import pytest


@pytest.fixture(autouse=True)
def _seed():
    np.random.seed(0)


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches():
    """Drop compiled-executable references between modules: the full suite
    jits hundreds of programs and XLA-CPU's JIT object space is finite —
    without this the tail of the suite hits 'Failed to materialize symbols'
    resource failures."""
    yield
    jax.clear_caches()
