"""Pallas kernel sweeps (interpret=True) vs the pure-jnp ref.py oracles.

Shapes/dtypes swept per kernel; SpGEMM kernels additionally cross-checked
against the Gustavson numpy oracle.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import bitmask_rows
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.grouped_matmul import TM, grouped_matmul
from repro.kernels.spgemm_numeric import spgemm_numeric
from repro.kernels.spgemm_symbolic import spgemm_symbolic
from repro.kernels.ops import pallas_spgemm
from repro.sparse import (
    gustavson_ell_structure,
    gustavson_numpy,
    random_csr,
    stencil2d_csr,
)
from repro.sparse.formats import csr_to_ell

RNG = np.random.default_rng(0)


def _pad_bitmask(bm):
    pad = (-bm.shape[1]) % 128
    return jnp.pad(bm, ((0, 0), (0, pad))) if pad else bm


@pytest.mark.parametrize("m,n,k,da,db", [
    (16, 24, 150, 3.0, 4.0),
    (32, 32, 700, 2.0, 6.0),
    (8, 64, 4096, 4.0, 2.0),
])
def test_spgemm_symbolic_sweep(m, n, k, da, db):
    a = random_csr(m, n, da, int(da * 10))
    b = random_csr(n, k, db, int(db * 10))
    ell = csr_to_ell(a)
    bm = _pad_bitmask(bitmask_rows(b))
    got = spgemm_symbolic(ell.indices, ell.row_nnz, bm, interpret=True)
    want = ref.spgemm_symbolic_ref(ell.indices, ell.row_nnz, bm)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    ip, _, _, _ = gustavson_numpy(a, b)
    np.testing.assert_array_equal(np.asarray(got), np.diff(ip))


@pytest.mark.parametrize("m,n,k", [(12, 20, 300), (24, 16, 600), (8, 32, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_spgemm_numeric_sweep(m, n, k, dtype):
    a = random_csr(m, n, 3.0, m)
    b = random_csr(n, k, 4.0, n)
    ea, eb = csr_to_ell(a), csr_to_ell(b)
    c_idx, c_nnz = gustavson_ell_structure(a, b)
    got = spgemm_numeric(
        ea.indices, ea.values.astype(dtype), ea.row_nnz, eb.indices,
        eb.values.astype(dtype), jnp.asarray(c_idx), jnp.asarray(c_nnz),
        k=k, interpret=True,
    )
    want = ref.spgemm_numeric_ref(
        ea.indices, ea.values.astype(dtype), eb.indices,
        eb.values.astype(dtype), jnp.asarray(c_idx), jnp.asarray(c_nnz), k,
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4,
                               atol=1e-4)


def test_pallas_spgemm_pipeline():
    a = stencil2d_csr(6, 6)
    b = stencil2d_csr(6, 6)
    c_nnz, c_idx, c_val = pallas_spgemm(a, b)
    ip, ind, val, _ = gustavson_numpy(a, b)
    for i in range(a.m):
        n_i = int(c_nnz[i])
        assert n_i == ip[i + 1] - ip[i]
        np.testing.assert_array_equal(np.asarray(c_idx)[i, :n_i], ind[ip[i]: ip[i + 1]])
        np.testing.assert_allclose(
            np.asarray(c_val)[i, :n_i], val[ip[i]: ip[i + 1]], rtol=1e-4,
            atol=1e-5,
        )


def test_bucketed_kernel_wrappers_match_plain():
    """Width-bucketed wrappers (x2 ELL capacity padding) must be semantically
    identical to the unbucketed kernels — padding is masked, output sliced."""
    from repro.kernels.spgemm_numeric import spgemm_numeric_bucketed
    from repro.kernels.spgemm_symbolic import spgemm_symbolic_bucketed

    a = random_csr(14, 18, 3.0, 5)
    b = random_csr(18, 200, 2.5, 6)
    ell = csr_to_ell(a)
    bm = _pad_bitmask(bitmask_rows(b))
    got = spgemm_symbolic_bucketed(ell.indices, ell.row_nnz, bm,
                                   interpret=True)
    ip, ind, val, _ = gustavson_numpy(a, b)
    np.testing.assert_array_equal(np.asarray(got), np.diff(ip))

    eb = csr_to_ell(b)
    c_idx, c_nnz = gustavson_ell_structure(a, b)
    r_c = c_idx.shape[1]
    got_v = spgemm_numeric_bucketed(
        ell.indices, ell.values, ell.row_nnz, eb.indices, eb.values,
        jnp.asarray(c_idx), jnp.asarray(c_nnz), k=b.k, interpret=True,
    )
    assert got_v.shape == (a.m, r_c)  # sliced back to the caller's width
    want_v = ref.spgemm_numeric_ref(
        ell.indices, ell.values, eb.indices, eb.values,
        jnp.asarray(c_idx), jnp.asarray(c_nnz), b.k,
    )
    np.testing.assert_allclose(np.asarray(got_v), np.asarray(want_v),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("e,d,f,blocks", [(4, 256, 256, 6), (8, 128, 384, 4)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_grouped_matmul_sweep(e, d, f, blocks, dtype):
    t = blocks * TM
    be = jnp.asarray(np.sort(RNG.integers(0, e, blocks)).astype(np.int32))
    x = jnp.asarray(RNG.standard_normal((t, d)), dtype)
    w = jnp.asarray(RNG.standard_normal((e, d, f)) * 0.1, dtype)
    got = grouped_matmul(x, w, be, interpret=True)
    want = ref.grouped_matmul_ref(x, w, jnp.repeat(be, TM))
    tol = 2e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )


@pytest.mark.parametrize("hq,hkv,t,d", [(4, 2, 256, 64), (8, 8, 128, 32),
                                        (4, 1, 256, 64)])
@pytest.mark.parametrize("kwargs", [
    dict(causal=True),
    dict(causal=True, window=64),
    dict(causal=True, softcap=30.0),
    dict(causal=False),
])
def test_flash_attention_sweep(hq, hkv, t, d, kwargs):
    q = jnp.asarray(RNG.standard_normal((hq, t, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((hkv, t, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((hkv, t, d)), jnp.float32)
    got = flash_attention(q, k, v, block_q=64, block_k=64, interpret=True,
                          **kwargs)
    want = ref.flash_attention_ref(q, k, v, **kwargs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3,
                               atol=2e-3)


def test_flash_attention_bf16():
    q = jnp.asarray(RNG.standard_normal((4, 128, 64)), jnp.bfloat16)
    k = jnp.asarray(RNG.standard_normal((2, 128, 64)), jnp.bfloat16)
    v = jnp.asarray(RNG.standard_normal((2, 128, 64)), jnp.bfloat16)
    got = flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=5e-2, atol=5e-2,
    )
