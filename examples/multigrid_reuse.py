"""The paper's headline application scenario: multigrid setup with
structure reuse (§4, Reuse case).

An AMG-style solver recomputes A_coarse = R*A*P every time matrix VALUES
change (nonlinear solves, time stepping) while the STRUCTURE stays fixed.
Two-phase SpGEMM pays symbolic once; from then on a ``ReuseExecutor`` pins
each plan (one structure hash, ever) and replays the numeric phase as a
single jitted dispatch per multiply — or ONE batched dispatch for a whole
ensemble of timesteps (``apply_batched``).

    PYTHONPATH=src python examples/multigrid_reuse.py

The distributed version of this scenario — the same pinned plans sharded
over a device mesh via ``repro.dist.ShardedReuseExecutor`` — lives in
examples/dist_multigrid.py.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ReuseExecutor, spgemm
from repro.sparse import CSR, galerkin_triple


def main():
    r, a, p = galerkin_triple(96, 96, agg_size=4)
    print(f"fine grid: {a.shape[0]} dofs, nnz={int(a.nnz())}")

    # --- setup (NoReuse): symbolic + numeric once, executors pin the plans --
    t0 = time.perf_counter()
    ap = spgemm(a, p, method="sparse")
    rap = spgemm(r, ap.c, method="sparse")
    ex_ap = ReuseExecutor(ap.plan)
    ex_rap = ReuseExecutor(rap.plan)
    jax.block_until_ready(rap.c.values)
    setup_s = time.perf_counter() - t0
    print(f"setup (symbolic+numeric): {setup_s * 1e3:.1f} ms  "
          f"A_coarse nnz={rap.stats['nnz_c']}")

    # --- time stepping: values change, structure fixed (Reuse) -----------
    rng = np.random.default_rng(0)
    reuse_times = []
    for step in range(5):
        new_vals = jnp.asarray(rng.standard_normal(a.nnz_cap), jnp.float32)
        a_t = CSR(a.indptr, a.indices, new_vals, a.shape)
        t0 = time.perf_counter()
        ap_vals = ex_ap.apply(a_t.values, p.values)
        rap_vals = ex_rap.apply(r.values, ap_vals)
        jax.block_until_ready(rap_vals)
        reuse_times.append(time.perf_counter() - t0)
    reuse_ms = float(np.mean(reuse_times[1:])) * 1e3
    print(f"reuse numeric-only per timestep: {reuse_ms:.1f} ms  "
          f"({setup_s * 1e3 / reuse_ms:.1f}x faster than setup)")

    # --- ensemble: a batch of timesteps in ONE dispatch per product ------
    batch = 8
    a_batch = jnp.asarray(
        rng.standard_normal((batch, a.nnz_cap)), jnp.float32)
    jax.block_until_ready(ex_rap.apply_batched(  # warmup (compile)
        jnp.broadcast_to(r.values, (batch, r.nnz_cap)),
        ex_ap.apply_batched(a_batch, p.values)))
    t0 = time.perf_counter()
    ap_b = ex_ap.apply_batched(a_batch, p.values)  # P shared, A batched
    rap_b = ex_rap.apply_batched(
        jnp.broadcast_to(r.values, (batch, r.nnz_cap)), ap_b)
    jax.block_until_ready(rap_b)
    batch_ms = (time.perf_counter() - t0) * 1e3
    print(f"batched reuse, {batch} timesteps in 2 dispatches: "
          f"{batch_ms:.1f} ms total, {batch_ms / batch:.2f} ms/timestep "
          f"({reuse_ms / (batch_ms / batch):.1f}x vs per-call reuse)")

    # validate one reuse iteration against a fresh run
    fresh = spgemm(CSR(a.indptr, a.indices, a_t.values, a.shape), p).c
    nnz = int(fresh.nnz())
    np.testing.assert_allclose(np.asarray(ap_vals)[:nnz],
                               np.asarray(fresh.values)[:nnz],
                               rtol=1e-4, atol=1e-5)
    # and the batch's last member against the per-call replay
    np.testing.assert_allclose(
        np.asarray(ex_ap.apply(a_batch[-1], p.values)),
        np.asarray(ap_b[-1]), rtol=1e-5, atol=1e-6)
    print("reuse + batched results validated. OK")


if __name__ == "__main__":
    main()
