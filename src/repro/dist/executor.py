"""ShardedReuseExecutor: pinned sharded plans replayed as ONE mesh dispatch.

The single-device ``ReuseExecutor`` (core/executor.py) made the paper's
Reuse case cheap to *dispatch*; this is the same contract lifted onto a
mesh. Construction pins a ``ShardedPlan`` (one ``structure_key`` hash, ever
— probed against the mesh-aware plan cache so repeated structures never
re-shard or re-trace) and every ``apply`` is a single jitted dispatch of a
``jax.shard_map``: per shard, two gathers + one sorted segment-sum — the
identical ``numeric_reuse`` replay, just running S-wide.

Value routing is part of the plan, so replays never touch structure:

  * fresh A values enter *global* ``(a_nnz_cap,)`` and are re-sharded by the
    pinned ``a_perm`` gather inside the dispatch;
  * replicated B: values pass through unsharded (zero communication — the
    paper's memory-for-communication trade);
  * allgather B: values are sharded by ``b_shard_perm``, all-gathered inside
    the dispatch, and routed into the concatenated layout by ``b_perm``. The
    *structure* all-gather was hoisted to plan-build time — the per-replay
    collective moves only ``(S, b_cap)`` values, not the CSR triplet.

``apply_batched`` vmaps the per-shard replay over stacked value arrays
``(batch, nnz_cap)`` — one dispatch for the whole batch across the whole
mesh. Replays are bitwise identical to the single-device executor after
``merge_shards``: each shard's products are the same products in the same
sorted order as the corresponding slice of the global plan.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core.distributed import ShardedCSR, merge_shards
from repro.core.executor import DISPATCH_COUNTS
from repro.core.meta import DEFAULT_PAD_POLICY
from repro.core.plan_cache import structure_key
from repro.core.spgemm import (
    SpgemmPlan,
    _note_trace,
    numeric_reuse,
    prepare_sparse_inputs,
)
from repro.dist.plan import B_PLACEMENTS, ShardedPlan, build_sharded_plan
from repro.dist.plan_cache import default_dist_plan_cache, dist_plan_key
from repro.obs import trace as obs_trace
from repro.runtime.validate import (PlanMismatchError, SpgemmConfigError,
                                    SpgemmInputError,
                                    check_csr, resolve_mode)
from repro.sparse.formats import CSR


def _local_plan(ip, ix, seg, asl, bsl, m_loc: int, k: int) -> SpgemmPlan:
    """Strip the leading per-device shard axis -> this shard's SpgemmPlan."""
    return SpgemmPlan(indptr=ip[0], indices=ix[0], seg_ids=seg[0],
                      a_slot_s=asl[0], b_slot_s=bsl[0], shape=(m_loc, k))


@partial(jax.jit, static_argnames=("mesh", "axis", "m_loc", "k", "a_axis", "b_axis"))
def _replay_replicated(ip, ix, seg, asl, bsl, aperm, a_values, b_values,
                       *, mesh, axis, m_loc, k, a_axis, b_axis):
    """One dispatch: per-shard numeric replay with B replicated.

    ``a_axis``/``b_axis`` of ``None`` mean unbatched operands (plain
    ``apply``); 0 means a leading batch axis (``apply_batched``).
    """
    _note_trace("dist_replay")
    batched = a_axis is not None or b_axis is not None

    def fn(ip, ix, seg, asl, bsl, aperm, a_values, b_values):
        plan = _local_plan(ip, ix, seg, asl, bsl, m_loc, k)
        ap = aperm[0]
        if not batched:
            return numeric_reuse(plan, a_values[ap], b_values)[None]
        out = jax.vmap(
            lambda av, bv: numeric_reuse(plan, av[ap], bv),
            in_axes=(a_axis, b_axis),
        )(a_values, b_values)
        return out[None]  # (1, batch, nnz_cap)

    out = shard_map(
        fn, mesh=mesh,
        in_specs=(P(axis),) * 6 + (P(), P()),
        out_specs=P(axis),
    )(ip, ix, seg, asl, bsl, aperm, a_values, b_values)
    return jnp.swapaxes(out, 0, 1) if batched else out


@partial(jax.jit, static_argnames=("mesh", "axis", "m_loc", "k", "a_axis", "b_axis"))
def _replay_allgather(ip, ix, seg, asl, bsl, aperm, bshard, bperm,
                      a_values, b_values, *, mesh, axis, m_loc, k,
                      a_axis, b_axis):
    """One dispatch: shard B values, all-gather them inside the mesh, route
    into the pinned concat layout, replay. Structure never moves."""
    _note_trace("dist_replay")
    batched = a_axis is not None or b_axis is not None
    # shard B values by the pinned map: (S, b_cap) or (batch, S, b_cap)
    b_sh = b_values[..., bshard] if b_axis == 0 else b_values[bshard]
    if b_axis == 0:
        b_sh = jnp.moveaxis(b_sh, 0, 1)  # (S, batch, b_cap): shard axis leads

    def fn(ip, ix, seg, asl, bsl, aperm, bperm, a_values, b_sh):
        plan = _local_plan(ip, ix, seg, asl, bsl, m_loc, k)
        ap = aperm[0]
        gathered = jax.lax.all_gather(b_sh[0], axis)  # (S, [batch,] b_cap)
        if b_axis == 0:
            flat = jnp.moveaxis(gathered, 0, 1).reshape(gathered.shape[1], -1)
            bg = flat[:, bperm]  # (batch, S*b_cap) in concat layout
        else:
            bg = gathered.reshape(-1)[bperm]
        if not batched:
            return numeric_reuse(plan, a_values[ap], bg)[None]
        out = jax.vmap(
            lambda av, bv: numeric_reuse(plan, av[ap], bv),
            in_axes=(a_axis, 0 if b_axis == 0 else None),
        )(a_values, bg)
        return out[None]

    out = shard_map(
        fn, mesh=mesh,
        in_specs=(P(axis),) * 6 + (P(), P(), P(axis)),
        out_specs=P(axis),
    )(ip, ix, seg, asl, bsl, aperm, bperm, a_values, b_sh)
    return jnp.swapaxes(out, 0, 1) if batched else out


class ShardedReuseExecutor:
    """A pinned ``ShardedPlan`` exposed as a mesh replay engine.

    Construction is the only host-side work (partitioning, one structure
    hash, one sharded symbolic pass on a cache miss); from then on every
    ``apply`` / ``apply_batched`` is one jitted ``shard_map`` dispatch —
    zero hashing, zero cache probes, zero retraces for fixed value shapes.
    """

    def __init__(self, plan: ShardedPlan, mesh, *, axis: str = "data",
                 b_placement: str = "replicated",
                 validate: str | None = "off"):
        if b_placement not in B_PLACEMENTS:
            raise SpgemmConfigError(
                f"unknown b_placement {b_placement!r}; expected one of "
                f"{B_PLACEMENTS}")
        if mesh.shape[axis] != plan.num_shards:
            raise PlanMismatchError(
                f"plan has {plan.num_shards} shards but mesh axis "
                f"{axis!r} has {mesh.shape[axis]} devices")
        self.plan = plan
        self.mesh = mesh
        self.axis = axis
        self.b_placement = b_placement
        self.cache_state = "pinned"
        self._merge_perm = None  # built lazily by merge_values
        # validate= mirrors ReuseExecutor: a literal "off" default (the
        # replay hot path must not silently change under $REPRO_VALIDATE);
        # pin-time syncs of two scalars buy O(1) per-replay operand checks
        self.validate_mode = resolve_mode(validate)
        self._a_req = self._b_req = 0
        if self.validate_mode != "off":
            # operand requirements over LIVE products only (padding slots
            # are clamped to build-time caps and dropped by sentinel
            # seg_ids — see runtime.validate.PlanGuard): trace each live
            # product's slot back through the pinned routing perms to the
            # global value slot it actually reads
            seg = np.asarray(plan.seg_ids)  # (S, fm_cap)
            live = seg < plan.nnz_cap
            asl = np.asarray(plan.a_slot_s)
            bsl = np.asarray(plan.b_slot_s)
            aperm = np.asarray(plan.a_perm)  # (S, a_cap): local -> global
            ga = np.take_along_axis(
                aperm, np.minimum(asl, aperm.shape[1] - 1), axis=1)
            self._a_req = int(ga[live].max()) + 1 if live.any() else 0
            if b_placement == "replicated":
                # replicated replay gathers global B values via b_slot_s
                gb = bsl[live]
            else:
                # concat slot -> gathered flat slot -> global value slot
                bperm = np.asarray(plan.b_perm)
                flatshard = np.asarray(plan.b_shard_perm).reshape(-1)
                gb = flatshard[bperm[np.minimum(bsl[live],
                                                len(bperm) - 1)]]
            self._b_req = int(gb.max()) + 1 if gb.size else 0

    def _check_values(self, a_values, b_values, batched: bool) -> None:
        """Per-replay operand check (validate != "off"): global value-buffer
        lengths against the pinned routing perms (``PlanMismatchError``),
        plus a device finiteness sweep in "device" mode."""
        for side, vals, req in (("A", a_values, self._a_req),
                                ("B", b_values, self._b_req)):
            ok_ndim = vals.ndim in (1, 2) if batched else vals.ndim == 1
            if not ok_ndim:
                raise PlanMismatchError(
                    f"{side} values must be "
                    f"{'(batch, nnz) or (nnz,)' if batched else '1-D (nnz,)'}"
                    f" in the flat global layout, got shape "
                    f"{tuple(vals.shape)}")
            if vals.shape[-1] < req:
                raise PlanMismatchError(
                    f"{side} value buffer has {vals.shape[-1]} slots but the "
                    f"pinned sharded plan routes up to slot {req - 1} — "
                    f"replaying against operands from a different structure?")
            if (self.validate_mode == "device"
                    and jnp.issubdtype(vals.dtype, jnp.floating)
                    and not bool(jnp.all(jnp.isfinite(vals)))):
                raise SpgemmInputError(
                    f"{side} values contain NaN/Inf (device validation)")

    @classmethod
    def from_matrices(cls, a: CSR, b: CSR, mesh, *, axis: str = "data",
                      b_placement: str = "replicated",
                      pad_policy: str | None = None,
                      plan_cache=None, validate: str | None = "off",
                      _prepared=None) -> "ShardedReuseExecutor":
        """Build (or fetch from the mesh-aware plan cache) the sharded plan
        for ``a @ b`` and pin it. One structure hash, ever; a cache hit
        skips partitioning, the sharded symbolic pass, and the plan build —
        repeated structures never re-shard.

        ``_prepared``: a caller that already ran ``prepare_sparse_inputs``
        (sharded_spgemm) passes its tuple here to skip the second host-sync
        preamble; the executor keeps no reference to the operands either
        way — replays take fresh values as arguments.
        """
        policy = DEFAULT_PAD_POLICY if pad_policy is None else pad_policy
        vmode = resolve_mode(validate)
        if vmode != "off":
            check_csr(a, vmode, name="A")
            check_csr(b, vmode, name="B")
        if _prepared is None:
            _prepared = prepare_sparse_inputs(a, b, policy)
        a, b, _, _, fm_cap = _prepared
        skey = structure_key(a, b, fm_cap, policy)  # the one hash
        if plan_cache is None:
            cache = default_dist_plan_cache()
        elif plan_cache is False:
            cache = None
        else:
            cache = plan_cache
        key = dist_plan_key(skey, mesh.shape[axis], b_placement)
        plan = cache.get(key) if cache is not None else None
        state = "hit"
        if plan is None:
            plan = build_sharded_plan(a, b, mesh, axis=axis,
                                      b_placement=b_placement,
                                      pad_policy=policy)
            if cache is not None:
                cache.put(key, plan)
                state = "miss"
            else:
                state = "bypass"
        ex = cls(plan, mesh, axis=axis, b_placement=b_placement,
                 validate=vmode)
        ex.cache_state = state
        return ex

    @property
    def shape(self) -> tuple:
        return tuple(self.plan.shape)

    @property
    def num_shards(self) -> int:
        return self.plan.num_shards

    @property
    def nnz_cap(self) -> int:
        return self.plan.nnz_cap

    def _replay(self, a_values, b_values, a_axis, b_axis):
        p = self.plan
        kwargs = dict(mesh=self.mesh, axis=self.axis, m_loc=p.m_loc,
                      k=self.shape[1], a_axis=a_axis, b_axis=b_axis)
        if self.b_placement == "replicated":
            return _replay_replicated(p.indptr, p.indices, p.seg_ids,
                                      p.a_slot_s, p.b_slot_s, p.a_perm,
                                      a_values, b_values, **kwargs)
        return _replay_allgather(p.indptr, p.indices, p.seg_ids,
                                 p.a_slot_s, p.b_slot_s, p.a_perm,
                                 p.b_shard_perm, p.b_perm,
                                 a_values, b_values, **kwargs)

    def apply(self, a_values: jax.Array, b_values: jax.Array) -> jax.Array:
        """Replay on new *global* operand values -> (S, nnz_cap) C values.

        Operand values use the same flat global layout as the single-device
        executor (the pinned perms re-shard them inside the dispatch), so a
        serving loop can switch meshes without reshaping its buffers.
        """
        DISPATCH_COUNTS["dist_apply"] += 1
        if self.validate_mode != "off":
            self._check_values(a_values, b_values, batched=False)
        with obs_trace.span("dist.replay", placement=self.b_placement,
                            shards=self.num_shards):
            return self._replay(a_values, b_values, None, None)

    def apply_batched(self, a_values: jax.Array,
                      b_values: jax.Array) -> jax.Array:
        """Replay stacked values in ONE dispatch -> (batch, S, nnz_cap).

        Either operand may be stacked ``(batch, operand_nnz_cap)`` or shared
        unbatched ``(operand_nnz_cap,)``; at least one must be stacked.
        """
        DISPATCH_COUNTS["dist_apply_batched"] += 1
        a_axis = 0 if a_values.ndim == 2 else None
        b_axis = 0 if b_values.ndim == 2 else None
        if a_axis is None and b_axis is None:
            raise SpgemmConfigError(
                "apply_batched needs at least one stacked (batch, nnz) "
                "operand; use apply() for a single replay")
        if self.validate_mode != "off":
            self._check_values(a_values, b_values, batched=True)
        with obs_trace.span("dist.replay", placement=self.b_placement,
                            shards=self.num_shards,
                            batch=(a_values.shape[0] if a_axis == 0
                                   else b_values.shape[0])):
            return self._replay(a_values, b_values, a_axis, b_axis)

    def to_sharded_csr(self, values: jax.Array) -> ShardedCSR:
        """Wrap one replay's (S, nnz_cap) values in the plan's C structure."""
        want = (self.num_shards, self.nnz_cap)
        if tuple(values.shape) != want:
            raise PlanMismatchError(
                f"expected ONE replay's (S, nnz_cap)={want} values, got "
                f"{tuple(values.shape)}; apply_batched output carries a "
                f"leading batch axis — index a batch element first")
        return ShardedCSR(indptr=self.plan.indptr, indices=self.plan.indices,
                          values=values, shape=self.shape)

    def merge(self, values: jax.Array) -> CSR:
        """Host-side: merge one replay's (S, nnz_cap) values into global C."""
        return merge_shards(self.to_sharded_csr(values), self.shape[0])

    def merge_values(self, values: jax.Array) -> jax.Array:
        """Device-side merge: one replay's (S, nnz_cap) values -> the flat
        global value layout of ``merge(...)`` (live slots, row-major).

        One jittable gather through a perm pinned on first use — the
        serving-loop alternative to ``merge`` when only *values* must reach
        the global layout (e.g. feeding the next pinned multiply of a
        V-cycle): no host transfer, no per-shard numpy concat.
        """
        want = (self.num_shards, self.nnz_cap)
        if tuple(values.shape) != want:
            raise PlanMismatchError(
                f"merge_values takes one replay's (S, nnz_cap)={want} "
                f"values, got {tuple(values.shape)}; index a batch element "
                f"of apply_batched output first")
        if self._merge_perm is None:
            ip = np.asarray(self.plan.indptr)
            m, m_loc = self.shape[0], self.plan.m_loc
            perm = []
            for s in range(self.num_shards):
                rows = min(m_loc, max(m - s * m_loc, 0))
                nnz_s = int(ip[s, rows]) if rows else 0
                perm.append(s * self.nnz_cap + np.arange(nnz_s, dtype=np.int64))
            self._merge_perm = jnp.asarray(
                np.concatenate(perm) if perm else np.zeros(0, np.int64),
                jnp.int32)
        return values.reshape(-1)[self._merge_perm]


def sharded_spgemm(a: CSR, b: CSR, mesh, *, axis: str = "data",
                   b_placement: str = "replicated",
                   pad_policy: str | None = None, plan_cache=None):
    """One sharded multiply through the pinned-plan machinery.

    The mesh entry point behind ``spgemm(..., mesh=...)``: resolves (or
    builds) the sharded plan via the mesh-aware cache, replays once, merges.
    Returns a ``SpgemmResult`` whose ``plan`` is the ``ShardedPlan`` — hand
    it to ``ShardedReuseExecutor`` to keep replaying without re-hashing.
    """
    from repro.core.spgemm import SpgemmResult

    policy = DEFAULT_PAD_POLICY if pad_policy is None else pad_policy
    prepared = prepare_sparse_inputs(a, b, policy)
    a, b, fm, maxrf, fm_cap = prepared
    ex = ShardedReuseExecutor.from_matrices(
        a, b, mesh, axis=axis, b_placement=b_placement, pad_policy=policy,
        plan_cache=plan_cache, _prepared=prepared)
    values = ex.apply(a.values, b.values)
    c = ex.merge(values)
    stats = {
        "method": "sparse",
        "pad_policy": policy,
        "fm": fm,
        "maxrf": maxrf,
        "fm_cap": fm_cap,
        "cache": ex.cache_state,
        "mesh_shape": tuple(mesh.devices.shape),
        "mesh_axis": axis,
        "num_shards": ex.num_shards,
        "b_placement": b_placement,
        "nnz_c": int(c.indptr[-1]),
        "nnz_cap": ex.nnz_cap,
    }
    return SpgemmResult(c=c, plan=ex.plan, stats=stats)
