"""Per-arch smoke tests (reduced configs): forward/train-step shapes, no
NaNs, decode==forward equivalence, cache machinery."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.data import make_labels
from repro.models import (
    NO_SHARDING,
    decode_step,
    forward,
    init_cache,
    init_params,
)
from repro.train import AdamWConfig, adamw_init, make_train_step

B, T = 2, 32


def _batch(cfg, rng, t=T):
    out = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, t)),
                                 jnp.int32)}
    if cfg.frontend == "vision":
        out["patches"] = jnp.asarray(
            rng.standard_normal((B, cfg.num_patches, cfg.frontend_dim)),
            jnp.float32)
    if cfg.frontend == "audio":
        out = {"frames": jnp.asarray(
            rng.standard_normal((B, t, cfg.frontend_dim)), jnp.float32)}
    return out


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_smoke(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    logits, _ = forward(params, _batch(cfg, rng), cfg, NO_SHARDING,
                        remat=False)
    assert logits.shape == (B, T, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    rng = np.random.default_rng(2)
    batch = _batch(cfg, rng)
    batch["labels"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T)),
                                  jnp.int32)
    step = make_train_step(cfg, NO_SHARDING, AdamWConfig(lr=1e-3))
    params2, opt2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(opt2.step) == 1
    # params actually moved
    delta = jax.tree.reduce(
        lambda acc, x: acc + float(jnp.sum(jnp.abs(x))),
        jax.tree.map(lambda a, b: a - b, params, params2), 0.0,
    )
    assert delta > 0


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if get_config(a, smoke=True).causal])
def test_decode_matches_forward(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(3)
    t = 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, t)), jnp.int32)
    full, _ = forward(params, {"tokens": toks}, cfg, NO_SHARDING, remat=False)
    cache = init_cache(cfg, B, max_len=t, dtype=jnp.float32)
    outs = []
    for i in range(t):
        lg, cache = decode_step(params, cache, toks[:, i:i + 1], jnp.int32(i),
                                cfg, NO_SHARDING, max_len=t)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    err = float(jnp.max(jnp.abs(dec.astype(jnp.float32)
                                - full.astype(jnp.float32))))
    assert err < 0.15, err  # bf16 compute tolerance (MoE: capacity noise)


def test_gemma2_ring_buffer_beyond_window():
    """Decode past the local window: ring cache must equal a full cache."""
    cfg = get_config("gemma2-9b", smoke=True)  # window 16
    params = init_params(cfg, jax.random.PRNGKey(2))
    rng = np.random.default_rng(4)
    t = 3 * cfg.window  # 48 tokens >> window
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, t)), jnp.int32)
    full, _ = forward(params, {"tokens": toks}, cfg, NO_SHARDING, remat=False)
    cache = init_cache(cfg, B, max_len=t, dtype=jnp.float32)
    outs = []
    for i in range(t):
        lg, cache = decode_step(params, cache, toks[:, i:i + 1], jnp.int32(i),
                                cfg, NO_SHARDING, max_len=t)
        outs.append(lg[:, 0])
    # ring (local) cache is min(t, window): check shape contract
    local_cache = cache["blocks"][0]
    assert local_cache.k.shape[2] == cfg.window
    dec = jnp.stack(outs, axis=1)
    err = float(jnp.max(jnp.abs(dec.astype(jnp.float32)
                                - full.astype(jnp.float32))))
    assert err < 0.15, err


def test_param_counts_match_template():
    """param_count() estimate vs actual initialized parameters (full cfgs
    use the template without allocation via shapes only)."""
    from repro.models import model_template
    from repro.models.model import _is_template_leaf

    for arch in ARCH_IDS:
        cfg = get_config(arch)
        t = model_template(cfg)
        leaves = jax.tree.flatten(t, is_leaf=_is_template_leaf)[0]
        total = 0
        for shape, _ in leaves:
            n = 1
            for d in shape:
                n *= d
            total += n
        est = cfg.param_count()
        assert abs(total - est) / est < 0.12, (arch, total, est)


def test_make_labels_audio():
    batch = {"frames": np.random.randn(2, 8, 16).astype(np.float32)}
    out = make_labels(batch)
    assert out["labels"].shape == (2, 8)
    assert out["labels"].min() >= 0 and out["labels"].max() < 504
