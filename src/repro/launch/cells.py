"""Dry-run cell construction: (arch x shape x mesh) -> lowerable closure.

``input_specs`` provides ShapeDtypeStruct stand-ins for every model input
(weak-type-correct, shardable, no device allocation). ``build_cell`` returns
the jitted function + abstract args + shardings for ``.lower()``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.mesh import dp_size, rules_for_mesh
from repro.models import (
    cache_shardings,
    cache_template,
    decode_step,
    forward,
    param_shardings,
    param_specs,
)
from repro.models.sharding import ShardingRules
from repro.train.optim import AdamWConfig, OptState, zero1_shardings
from repro.train.step import train_step


@dataclasses.dataclass
class Cell:
    arch: str
    shape: ShapeConfig
    fn: Callable
    args: tuple  # ShapeDtypeStruct pytrees
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple = ()

    def lower(self):
        jitted = jax.jit(
            self.fn,
            in_shardings=self.in_shardings,
            out_shardings=self.out_shardings,
            donate_argnums=self.donate_argnums,
        )
        return jitted.lower(*self.args)


def _named(mesh, tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, *, with_labels: bool):
    """ShapeDtypeStruct stand-ins + PartitionSpecs for one batch."""
    gb, t = shape.global_batch, shape.seq_len
    specs: dict[str, Any] = {}
    shards: dict[str, Any] = {}
    if cfg.frontend == "audio":
        specs["frames"] = jax.ShapeDtypeStruct((gb, t, cfg.frontend_dim), jnp.float32)
        shards["frames"] = P("__dp__", None, None)
    else:
        specs["tokens"] = jax.ShapeDtypeStruct((gb, t), jnp.int32)
        shards["tokens"] = P("__dp__", None)
        if cfg.frontend == "vision":
            specs["patches"] = jax.ShapeDtypeStruct(
                (gb, cfg.num_patches, cfg.frontend_dim), jnp.float32
            )
            shards["patches"] = P("__dp__", None, None)
    if with_labels:
        specs["labels"] = jax.ShapeDtypeStruct((gb, t), jnp.int32)
        shards["labels"] = P("__dp__", None)
    return specs, shards


def _resolve_dp(tree, dp, gb: int, dp_total: int):
    """Replace the '__dp__' placeholder; drop it if batch doesn't divide."""
    use = dp if gb % dp_total == 0 else None

    def fix(spec):
        return P(*[use if d == "__dp__" else d for d in spec])

    return jax.tree.map(fix, tree, is_leaf=lambda x: isinstance(x, P))


def input_specs(arch: str, shape_name: str):
    """Public deliverable: abstract input stand-ins for an (arch, shape)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    with_labels = shape.kind == "train"
    specs, _ = batch_specs(cfg, shape, with_labels=with_labels)
    return specs


def build_cell(arch: str, shape_name: str, mesh, *, smoke: bool = False) -> Cell:
    cfg = get_config(arch, smoke=smoke)
    shape = SHAPES[shape_name]
    rules = rules_for_mesh(mesh)
    dp = rules.dp
    dp_total = dp_size(mesh)
    gb, t = shape.global_batch, shape.seq_len

    if shape.kind == "train":
        p_dtype = jnp.float32
        p_specs = param_specs(cfg, rules, dtype=p_dtype)
        p_shard = param_shardings(cfg, rules)
        opt_specs = OptState(
            mu=jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), p_specs
            ),
            nu=jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), p_specs
            ),
            step=jax.ShapeDtypeStruct((), jnp.int32),
        )
        opt_shard = OptState(
            mu=zero1_shardings(p_shard, rules.dp_axes, dict(mesh.shape), p_specs),
            nu=zero1_shardings(p_shard, rules.dp_axes, dict(mesh.shape), p_specs),
            step=P(),
        )
        b_specs, b_shard = batch_specs(cfg, shape, with_labels=True)
        b_shard = _resolve_dp(b_shard, dp, gb, dp_total)
        opt_cfg = AdamWConfig()
        # Microbatching keeps the per-step working set under HBM: MoE carries
        # big routing/dispatch buffers; SSD materializes chunk decay blocks;
        # qwen2's replicated-attention fallback keeps full-T q/kv per shard.
        num_microbatches = {"moe": 4, "ssm": 4}.get(cfg.family, 1)
        if cfg.num_heads % rules.tp_size:
            num_microbatches = max(num_microbatches, 2)

        def fn(params, opt_state, batch):
            return train_step(
                params, opt_state, batch, cfg, rules, opt_cfg, mesh=mesh,
                num_microbatches=num_microbatches,
            )

        metrics_shard = {"grad_norm": P(), "lr": P(), "loss": P()}
        return Cell(
            arch=arch, shape=shape, fn=fn,
            args=(p_specs, opt_specs, b_specs),
            in_shardings=_named(mesh, (p_shard, opt_shard, b_shard)),
            out_shardings=_named(mesh, (p_shard, opt_shard, metrics_shard)),
            donate_argnums=(0, 1),
        )

    if shape.kind == "prefill":
        p_specs = param_specs(cfg, rules, dtype=jnp.bfloat16)
        p_shard = param_shardings(cfg, rules)
        b_specs, b_shard = batch_specs(cfg, shape, with_labels=False)
        b_shard = _resolve_dp(b_shard, dp, gb, dp_total)
        return_caches = cfg.causal  # encoder has no serving cache

        def fn(params, batch):
            logits, caches = forward(
                params, batch, cfg, rules, mesh=mesh,
                return_caches=return_caches, remat=False, max_len=t,
            )
            return logits, caches

        return Cell(
            arch=arch, shape=shape, fn=fn,
            args=(p_specs, b_specs),
            in_shardings=_named(mesh, (p_shard, b_shard)),
            out_shardings=None,
        )

    # decode
    long_ctx = gb % dp_total != 0
    rules = dataclasses.replace(rules, decode=True, long_context=long_ctx)
    p_specs = param_specs(cfg, rules, dtype=jnp.bfloat16)
    p_shard = param_shardings(cfg, rules)
    c_specs = cache_template(cfg, gb, max_len=t, dtype=jnp.bfloat16)
    c_shard = cache_shardings(cfg, rules, gb, t, long_context=long_ctx)
    tok = jax.ShapeDtypeStruct((gb, 1), jnp.int32)
    tok_shard = P(dp if gb % dp_total == 0 else None, None)
    pos = jax.ShapeDtypeStruct((), jnp.int32)

    def fn(params, caches, tokens, position):
        return decode_step(
            params, caches, tokens, position, cfg, rules, mesh=mesh, max_len=t
        )

    logits_shard = P(dp if gb % dp_total == 0 else None, None, None)
    return Cell(
        arch=arch, shape=shape, fn=fn,
        args=(p_specs, c_specs, tok, pos),
        in_shardings=_named(mesh, (p_shard, c_shard, tok_shard, P())),
        out_shardings=_named(mesh, (logits_shard, c_shard)),
        donate_argnums=(1,),
    )
