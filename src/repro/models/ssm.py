"""Mamba2 block: SSD (state-space duality) with chunked scan.

Faithful to arXiv:2405.21060's minimal SSD: within-chunk attention-like
block (decay-masked) + across-chunk state recurrence, expressed as a
lax.scan over chunks so peak memory is one (B, H, Q, Q) decay block.
Decode is the O(1) state update — the reason mamba2 runs the long_500k cell.

Projections are kept separate per component (z / x / BC / dt) so each can
carry its own TP sharding (heads over 'model'; BC replicated — it is shared
across heads, G=1) with no sharded-dim slicing.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import rms_norm
from repro.models.sharding import ShardingRules


class SSMCache(NamedTuple):
    state: jax.Array  # (B, H, P, N) f32
    conv_x: jax.Array  # (B, conv_w - 1, d_in)
    conv_bc: jax.Array  # (B, conv_w - 1, 2N)


def _dims(cfg: ModelConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    n_heads = d_in // cfg.ssm_head_dim
    return d_in, n_heads


def ssm_params_template(cfg: ModelConfig):
    d = cfg.d_model
    d_in, n_heads = _dims(cfg)
    n = cfg.ssm_state
    k = cfg.conv_width
    return {
        "in_z": ((d, d_in), "ffn_in"),
        "in_x": ((d, d_in), "ffn_in"),
        "in_bc": ((d, 2 * n), "norm"),
        "in_dt": ((d, n_heads), "norm"),
        "conv_x_w": ((k, d_in), "conv_ch"),
        "conv_x_b": ((d_in,), "conv_ch1"),
        "conv_bc_w": ((k, 2 * n), "norm"),
        "conv_bc_b": ((2 * n,), "norm"),
        "a_log": ((n_heads,), "norm"),
        "d_skip": ((n_heads,), "norm"),
        "dt_bias": ((n_heads,), "norm"),
        "gate_norm": ((d_in,), "conv_ch1"),
        "out_proj": ((d_in, d), "ffn_out"),
        "norm": ((d,), "norm"),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: (B, T, C); w: (K, C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(
        xp[:, i : i + x.shape[1]] * w[i][None, None, :] for i in range(k)
    )
    return out + b[None, None, :]


def _conv_step(window, w, b):
    """window: (B, K, C) -> (B, 1, C)."""
    out = jnp.einsum(
        "bkc,kc->bc", window.astype(jnp.float32), w.astype(jnp.float32)
    ) + b.astype(jnp.float32)
    return out[:, None, :]


def ssm_layer(p, x, cfg: ModelConfig, rules: ShardingRules, *,
              cache: SSMCache | None = None, return_cache: bool = False):
    """Pre-norm Mamba2 block. x: (B, T, d). Returns (delta, new_cache|None).

    cache given => decode (T == 1, O(1) state update). return_cache on the
    full-sequence path hands back the final state (prefill -> decode).
    """
    d_in, n_heads = _dims(cfg)
    n = cfg.ssm_state
    hd = cfg.ssm_head_dim
    b_sz, t, _ = x.shape

    h = rms_norm(x, p["norm"], cfg.norm_eps)
    z = h @ p["in_z"].astype(h.dtype)  # (B, T, d_in) gate branch
    xs = h @ p["in_x"].astype(h.dtype)  # (B, T, d_in)
    bc = h @ p["in_bc"].astype(h.dtype)  # (B, T, 2N)
    dt_raw = h @ p["in_dt"].astype(h.dtype)  # (B, T, H)
    # Pin head-TP on the SSD internals (§Perf: GSPMD otherwise propagates
    # the residual's seq-sharding and runs the whole SSD model-replicated).
    if rules.enabled and rules.tp_axis and not rules.decode:
        from jax.sharding import PartitionSpec as P

        tp_d = rules._tp_if(d_in)
        tp_h = rules._tp_if(n_heads)
        z = rules.constraint(z, P(rules.dp, None, tp_d))
        xs = rules.constraint(xs, P(rules.dp, None, tp_d))
        bc = rules.constraint(bc, P(rules.dp, None, None))
        dt_raw = rules.constraint(dt_raw, P(rules.dp, None, tp_h))

    new_cache = None
    if cache is None:
        xs_c = _causal_conv(xs, p["conv_x_w"].astype(xs.dtype),
                            p["conv_x_b"].astype(xs.dtype))
        bc_c = _causal_conv(bc, p["conv_bc_w"].astype(bc.dtype),
                            p["conv_bc_b"].astype(bc.dtype))
    else:
        win_x = jnp.concatenate([cache.conv_x, xs], axis=1)
        win_bc = jnp.concatenate([cache.conv_bc, bc], axis=1)
        xs_c = _conv_step(win_x, p["conv_x_w"], p["conv_x_b"]).astype(xs.dtype)
        bc_c = _conv_step(win_bc, p["conv_bc_w"], p["conv_bc_b"]).astype(bc.dtype)
    xs_c = jax.nn.silu(xs_c)
    bc_c = jax.nn.silu(bc_c)
    b_in, c_out = jnp.split(bc_c, [n], axis=-1)  # (B, T, N) each
    xh = xs_c.reshape(b_sz, t, n_heads, hd)

    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    )  # (B, T, H)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))  # (H,)
    da = dt * a[None, None, :]  # (B, T, H) — log-decay per step
    dx = xh.astype(jnp.float32) * dt[..., None]  # dt-scaled input

    if cache is None:
        if rules.enabled and rules.tp_axis and not rules.decode:
            from jax.sharding import PartitionSpec as P

            tp_h = rules._tp_if(n_heads)
            dx = rules.constraint(dx, P(rules.dp, None, tp_h, None))
            da = rules.constraint(da, P(rules.dp, None, tp_h))
        y, final_state = _ssd_chunked(
            dx, da, b_in.astype(jnp.float32), c_out.astype(jnp.float32),
            chunk=min(cfg.ssm_chunk, t),
        )
        if return_cache:
            kw = cfg.conv_width - 1
            new_cache = SSMCache(
                state=final_state, conv_x=xs[:, -kw:], conv_bc=bc[:, -kw:]
            )
    else:
        # decode: S = exp(da) * S + dx (x) b ;  y = C . S
        s = cache.state  # (B, H, P, N)
        decay = jnp.exp(da[:, 0])  # (B, H)
        s = s * decay[:, :, None, None] + jnp.einsum(
            "bhp,bn->bhpn", dx[:, 0], b_in[:, 0].astype(jnp.float32)
        )
        y = jnp.einsum("bhpn,bn->bhp", s, c_out[:, 0].astype(jnp.float32))
        y = y[:, None]  # (B, 1, H, P)
        new_cache = SSMCache(state=s, conv_x=win_x[:, 1:], conv_bc=win_bc[:, 1:])

    y = y + xh.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(b_sz, t, d_in)
    # gated RMSNorm then out projection
    y = rms_norm(y.astype(x.dtype), p["gate_norm"], cfg.norm_eps)
    y = y * jax.nn.silu(z.astype(y.dtype))
    delta = y @ p["out_proj"].astype(y.dtype)
    return delta, new_cache


def _ssd_chunked(dx, da, b_in, c_out, chunk: int):
    """Minimal SSD: dx (B,T,H,P), da (B,T,H), b/c (B,T,N).

    Returns (y (B,T,H,P) f32, final state (B,H,P,N)).
    """
    b_sz, t, n_heads, hd = dx.shape
    n = b_in.shape[-1]
    pad = (-t) % chunk
    if pad:
        dx = jnp.pad(dx, ((0, 0), (0, pad), (0, 0), (0, 0)))
        da = jnp.pad(da, ((0, 0), (0, pad), (0, 0)))
        b_in = jnp.pad(b_in, ((0, 0), (0, pad), (0, 0)))
        c_out = jnp.pad(c_out, ((0, 0), (0, pad), (0, 0)))
    tp = t + pad
    nc = tp // chunk
    dxc = dx.reshape(b_sz, nc, chunk, n_heads, hd).transpose(1, 0, 2, 3, 4)
    dac = da.reshape(b_sz, nc, chunk, n_heads).transpose(1, 0, 2, 3)
    bc = b_in.reshape(b_sz, nc, chunk, n).transpose(1, 0, 2, 3)
    cc = c_out.reshape(b_sz, nc, chunk, n).transpose(1, 0, 2, 3)

    def step(state, inp):
        dxq, daq, bq, cq = inp  # (B,Q,H,P), (B,Q,H), (B,Q,N), (B,Q,N)
        da_cs = jnp.cumsum(daq, axis=1)  # (B,Q,H)
        # intra-chunk: L[l,s] = exp(da_cs[l] - da_cs[s]) for l >= s
        ldiff = da_cs[:, :, None, :] - da_cs[:, None, :, :]  # (B,Q,Q,H)
        tri = jnp.tril(jnp.ones((chunk, chunk), jnp.bool_))
        l_mat = jnp.where(tri[None, :, :, None], jnp.exp(ldiff), 0.0)
        scores = jnp.einsum("bln,bsn->bls", cq, bq)  # (B,Q,Q)
        y_diag = jnp.einsum("bls,blsh,bshp->blhp", scores, l_mat, dxq)
        # contribution of incoming state
        state_decay = jnp.exp(da_cs)  # (B,Q,H)
        y_off = jnp.einsum("bln,bhpn,blh->blhp", cq, state, state_decay)
        # update state
        chunk_decay = jnp.exp(da_cs[:, -1, :])  # (B,H)
        in_decay = jnp.exp(da_cs[:, -1:, :] - da_cs)  # (B,Q,H)
        state = state * chunk_decay[:, :, None, None] + jnp.einsum(
            "bsn,bsh,bshp->bhpn", bq, in_decay, dxq
        )
        return state, y_diag + y_off

    state0 = jnp.zeros((b_sz, n_heads, hd, n), jnp.float32)
    final_state, ys = jax.lax.scan(step, state0, (dxc, dac, bc, cc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b_sz, tp, n_heads, hd)
    return y[:, :t], final_state
