from repro.data.pipeline import SyntheticLMDataset, TokenFileDataset, make_labels

__all__ = ["SyntheticLMDataset", "TokenFileDataset", "make_labels"]
