"""The paper's primary contribution: performance-portable two-phase SpGEMM.

Public API:
    spgemm            — full meta-algorithm driver (KKSPGEMM)
    symbolic          — phase 1 (row sizes; compression-aware)
    numeric_fresh     — phase 2, first run (structure + values + reuse plan)
    numeric_reuse     — phase 2, Reuse case (new values, same structure)
    compress_matrix   — §3.2 bit compression
    distributed_spgemm — 1-D row-wise SpGEMM over a device mesh
"""
from repro.core.spgemm import (
    SpgemmPlan,
    SpgemmResult,
    expand_products,
    host_fm_cap,
    numeric_dense_acc,
    numeric_fresh,
    numeric_reuse,
    spgemm,
    symbolic,
    symbolic_compressed,
    symbolic_dense_bitmask,
    symbolic_plain,
)
from repro.core.compression import (
    COMPRESSION_CF_CUTOFF,
    CompressedMatrix,
    bitmask_rows,
    compress_matrix,
    compression_decision,
    flops_stats,
)
from repro.core.meta import (
    AVG_ROW_FLOPS_CUTOFF,
    DENSE_K_CUTOFF,
    choose_kernel,
    choose_method,
    estimate_ars,
)
from repro.core.distributed import (
    ShardedCSR,
    concat_csr_shards,
    dist_numeric,
    dist_symbolic,
    distributed_spgemm,
    merge_shards,
    partition_rows,
)
from repro.core.memory_pool import PoolConfig, acquire_release_sim, chunk_for_step, size_pool

__all__ = [
    "SpgemmPlan",
    "SpgemmResult",
    "expand_products",
    "host_fm_cap",
    "numeric_dense_acc",
    "numeric_fresh",
    "numeric_reuse",
    "spgemm",
    "symbolic",
    "symbolic_compressed",
    "symbolic_dense_bitmask",
    "symbolic_plain",
    "COMPRESSION_CF_CUTOFF",
    "CompressedMatrix",
    "bitmask_rows",
    "compress_matrix",
    "compression_decision",
    "flops_stats",
    "AVG_ROW_FLOPS_CUTOFF",
    "DENSE_K_CUTOFF",
    "choose_kernel",
    "choose_method",
    "estimate_ars",
    "ShardedCSR",
    "concat_csr_shards",
    "dist_numeric",
    "dist_symbolic",
    "distributed_spgemm",
    "merge_shards",
    "partition_rows",
    "PoolConfig",
    "acquire_release_sim",
    "chunk_for_step",
    "size_pool",
]
