"""Fault-tolerance runtime: heartbeats + straggler watchdog.

On a 1000+-node cluster the failure model is: (a) hard node loss — the
runner reschedules, the trainer resumes from the latest atomic checkpoint
with exact data skip-ahead; (b) stragglers — a step exceeding the deadline
flags the node; the policy (checkpoint-and-requeue) avoids dragging the
whole synchronous step at the slowest node's pace.

These are host-side utilities (no device code): ``Heartbeat`` writes a
liveness file the cluster runner monitors; ``StepWatchdog`` wraps each step
(training steps in ``launch/train.py``, pinned-plan replays via
``ReuseExecutor(watchdog=...)``) and triggers the straggler policy.
Deadline math uses ``time.monotonic()`` — wall-clock jumps (NTP slew,
suspend/resume) must not fire or mask a deadline.
"""
from __future__ import annotations

import contextlib
import json
import os
import threading
import time


class Heartbeat:
    """Background thread writing {step, time} to a liveness file.

    Write failures (disk full, unlinked directory) must not kill the beat:
    the whole point of a liveness file is surviving a degraded node long
    enough to report it. Each failed write is counted on ``write_errors``
    and the thread keeps beating; ``stop()`` returns the final count so the
    caller can surface persistent failures.
    """

    def __init__(self, path: str, interval_s: float = 10.0):
        self.path = path
        self.interval_s = interval_s
        self.step = 0
        self.write_errors = 0
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    def start(self):
        # Live visibility (PR 9): a dying disk should show up in a metrics
        # scrape mid-run, not only at stop(). The gauge reads the counter
        # through a callback, so every export sees the current value.
        from repro.obs import metrics  # lazy: keep runtime import-light

        metrics.default_registry().gauge(
            "heartbeat.write_errors", fn=lambda: self.write_errors)
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()
        return self

    def _run(self):
        while not self._stop.is_set():
            tmp = self.path + ".tmp"
            try:
                with open(tmp, "w") as f:
                    json.dump({"step": self.step, "time": time.time()}, f)
                os.replace(tmp, self.path)
            except OSError:
                self.write_errors += 1
            self._stop.wait(self.interval_s)

    def stop(self) -> int:
        """Stop the beat; returns the number of failed liveness writes."""
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=2 * self.interval_s)
        return self.write_errors


class StragglerDetected(RuntimeError):
    pass


class StepWatchdog:
    """Flags steps that exceed a deadline (straggler mitigation hook).

    policy="raise"  -> raise StragglerDetected (caller checkpoints + exits
                       for reschedule; the default requeue-style policy)
    policy="warn"   -> print and continue (collect telemetry)

    A step body that raises is still timed and recorded in ``slow_steps``
    (the body's exception propagates — a slow *failing* step must not be
    masked by a second exception from the watchdog, so ``policy="raise"``
    only fires when the body completed).
    """

    def __init__(self, deadline_s: float = 300.0, policy: str = "warn"):
        self.deadline_s = deadline_s
        self.policy = policy
        self.slow_steps: list[tuple[int, float]] = []

    @contextlib.contextmanager
    def step(self, step_idx: int):
        t0 = time.monotonic()
        ok = False
        try:
            yield
            ok = True
        finally:
            dt = time.monotonic() - t0
            if dt > self.deadline_s:
                self.slow_steps.append((step_idx, dt))
                msg = (f"step {step_idx} took {dt:.1f}s "
                       f"(deadline {self.deadline_s:.1f}s)")
                if self.policy == "raise" and ok:
                    raise StragglerDetected(msg)
                print("WATCHDOG:", msg)
