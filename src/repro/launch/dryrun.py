"""Multi-pod dry-run: .lower().compile() every (arch x shape x mesh) cell.

Run as a module entry point (``python -m repro.launch.dryrun``): the
``__main__`` block calls :func:`force_host_devices` before ``main()`` so the
XLA host-device flag is set before the first backend touch. Importing this
module never reconfigures XLA — library callers who want the fake-device
mesh must call :func:`force_host_devices` themselves, explicitly, before
any jax device work.

Per cell: prints memory_analysis() (proves it fits) and cost_analysis()
(FLOPs/bytes for the roofline), extracts collective bytes from the compiled
HLO, and appends a JSON record consumed by EXPERIMENTS.md tooling.
"""
import argparse
import json
import os
import time
import traceback

import jax

from repro.compat import use_mesh
from repro.configs import SHAPES, all_cells, get_config, skip_reason
from repro.launch.cells import build_cell
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import analyze


def force_host_devices(n: int = 512) -> None:
    """Make the CPU platform expose ``n`` fake devices (mesh dry-runs).

    Pure env *write* (no read, no device query): appends the
    ``--xla_force_host_platform_device_count`` flag so it only takes effect
    if XLA has not initialized yet — call it first thing in an entrypoint,
    before any jax device work.
    """
    os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"


def run_cell(arch: str, shape_name: str, mesh, *, smoke: bool = False,
             verbose: bool = True, hlo_dir: str | None = None) -> dict:
    cfg = get_config(arch, smoke=smoke)
    cell = build_cell(arch, shape_name, mesh, smoke=smoke)
    t0 = time.time()
    with use_mesh(mesh):
        lowered = cell.lower()
        t_lower = time.time() - t0
        compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    if hlo_dir:
        import gzip
        os.makedirs(hlo_dir, exist_ok=True)
        mesh_name = "x".join(str(mesh.shape[n]) for n in mesh.axis_names)
        fname = f"{arch}__{shape_name}__{mesh_name}.hlo.gz"
        with gzip.open(os.path.join(hlo_dir, fname), "wt") as hf:
            hf.write(compiled.as_text())
    mem = compiled.memory_analysis()
    roof = analyze(compiled, arch=arch, shape=SHAPES[shape_name], mesh=mesh,
                   cfg=cfg)
    rec = roof.row()
    rec.update(
        lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
        smoke=smoke, status="ok",
    )
    if mem is not None:
        try:
            rec["memory_analysis"] = {
                "argument_bytes": int(mem.argument_size_in_bytes),
                "output_bytes": int(mem.output_size_in_bytes),
                "temp_bytes": int(mem.temp_size_in_bytes),
                "generated_code_bytes": int(mem.generated_code_size_in_bytes),
            }
        except AttributeError:
            rec["memory_analysis"] = str(mem)
    if verbose:
        print(f"--- {arch} x {shape_name} x {rec['mesh']} ---")
        print("memory_analysis:", rec.get("memory_analysis"))
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        print("cost_analysis: flops=%.3e bytes=%.3e" % (
            float(ca.get("flops", 0)), float(ca.get("bytes accessed", 0))))
        print("collectives:", rec["coll_breakdown"])
        print("terms: compute=%.4fs memory=%.4fs collective=%.4fs dominant=%s"
              % (rec["t_compute_s"], rec["t_memory_s"], rec["t_collective_s"],
                 rec["dominant"]))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default="dryrun_results.jsonl")
    ap.add_argument("--hlo-dir", default=None,
                    help="save compiled HLO text (gzip) per cell")
    args = ap.parse_args()

    meshes = []
    if args.both_meshes:
        meshes = [make_production_mesh(), make_production_mesh(multi_pod=True)]
    else:
        meshes = [make_production_mesh(multi_pod=args.multi_pod)]

    cells = list(all_cells())
    if args.arch:
        cells = [(a, s) for a, s in cells if a == args.arch]
    if args.shape:
        cells = [(a, s) for a, s in cells if s == args.shape]

    n_ok = n_fail = 0
    with open(args.out, "a") as f:
        for mesh in meshes:
            for arch, shape in cells:
                reason = skip_reason(arch, shape)
                if reason:
                    print(f"SKIP {arch} x {shape}: {reason}")
                    continue
                try:
                    rec = run_cell(arch, shape, mesh, smoke=args.smoke,
                                   hlo_dir=args.hlo_dir)
                    n_ok += 1
                # a failing cell is recorded as a "fail" JSONL row + printed
                # traceback, and flips the exit code at the end — survey
                # semantics: compile every cell, report all failures at once
                # repro: allow[jit-boundary,taxonomy] survey loop records and exits nonzero
                except Exception as e:  # noqa: BLE001
                    traceback.print_exc()
                    rec = {
                        "arch": arch, "shape": shape,
                        "mesh": "x".join(str(mesh.shape[n]) for n in mesh.axis_names),
                        "status": "fail", "error": repr(e)[:500],
                    }
                    n_fail += 1
                f.write(json.dumps(rec) + "\n")
                f.flush()
    print(f"\nDRY-RUN: {n_ok} ok, {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    force_host_devices()
    main()
