"""Shared fixtures. NOTE: no XLA device-count flags here by design — smoke
tests and benches must see 1 CPU device; only launch/dryrun.py (separate
process) forces 512 placeholder devices."""
import jax
import numpy as np
import pytest

try:
    import hypothesis  # noqa: F401

    _HAVE_HYPOTHESIS = True
except ImportError:
    _HAVE_HYPOTHESIS = False

# Modules whose property tests need hypothesis (see requirements-dev.txt):
# without it they must be skipped at collection, not error at import.
_HYPOTHESIS_MODULES = ["test_accumulators.py", "test_sparse.py", "test_spgemm.py"]
collect_ignore = [] if _HAVE_HYPOTHESIS else list(_HYPOTHESIS_MODULES)


def pytest_report_header(config):
    if not _HAVE_HYPOTHESIS:
        return ("hypothesis not installed — skipping "
                + ", ".join(_HYPOTHESIS_MODULES)
                + " (pip install -r requirements-dev.txt)")
    return None


@pytest.fixture(autouse=True)
def _seed():
    np.random.seed(0)


@pytest.fixture(autouse=True)
def _reset_telemetry():
    """Per-test telemetry + tuner isolation: every counter starts at zero
    and no fitted table / measured winner leaks across tests (the tuner
    registries are process-global). Lazy imports keep collection cheap."""
    from repro import obs
    from repro.core import autotune, telemetry
    from repro.runtime import faults

    telemetry.reset_all()
    autotune.reset_tuner()
    faults.reset_failpoints()
    obs.reset_obs()
    yield
    faults.reset_failpoints()  # an armed failpoint must never leak forward
    obs.reset_obs()  # enabled tracing / ring contents must not leak either


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches():
    """Drop compiled-executable references between modules: the full suite
    jits hundreds of programs and XLA-CPU's JIT object space is finite —
    without this the tail of the suite hits 'Failed to materialize symbols'
    resource failures."""
    yield
    jax.clear_caches()
