"""Distributed tests on 8 fake host devices (subprocess: the device-count
flag must be set before jax initializes, and the main test process must keep
seeing 1 device)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(body: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(body)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    return proc.stdout


def test_distributed_spgemm():
    out = run_sub("""
        import numpy as np, jax
        from repro.compat import make_mesh
        from repro.sparse import random_csr
        from repro.sparse.oracle import dense_spgemm_oracle
        from repro.core import distributed_spgemm
        mesh = make_mesh((8,), ("data",))
        a = random_csr(96, 64, 4.0, 1)
        b = random_csr(64, 80, 3.0, 2)
        want = dense_spgemm_oracle(a, b)
        for placement in ("replicated", "allgather"):
            c = distributed_spgemm(a, b, mesh, b_placement=placement)
            np.testing.assert_allclose(np.asarray(c.to_dense()), want,
                                       rtol=1e-4, atol=1e-4)
        print("OK")
    """)
    assert "OK" in out


def test_tp_train_step_matches_single_device():
    """2x4 mesh sharded train step == unsharded train step (same batch)."""
    out = run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.launch.mesh import make_test_mesh, rules_for_mesh
        from repro.models import init_params, NO_SHARDING
        from repro.train import AdamWConfig, adamw_init, make_train_step
        cfg = get_config("llama3.2-1b", smoke=True)
        params = init_params(cfg, jax.random.PRNGKey(0))
        opt = adamw_init(params)
        rng = np.random.default_rng(0)
        batch = {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)), jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)), jnp.int32),
        }
        p1, _, m1 = make_train_step(cfg, NO_SHARDING, AdamWConfig())(params, opt, batch)
        mesh = make_test_mesh((2, 4))
        rules = rules_for_mesh(mesh)
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.models import param_shardings
        from repro.train import zero1_shardings, OptState
        p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                            param_shardings(cfg, rules),
                            is_leaf=lambda x: isinstance(x, P))
        # pin outputs: avoids gspmd->named conversion of inferred shardings
        o_sh = OptState(mu=p_sh, nu=p_sh,
                        step=NamedSharding(mesh, P()))
        rep = NamedSharding(mesh, P())
        m_sh = {"grad_norm": rep, "lr": rep, "loss": rep}
        from repro.compat import use_mesh
        with use_mesh(mesh):
            p2, _, m2 = jax.jit(make_train_step(cfg, rules, AdamWConfig(),
                                                mesh=mesh),
                                out_shardings=(p_sh, o_sh, m_sh))(params, opt, batch)
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=2e-2)
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=2e-2, atol=2e-3)
        print("OK")
    """)
    assert "OK" in out


def test_moe_shard_map_matches_local():
    out = run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.launch.mesh import make_test_mesh, rules_for_mesh
        from repro.models import init_params, NO_SHARDING, forward
        cfg = get_config("qwen3-moe-30b-a3b", smoke=True)  # 8 experts
        params = init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(1)
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)),
                                       jnp.int32)}
        l1, _ = forward(params, batch, cfg, NO_SHARDING, remat=False)
        mesh = make_test_mesh((2, 4))
        rules = rules_for_mesh(mesh)
        from repro.compat import use_mesh
        with use_mesh(mesh):
            l2 = jax.jit(lambda p, b: forward(p, b, cfg, rules, mesh=mesh,
                                              remat=False)[0])(params, batch)
        # capacity differs between 1-shard and 4-shard dispatch; compare loosely
        err = float(jnp.mean(jnp.abs(l1.astype(jnp.float32) - l2.astype(jnp.float32))))
        assert err < 0.05, err
        print("OK")
    """)
    assert "OK" in out


def test_compressed_psum_and_topk():
    out = run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.compat import make_mesh, shard_map
        from repro.dist import (compressed_psum, quantize_int8, dequantize_int8,
                                topk_compress, topk_decompress)
        x = jnp.asarray(np.random.default_rng(0).standard_normal((8, 128)),
                        jnp.float32)
        q, s = quantize_int8(x)
        xq = dequantize_int8(q, s, x.shape)
        np.testing.assert_allclose(np.asarray(xq), np.asarray(x), atol=2e-2)
        mesh = make_mesh((8,), ("data",))
        def f(xs):
            return compressed_psum(xs, "data")
        got = jax.jit(shard_map(f, mesh=mesh, in_specs=P("data"),
                                out_specs=P("data")))(x)
        want = jnp.broadcast_to(jnp.mean(x, 0, keepdims=True), x.shape)
        # compressed mean ~= exact mean
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-2)
        v, i, r = topk_compress(x, 64)
        dec = topk_decompress(v, i, x.shape)
        np.testing.assert_allclose(np.asarray(dec + r), np.asarray(x), atol=1e-6)
        print("OK")
    """)
    assert "OK" in out


def test_elastic_checkpoint_reshard():
    """Save params sharded on a (4,2) mesh; restore onto (2,4): values
    identical — elastic scaling across restarts."""
    out = run_sub("""
        import numpy as np, jax, jax.numpy as jnp, tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.ckpt import save, restore
        from repro.launch.mesh import make_test_mesh
        tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
                "b": jnp.ones((8,), jnp.float32)}
        mesh_a = make_test_mesh((4, 2))
        sh_a = {"w": NamedSharding(mesh_a, P("data", "model")),
                "b": NamedSharding(mesh_a, P("data"))}
        placed = jax.tree.map(jax.device_put, tree, sh_a)
        d = tempfile.mkdtemp()
        save(d, 3, placed)
        mesh_b = make_test_mesh((2, 4))
        sh_b = {"w": NamedSharding(mesh_b, P("model", "data")),
                "b": NamedSharding(mesh_b, P("model"))}
        restored, _ = restore(d, 3, tree, shardings=sh_b)
        for k in tree:
            np.testing.assert_array_equal(np.asarray(restored[k]),
                                          np.asarray(tree[k]))
            assert restored[k].sharding == sh_b[k]
        print("OK")
    """)
    assert "OK" in out


def test_pipeline_parallel_forward():
    out = run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.compat import make_mesh
        from repro.dist.pipeline import pipeline_forward
        # 4-stage pipeline on a 'pipe' mesh axis vs serial execution
        mesh = make_mesh((4,), ("pipe",))
        rng = np.random.default_rng(0)
        d = 16
        ws = jnp.asarray(rng.standard_normal((4, d, d)) * 0.3, jnp.float32)
        x = jnp.asarray(rng.standard_normal((8, 4, d)), jnp.float32)  # (mb, B, d)
        def layer(w, h):
            return jnp.tanh(h @ w)
        want = x
        for i in range(4):
            want = layer(ws[i], want)
        got = pipeline_forward(layer, ws, x, mesh, axis="pipe")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-5)
        print("OK")
    """)
    assert "OK" in out


def test_dryrun_smoke_multipod():
    """The dry-run entry point itself (512 devices, multi-pod mesh) on a
    smoke config: proves the pod axis shards end-to-end."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "llama3.2-1b",
         "--shape", "train_4k", "--smoke", "--multi-pod", "--out",
         "/tmp/test_dryrun_smoke.jsonl"],
        capture_output=True, text=True, env=env, timeout=900,
        cwd=REPO,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "1 ok, 0 failed" in proc.stdout
