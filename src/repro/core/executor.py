"""High-throughput reuse engine: pinned plans, batched replay, grouping.

The paper's Reuse case pays for the two-phase split only if the numeric
replay is cheap to *dispatch*, not just cheap to compute: Nagasaka et al.
(arXiv:1804.01698) show the numeric phase is bandwidth-bound, so per-call
host overheads (structure hashing, cache probes, one XLA dispatch per
multiply) dominate exactly the workloads the paper targets — multigrid
setup, graph analytics with changing weights, now at serving rates.

``ReuseExecutor`` closes that gap in three steps:

  * **pin**: the plan is hashed and resolved once at construction (one
    ``structure_key`` call, ever — ``plan_cache.HASH_COUNTS`` proves it);
  * **replay**: ``apply(a_values, b_values)`` is a single jitted dispatch of
    the precomposed v2 plan (two gathers + one sorted segment-sum), with an
    optional donating variant for serving loops that discard their inputs;
  * **batch**: ``apply_batched`` vmaps the replay over stacked value arrays
    ``(batch, nnz_cap)`` — same structure, new values, ONE XLA dispatch for
    the whole batch instead of ``batch`` round-trips through the runtime.

``spgemm_grouped`` extends this to mixed batches: multiplies are grouped by
``plan_cache.structure_key`` (one hash per multiply, the unavoidable
minimum — input prep and plan resolution share ``spgemm()``'s code path)
and each structure group becomes one batched dispatch.

Backends: ``backend="xla"`` (the default that ``"auto"`` resolves to)
replays through ``numeric_reuse``; ``backend="pallas"`` opts into the
``kernels/segsum_reuse`` flat-parallel TPU kernel (``interpret=True``
off-TPU); ``backend="pallas_lp"`` opts into the ``kernels/spgemm_lp``
LP-hash accumulator replay — the KKLP position, for measuring the paper's
accumulator trade-off on the replay hot loop. The Pallas kernels are
explicit opt-in — not what ``"auto"`` picks — until they have real-TPU
compile coverage (CI only exercises interpret mode), and they accumulate in
f32, so f64/int operands route back to XLA. Batched replay always uses the
XLA path — it is the vmap-friendly formulation, and one fused dispatch is
the point of batching.
"""
from __future__ import annotations

import time
from collections import Counter, OrderedDict
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.meta import DEFAULT_PAD_POLICY, f32_accumulation_ok
from repro.core.plan_cache import default_plan_cache, structure_key
from repro.obs import trace as obs_trace
from repro.core.spgemm import (
    SpgemmPlan,
    _note_trace,
    lp_replay_values,
    numeric_reuse,
    prepare_sparse_inputs,
    resolve_plan,
    spgemm,
)
from repro.runtime import faults
from repro.runtime.validate import (
    KernelFallbackError,
    PlanGuard,
    SpgemmConfigError,
    SpgemmError,
    check_plan_compat,
    resolve_mode,
)
from repro.runtime.watchdog import StragglerDetected
from repro.sparse.formats import CSR

BACKENDS = ("auto", "xla", "pallas", "pallas_lp")

# Dispatch telemetry: counts *calls* (not traces — that's TRACE_COUNTS), so
# tests can assert grouping really issues one batched dispatch per structure.
DISPATCH_COUNTS: Counter = Counter()


def reset_dispatch_counts() -> None:
    DISPATCH_COUNTS.clear()


def _resolve_backend(backend: str) -> str:
    if backend not in BACKENDS:
        raise SpgemmConfigError(f"unknown backend {backend!r}; expected one of {BACKENDS}")
    # "auto" stays on XLA even on TPU: the Pallas kernel is explicit opt-in
    # until it has real-TPU compile coverage (tests only run interpret mode).
    return "xla" if backend == "auto" else backend


def _replay(plan: SpgemmPlan, a_values, b_values, backend: str, interpret: bool):
    if backend == "pallas_lp":
        # shared LP dispatch: Pallas kernel or the exact-XLA dtype fallback
        return lp_replay_values(plan, a_values, b_values, interpret)[0]
    if backend == "pallas" and f32_accumulation_ok(a_values.dtype,
                                                   b_values.dtype):
        from repro.kernels.segsum_reuse import segsum_reuse  # lazy: kernels dep

        return segsum_reuse(plan, a_values, b_values, interpret=interpret)
    # XLA path — also the fallback for f64 (the Pallas kernels accumulate in
    # f32, which would halve double precision) and for integer dtypes (f32
    # rounding above 2^24 would break integer exactness).
    return numeric_reuse(plan, a_values, b_values)


def _apply_impl(plan, a_values, b_values, backend, interpret):
    _note_trace("executor_apply")
    return _replay(plan, a_values, b_values, backend, interpret)


_apply = jax.jit(_apply_impl, static_argnames=("backend", "interpret"))
# serving-loop variants: per-operand buffer donation, so a loop with one
# fixed operand (multigrid's P) can donate only the per-step values
_apply_donated = {
    (True, True): jax.jit(_apply_impl, static_argnames=("backend", "interpret"),
                          donate_argnums=(1, 2)),
    (True, False): jax.jit(_apply_impl, static_argnames=("backend", "interpret"),
                           donate_argnums=(1,)),
    (False, True): jax.jit(_apply_impl, static_argnames=("backend", "interpret"),
                           donate_argnums=(2,)),
}


def replay_candidates(plan, a_values, b_values, interpret: bool) -> dict:
    """The eligible replay backends for these operands, as autotuner thunks.

    This *is* the PR 5 selection table in measurable form: XLA is always
    eligible; the f32-accumulating Pallas kernels (segsum + LP-hash) join
    only when ``f32_accumulation_ok`` admits the operand dtypes — measure
    mode must never time (let alone pick) a kernel the dtype guard would
    refuse to dispatch.
    """
    cands = {"xla": lambda: _apply(plan, a_values, b_values,
                                   backend="xla", interpret=interpret)}
    if f32_accumulation_ok(a_values.dtype, b_values.dtype):
        for name in ("pallas", "pallas_lp"):
            cands[name] = (lambda nm=name: _apply(
                plan, a_values, b_values, backend=nm, interpret=interpret))
    return cands


@partial(jax.jit, static_argnames=("a_axis", "b_axis"))
def _apply_batched(plan, a_values, b_values, a_axis, b_axis):
    _note_trace("executor_apply_batched")
    return jax.vmap(
        lambda av, bv: numeric_reuse(plan, av, bv), in_axes=(a_axis, b_axis)
    )(a_values, b_values)


class ReuseExecutor:
    """A pinned ``SpgemmPlan`` exposed as a replay engine.

    Construction is the only host-side work: from then on every ``apply`` /
    ``apply_batched`` is a pure jitted dispatch — zero structure hashing,
    zero cache probes, zero retraces (for fixed operand shapes/dtypes).

    ``tune="measure"`` defers the backend choice to first ``apply``: the
    autotuner's bucket table is consulted (a previous executor on a
    same-bucket problem already paid the sweep), else the eligible replay
    backends are micro-benchmarked once on the first real operands; every
    later ``apply`` re-dispatches the pinned winner with zero re-tuning.
    ``kernel_source`` records the provenance ("static" until the first
    measured apply, then "measured"). Requires ``backend="auto"`` — an
    explicit backend pin and measure mode are contradictory instructions.
    ``apply_batched`` stays on the XLA vmap formulation regardless: one
    fused dispatch is the point of batching, and the Pallas kernels have no
    batched formulation (module docstring).

    Robustness knobs (PR 7, see ROADMAP "The failure model"):
    ``validate="off"|"host"|"device"`` builds a pin-time ``PlanGuard`` and
    checks operand buffers O(1) per replay ("device" adds a finiteness
    sweep); ``nan_guard=True`` re-runs non-finite outputs once through the
    XLA oracle and classifies kernel-vs-data; ``watchdog=StepWatchdog(...)``
    deadlines each replay (blocking on the result); ``on_kernel_failure``
    picks between the degradation ladder ("fallback": any Pallas failure
    re-dispatches exact XLA, counted in ``telemetry.FALLBACK_COUNTS`` and
    visible as ``kernel_source == "fallback"``) and a typed
    ``KernelFallbackError`` ("raise").
    """

    def __init__(self, plan: SpgemmPlan, *, backend: str = "auto",
                 interpret: bool | None = None, tune: str | None = None,
                 validate: str | None = "off", nan_guard: bool = False,
                 watchdog=None, on_kernel_failure: str = "fallback"):
        from repro.core import autotune  # lazy: keep ctor import-light

        if plan is None:
            raise SpgemmConfigError(
                "ReuseExecutor needs a SpgemmPlan; got None — the dense "
                "spgemm method returns plan=None (no Reuse path), build the "
                "plan with method='sparse'"
            )
        autotune.validate_tune(tune)
        if tune == "measure" and backend != "auto":
            raise SpgemmConfigError(
                f"tune='measure' requires backend='auto' (got "
                f"backend={backend!r}): measure mode picks the backend "
                f"empirically, an explicit pin contradicts it")
        if on_kernel_failure not in ("fallback", "raise"):
            raise SpgemmConfigError(
                f"on_kernel_failure must be 'fallback' or 'raise', got "
                f"{on_kernel_failure!r}")
        self.plan = plan
        self.backend = _resolve_backend(backend)
        self.tune = tune
        self.kernel_source = "static"
        self._needs_measure = tune == "measure"
        # Pallas only lowers on TPU; everywhere else run it interpreted.
        self.interpret = (
            jax.default_backend() != "tpu" if interpret is None else interpret
        )
        # robustness layer (PR 7). Note the executor's validate default is a
        # literal "off", NOT None: replay is the hot path, and the
        # $REPRO_VALIDATE escape hatch changing its dispatch behind a
        # serving loop's back would be a perf landmine — opt in explicitly.
        self.validate_mode = resolve_mode(validate)
        self.nan_guard = nan_guard
        self.watchdog = watchdog
        self.on_kernel_failure = on_kernel_failure
        self.nan_events: list[tuple] = []
        # pin-time plan digest: one host sync here buys O(1) per-replay
        # operand checks (PlanGuard also vets the plan's own indptr)
        self._guard = PlanGuard(plan) if self.validate_mode != "off" else None
        self._skey: str | None = None  # set by from_matrices/pin
        self._pad_policy: str | None = None
        self._fm_cap: int | None = None

    @classmethod
    def from_matrices(cls, a: CSR, b: CSR, *, pad_policy: str | None = None,
                      plan_cache=None, backend: str = "auto",
                      interpret: bool | None = None,
                      tune: str | None = None,
                      validate: str | None = "off", nan_guard: bool = False,
                      watchdog=None,
                      on_kernel_failure: str = "fallback") -> "ReuseExecutor":
        """Build (or fetch from the plan cache) the plan for ``a @ b`` and pin
        it. This is the one and only structure hash in the executor's life.
        The hash's structure key is retained, enabling ``check_compat``."""
        res = spgemm(a, b, method="sparse", pad_policy=pad_policy,
                     plan_cache=plan_cache, validate=validate)
        ex = cls(res.plan, backend=backend, interpret=interpret, tune=tune,
                 validate=validate, nan_guard=nan_guard, watchdog=watchdog,
                 on_kernel_failure=on_kernel_failure)
        ex._skey = res.stats["structure_key"]
        ex._pad_policy = res.stats["pad_policy"]
        ex._fm_cap = res.stats["fm_cap"]
        return ex

    # the serving-facing name for pinning a plan from operands
    pin = from_matrices

    def check_compat(self, a: CSR, b: CSR) -> None:
        """Structure-key recheck: would these operands rebuild *this* plan?

        Raises ``PlanMismatchError`` if not (or if the executor was built
        from a bare plan and has no pinned key). Costs one ``structure_key``
        digest (HASH_COUNTS bumps) — an opt-in integrity check, not part of
        the replay hot path.
        """
        policy = self._pad_policy or DEFAULT_PAD_POLICY
        a, b, _, _, fm_cap = prepare_sparse_inputs(a, b, policy)
        if self._skey is not None and fm_cap != self._fm_cap:
            from repro.runtime.validate import PlanMismatchError

            raise PlanMismatchError(
                f"operand expansion bucket fm_cap={fm_cap} != the pinned "
                f"plan's {self._fm_cap}")
        check_plan_compat(self._skey, a, b, fm_cap, policy)

    def _measure(self, a_values: jax.Array, b_values: jax.Array) -> None:
        """First-apply backend measurement (tune="measure" only).

        Bucket table first — a hit reuses another executor's sweep; else
        micro-bench the eligible backends on these operands and record the
        winner for the bucket. Either way the winner is pinned: later
        applies are plain dispatches.
        """
        from repro.core import autotune

        m, k = (int(x) for x in self.plan.shape)
        bkey = autotune.bucket_key(m, k, self.fm_cap, a_values.dtype,
                                   b_values.dtype, table="replay")
        winner = autotune.lookup_measured(bkey)
        if winner is None:
            winner, _ = autotune.measure_and_record(
                bkey, replay_candidates(self.plan, a_values, b_values,
                                        self.interpret))
        self.backend = winner
        self.kernel_source = "measured"
        self._needs_measure = False

    @property
    def shape(self) -> tuple:
        return tuple(self.plan.shape)

    @property
    def nnz_cap(self) -> int:
        return self.plan.indices.shape[0]

    @property
    def fm_cap(self) -> int:
        return self.plan.seg_ids.shape[0]

    def apply(self, a_values: jax.Array, b_values: jax.Array, *,
              donate: bool | str = False) -> jax.Array:
        """Replay the pinned plan on new operand values: (nnz_cap,) C values.

        donate: ``True``/``"both"`` donates both value buffers to the
        dispatch; ``"a"``/``"b"`` donates only that operand — use these when
        the other operand is fixed across calls (multigrid's P), since a
        donated buffer must not be passed again. Donation is permission, not
        a guarantee: XLA only aliases a donated operand into the output when
        their shapes/dtypes line up (operand ``nnz_cap`` == plan ``nnz_cap``
        bucket), and warns-and-copies otherwise — leave it off unless the
        buckets match.
        """
        DISPATCH_COUNTS["apply"] += 1
        if self._needs_measure:
            # measurement never donates: the sweep replays the same buffers
            self._measure(a_values, b_values)
        if donate:
            if self.nan_guard:
                raise SpgemmConfigError(
                    "nan_guard and donate are incompatible: the guard's "
                    "oracle re-run reads the operand buffers after dispatch, "
                    "which donation invalidates")
            key = {True: (True, True), "both": (True, True),
                   "a": (True, False), "b": (False, True)}.get(donate)
            if key is None:
                raise SpgemmConfigError(
                    f"donate must be bool, 'a', 'b' or 'both'; got {donate!r}")
            fn = _apply_donated[key]
        else:
            fn = _apply
        if self._guard is not None:
            self._guard.check_values(a_values, b_values, self.validate_mode)
        out = self._dispatch(fn, a_values, b_values)
        if self.nan_guard:
            out = self._nan_check(out, a_values, b_values)
        return out

    def _dispatch(self, fn, a_values, b_values):
        """One replay dispatch under the degradation ladder + watchdog.

        Tracing split: when the tracer is off (the default), this is exactly
        the bare ladder — no span, no clock read, no recorder entry on
        success (fallbacks and errors are always recorded; they are rare and
        already off the fast path). When tracing is on, the dispatch gets a
        ``numeric.dispatch`` span (feeding the per-kernel histograms) and a
        flight-recorder event with the host-side duration.
        """
        backend = self.backend
        if backend in ("pallas", "pallas_lp") and not f32_accumulation_ok(
                a_values.dtype, b_values.dtype):
            # the dtype guard inside _replay/lp_replay_values will route this
            # dispatch to exact XLA; record the provenance eagerly
            from repro.core.telemetry import FALLBACK_COUNTS  # lazy: cycle

            FALLBACK_COUNTS["dtype:executor->xla"] += 1
        if not obs_trace.enabled():
            return self._run_ladder(fn, a_values, b_values, backend)
        from repro.obs import recorder  # lazy: off the untraced hot path

        t0 = time.perf_counter()
        with obs_trace.span("numeric.dispatch", kernel=backend,
                            site="executor") as sp:
            out = self._run_ladder(fn, a_values, b_values, backend, sp=sp)
        recorder.record(
            "dispatch", kernel=self.backend, structure_key=self._skey,
            shapes=f"{tuple(a_values.shape)}x{tuple(b_values.shape)}",
            duration_s=time.perf_counter() - t0,
            verdict=("fallback" if sp.attrs.get("fallback") else "ok"),
            trace_id=obs_trace.current_trace_id())
        return out

    def _run_ladder(self, fn, a_values, b_values, backend, sp=None):
        """The degradation ladder proper (tracing-agnostic).

        Failure catching lives HERE, outside jit: a trace that dies is never
        cached, so re-dispatching ``backend="xla"`` compiles into its own
        (clean) cache entry — the failed backend cannot poison it. All
        counter bumps are eager host-side for the same reason.
        """
        try:
            faults.check(f"kernel:{backend}")
            out = self._timed(fn, a_values, b_values, backend)
        except (SpgemmError, StragglerDetected):
            # typed validation errors and watchdog deadline verdicts are not
            # kernel failures — the ladder must not absorb either
            raise
        except Exception as e:
            if self.on_kernel_failure == "raise" or backend == "xla":
                err = KernelFallbackError(
                    f"replay backend {backend!r} failed"
                    + ("" if backend == "xla"
                       else " and on_kernel_failure='raise'"))
                from repro.obs import recorder  # lazy: error path only

                recorder.note_error(err, kernel=backend, site="executor",
                                    structure_key=self._skey,
                                    trace_id=obs_trace.current_trace_id())
                raise err from e
            from repro.core.telemetry import FALLBACK_COUNTS  # lazy: cycle
            from repro.obs import recorder  # lazy: fallback path only

            FALLBACK_COUNTS[f"fault:{backend}->xla"] += 1
            self.kernel_source = "fallback"
            recorder.record("fallback", kernel=backend,
                            fallback=f"{backend}->xla", verdict="fallback",
                            site="executor", structure_key=self._skey,
                            trace_id=obs_trace.current_trace_id())
            if sp is not None:
                sp.set("fallback", f"{backend}->xla")
            out = self._timed(_apply, a_values, b_values, "xla")
        if faults.armed("executor:poison_output") and jnp.issubdtype(
                out.dtype, jnp.floating):
            # chaos hook: simulate a kernel writing garbage (exercises the
            # NaN guard's recovered path without a real miscompile)
            out = out.at[:1].set(jnp.nan)
        return out

    def _timed(self, fn, a_values, b_values, backend):
        """Run one dispatch, under the watchdog's deadline when one is set.

        The watchdog measures wall time to *completed results*, so the
        guarded path blocks on the output; unguarded dispatch keeps JAX's
        async semantics untouched.
        """
        if self.watchdog is None:
            return fn(self.plan, a_values, b_values,
                      backend=backend, interpret=self.interpret)
        with self.watchdog.step(DISPATCH_COUNTS["apply"]
                                + DISPATCH_COUNTS["apply_batched"]):
            out = fn(self.plan, a_values, b_values,
                     backend=backend, interpret=self.interpret)
            return jax.block_until_ready(out)

    def _nan_check(self, out, a_values, b_values):
        """Opt-in output guard: on non-finite output, re-run once through
        the exact-XLA oracle (``numeric_reuse``) and classify — "recovered"
        (kernel-side fault: oracle output finite, returned instead) vs
        "data" (operands themselves carry NaN/Inf: flagged, oracle output
        returned so the two verdicts are at least consistent)."""
        if not jnp.issubdtype(out.dtype, jnp.floating):
            return out
        if bool(jnp.all(jnp.isfinite(out))):
            return out
        from repro.core.telemetry import FALLBACK_COUNTS  # lazy: cycle

        FALLBACK_COUNTS["nan_guard:rerun"] += 1
        oracle = numeric_reuse(self.plan, a_values, b_values)
        if bool(jnp.all(jnp.isfinite(oracle))):
            FALLBACK_COUNTS["nan_guard:recovered"] += 1
            self.nan_events.append(("recovered", self.backend))
            return oracle
        FALLBACK_COUNTS["nan_guard:data"] += 1
        self.nan_events.append(("data", self.backend))
        return oracle

    def apply_batched(self, a_values: jax.Array, b_values: jax.Array) -> jax.Array:
        """Replay over stacked values in ONE dispatch: (batch, nnz_cap).

        Either operand may be stacked ``(batch, operand_nnz_cap)`` or shared
        unbatched ``(operand_nnz_cap,)`` (e.g. a fixed prolongator P against
        a batch of A values). At least one side must be stacked.
        """
        DISPATCH_COUNTS["apply_batched"] += 1
        a_axis = 0 if a_values.ndim == 2 else None
        b_axis = 0 if b_values.ndim == 2 else None
        if a_axis is None and b_axis is None:
            raise SpgemmConfigError(
                "apply_batched needs at least one stacked (batch, nnz) operand; "
                "use apply() for a single replay"
            )
        if self._guard is not None:
            self._guard.check_values(a_values, b_values, self.validate_mode,
                                     batched=True)
        batch = a_values.shape[0] if a_axis == 0 else b_values.shape[0]
        # batched replay is always the XLA vmap formulation (module docstring)
        with obs_trace.span("numeric.dispatch", kernel="xla",
                            site="executor", batch=batch):
            if self.watchdog is None:
                return _apply_batched(self.plan, a_values, b_values,
                                      a_axis=a_axis, b_axis=b_axis)
            with self.watchdog.step(DISPATCH_COUNTS["apply"]
                                    + DISPATCH_COUNTS["apply_batched"]):
                out = _apply_batched(self.plan, a_values, b_values,
                                     a_axis=a_axis, b_axis=b_axis)
                return jax.block_until_ready(out)

    def to_csr(self, values: jax.Array) -> CSR:
        """Wrap one replay's values in the plan's C structure."""
        return CSR(indptr=self.plan.indptr, indices=self.plan.indices,
                   values=values, shape=self.shape)


def spgemm_grouped(pairs: Sequence[tuple[CSR, CSR]], *,
                   pad_policy: str | None = None, plan_cache=None,
                   backend: str = "auto", interpret: bool | None = None,
                   tune: str | None = None) -> list[CSR]:
    """Mixed-structure batch: group by structure, one dispatch per group.

    Each (A, B) multiply is hashed once with ``plan_cache.structure_key``;
    multiplies sharing a structure (and operand value dtypes — stacking must
    not promote a mixed group) are stacked and replayed through a single
    ``apply_batched`` dispatch (plans come from — and land in — the plan
    cache, so repeated batches skip expansion entirely). Results come back
    in input order as CSR matrices sharing their group's structure arrays.

    tune="measure": singleton groups dispatch the measured replay winner —
    the plan-cache entry's recorded winner when one exists (zero re-tuning
    across calls), else a first-sight measurement whose winner is written
    back to the entry, exactly mirroring ``spgemm(tune="measure")``.
    Batched (>1) groups keep the XLA vmap formulation — one fused dispatch
    is the point of batching (see ReuseExecutor). Requires backend="auto".
    """
    from repro.core import autotune  # lazy, mirrors ReuseExecutor

    autotune.validate_tune(tune)
    if tune == "measure" and backend != "auto":
        raise SpgemmConfigError(
            f"tune='measure' requires backend='auto' (got "
            f"backend={backend!r}): measure mode picks the backend "
            f"empirically, an explicit pin contradicts it")
    policy = DEFAULT_PAD_POLICY if pad_policy is None else pad_policy
    pairs = list(pairs)
    if not pairs:
        # The empty batch is a legal no-op (a serving tick with nothing
        # admitted), not an error: return the empty result explicitly so a
        # generator input or an all-shed batch can never fall through to an
        # opaque downstream IndexError.
        return []
    if plan_cache is None:
        cache = default_plan_cache()
    elif plan_cache is False:
        cache = None
    else:
        cache = plan_cache

    prepared: list[tuple[CSR, CSR, int]] = []
    groups: OrderedDict[tuple, list[int]] = OrderedDict()
    for a, b in pairs:
        a, b, _, _, fm_cap = prepare_sparse_inputs(a, b, policy)
        skey = structure_key(a, b, fm_cap, policy)  # the one hash per multiply
        # dtypes join the grouping (not the plan key): jnp.stack on a mixed
        # group would silently promote, diverging from the per-call contract
        gkey = (skey, str(a.values.dtype), str(b.values.dtype))
        groups.setdefault(gkey, []).append(len(prepared))
        prepared.append((a, b, fm_cap))

    results: list[CSR | None] = [None] * len(prepared)
    for (skey, adt, bdt), idxs in groups.items():
        a0, b0, fm_cap = prepared[idxs[0]]
        plan, _, _ = resolve_plan(a0, b0, fm_cap, policy, cache, key=skey)
        group_tune = tune if len(idxs) == 1 else None  # batched stays XLA
        meta_key = ("tuned_backend", adt, bdt)
        if group_tune == "measure" and cache is not None:
            pinned = cache.get_meta(skey, meta_key)
            if pinned is not None:
                # a prior measured call already decided for this entry:
                # dispatch the winner directly, zero re-tuning
                autotune.TUNE_COUNTS["plan_meta_hit"] += 1
                ex = ReuseExecutor(plan, backend=pinned, interpret=interpret)
                results[idxs[0]] = ex.to_csr(ex.apply(a0.values, b0.values))
                continue
        ex = ReuseExecutor(plan, backend=backend, interpret=interpret,
                           tune=group_tune)
        if len(idxs) == 1:
            results[idxs[0]] = ex.to_csr(ex.apply(a0.values, b0.values))
            if ex.kernel_source == "measured" and cache is not None:
                cache.set_meta(skey, meta_key, ex.backend)
            continue
        a_stack = jnp.stack([prepared[i][0].values for i in idxs])
        b_stack = jnp.stack([prepared[i][1].values for i in idxs])
        vals = ex.apply_batched(a_stack, b_stack)
        for j, i in enumerate(idxs):
            results[i] = ex.to_csr(vals[j])
    return results
