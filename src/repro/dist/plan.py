"""ShardedPlan: the two-phase SpGEMM plan lifecycle lifted onto a JAX mesh.

A ``ShardedPlan`` is a stacked per-shard ``SpgemmPlan``: every array carries
a leading shard axis ``S`` and *uniform* capacities (the max over shards,
bucketed through ``core.meta.round_capacity`` so shards share capacity
buckets — and compiled executables — with the single-device path). Building
one costs:

  1. ONE sharded expand-and-sort pass (``shard_map`` over the ``data``
     axis): each shard enumerates and sorts its own products, returning the
     stacked ``SortedExpansion`` — the sharded analog of the single-device
     single-expansion contract (the expansion is never re-run for the plan);
  2. ONE host cap-sync: the per-shard nnz(C) maxima come back to the host
     and pick the uniform ``nnz_cap`` bucket (the same role as the paper's
     host-side allocation between the symbolic and numeric phases);
  3. a vmapped ``plan_from_sorted`` over the stacked expansion — pure
     composition, no second sort.

The plan also pins the *value routing* so replays never touch structure:

  * ``a_perm`` (S, a_cap): global A value slot feeding each shard slot —
    fresh A values are re-sharded with one gather;
  * ``b_shard_perm`` / ``b_perm`` (allgather placement only): how B values
    shard before the collective and how the flattened all-gather maps onto
    the concatenated global B layout the plan was built against. B's
    *structure* all-gather (``concat_csr_shards``) happens once, here —
    replays only all-gather values.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core.distributed import (
    ShardedCSR,
    allgather_value_perm,
    concat_csr_shards,
    partition_rows,
    partition_value_map,
    shard_fm_cap,
)
from repro.core.meta import DEFAULT_PAD_POLICY, round_capacity
from repro.core.spgemm import (
    SortedExpansion,
    expand_and_sort,
    plan_from_sorted,
)
from repro.sparse.formats import CSR

B_PLACEMENTS = ("replicated", "allgather")


class ShardedPlan(NamedTuple):
    """Stacked per-shard numeric plan (leading axis S, uniform caps).

    ``indptr``/``indices`` describe each shard's rows of C; ``seg_ids`` /
    ``a_slot_s`` / ``b_slot_s`` are the per-shard precomposed v2 replay maps
    (see ``SpgemmPlan``); the perms route *values* between the global and
    sharded layouts. For the replicated placement the B perms are empty
    ``(0,)``-shaped placeholders.
    """

    indptr: jax.Array  # (S, m_loc+1) int32 — per-shard C row pointers
    indices: jax.Array  # (S, nnz_cap) int32 — per-shard C columns
    seg_ids: jax.Array  # (S, fm_cap) int32 — sorted product -> C slot
    a_slot_s: jax.Array  # (S, fm_cap) int32 — A slot per sorted product
    b_slot_s: jax.Array  # (S, fm_cap) int32 — B slot per sorted product
    a_perm: jax.Array  # (S, a_cap) int32 — global A value slot per shard slot
    b_shard_perm: jax.Array  # (S, b_cap) int32 (allgather) — B value sharding
    b_perm: jax.Array  # (S*b_cap,) int32 (allgather) — gathered -> concat slot
    shape: tuple  # global (m, k) of C

    @property
    def num_shards(self) -> int:
        return self.indptr.shape[0]

    @property
    def m_loc(self) -> int:
        return self.indptr.shape[1] - 1

    @property
    def nnz_cap(self) -> int:
        return self.indices.shape[1]

    @property
    def fm_cap(self) -> int:
        return self.seg_ids.shape[1]


def dist_expand_and_sort(a_sh: ShardedCSR, b: CSR | ShardedCSR, mesh,
                         axis: str, fm_cap: int) -> SortedExpansion:
    """ONE sharded expansion+sort: stacked ``SortedExpansion`` (leading S).

    ``row_sizes`` (S, m_loc) doubles as the sharded symbolic answer — the
    host reads its per-shard sums to pick the uniform ``nnz_cap`` bucket,
    then feeds the *same* expansion to the plan build (never re-expanded).
    """
    m_loc = a_sh.m_loc
    k = b.shape[1]
    replicated = isinstance(b, CSR)

    def fn(ip, ix, vl, b_ip, b_ix, b_vl):
        a_loc = CSR(indptr=ip[0], indices=ix[0], values=vl[0],
                    shape=(m_loc, a_sh.shape[1]))
        if replicated:
            b_loc = CSR(indptr=b_ip, indices=b_ix, values=b_vl, shape=b.shape)
        else:
            b_ips = jax.lax.all_gather(b_ip[0], axis)
            b_ixs = jax.lax.all_gather(b_ix[0], axis)
            b_vls = jax.lax.all_gather(b_vl[0], axis)
            b_loc = concat_csr_shards(b_ips, b_ixs, b_vls, k)
        sx = expand_and_sort(a_loc, b_loc, fm_cap)
        return jax.tree.map(lambda x: x[None], sx)

    b_specs = (P(), P(), P()) if replicated else (P(axis), P(axis), P(axis))
    out_specs = SortedExpansion(*([P(axis)] * len(SortedExpansion._fields)))
    return shard_map(
        fn, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis)) + b_specs,
        out_specs=out_specs,
    )(a_sh.indptr, a_sh.indices, a_sh.values, b.indptr, b.indices, b.values)


def build_sharded_plan(a: CSR, b: CSR, mesh, *, axis: str = "data",
                       b_placement: str = "replicated",
                       pad_policy: str | None = None) -> ShardedPlan:
    """Pin the full sharded plan lifecycle: partition -> one sharded
    expand/sort -> one host cap-sync -> stacked plan composition.

    ``a`` and ``b`` are the *global* operands (callers that also feed the
    single-device path should pass them through ``prepare_sparse_inputs``
    first so both paths hash and bucket identically).
    """
    if b_placement not in B_PLACEMENTS:
        from repro.runtime.validate import SpgemmConfigError  # cycle-free
        raise SpgemmConfigError(
            f"unknown b_placement {b_placement!r}; expected one of {B_PLACEMENTS}")
    policy = DEFAULT_PAD_POLICY if pad_policy is None else pad_policy
    num = mesh.shape[axis]
    a_sh = partition_rows(a, num, policy)
    a_perm = partition_value_map(a, num, policy)
    if b_placement == "replicated":
        b_in: CSR | ShardedCSR = b
        b_shard_perm = np.zeros((num, 0), np.int32)
        b_perm = np.zeros((0,), np.int32)
    else:
        b_sh = partition_rows(b, num, policy)
        b_in = b_sh
        b_shard_perm = partition_value_map(b, num, policy)
        b_perm = allgather_value_perm(b_sh)

    fm_cap = shard_fm_cap(a_sh, b, policy)
    sx = dist_expand_and_sort(a_sh, b_in, mesh, axis, fm_cap)
    # the one host round-trip between phases: uniform nnz bucket over shards
    nnz_cap = round_capacity(int(jnp.max(jnp.sum(sx.row_sizes, axis=1))), policy)
    k = b.shape[1]

    def build(one: SortedExpansion):
        p = plan_from_sorted(one, k, nnz_cap)
        return p.indptr, p.indices, p.seg_ids, p.a_slot_s, p.b_slot_s

    ip, ix, seg, asl, bsl = jax.vmap(build)(sx)
    return ShardedPlan(
        indptr=ip, indices=ix, seg_ids=seg, a_slot_s=asl, b_slot_s=bsl,
        a_perm=jnp.asarray(a_perm),
        b_shard_perm=jnp.asarray(b_shard_perm),
        b_perm=jnp.asarray(b_perm),
        shape=(a.m, k),
    )
