"""Model zoo: configurable transformer/SSM/hybrid/MoE stacks."""
from repro.models.model import (
    cache_shardings,
    cache_template,
    decode_step,
    forward,
    init_cache,
    init_params,
    model_template,
    param_shardings,
    param_specs,
)
from repro.models.sharding import NO_SHARDING, ShardingRules

__all__ = [
    "forward",
    "decode_step",
    "init_params",
    "init_cache",
    "param_specs",
    "param_shardings",
    "cache_template",
    "cache_shardings",
    "model_template",
    "ShardingRules",
    "NO_SHARDING",
]
