"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic specification its kernel is tested against
(interpret=True sweeps in tests/test_kernels.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def spgemm_symbolic_ref(a_idx, a_nnz, b_bitmask):
    """Row sizes of C: popcount of the OR of B's bitmask rows selected by A.

    a_idx: (m, rA) int32 ELL columns of A; a_nnz: (m,) live widths;
    b_bitmask: (n, k32) uint32. Returns (m,) int32.
    """
    m, rA = a_idx.shape
    live = jnp.arange(rA, dtype=jnp.int32)[None, :] < a_nnz[:, None]
    rows = b_bitmask[a_idx.clip(0, b_bitmask.shape[0] - 1)]  # (m, rA, k32)
    rows = jnp.where(live[:, :, None], rows, jnp.uint32(0))
    acc = jax.lax.reduce(rows, jnp.uint32(0), jnp.bitwise_or, dimensions=(1,))
    return jnp.sum(jax.lax.population_count(acc), axis=-1).astype(jnp.int32)


def spgemm_numeric_ref(a_idx, a_val, b_idx, b_val, c_idx, c_nnz, k):
    """ELL-in/ELL-out numeric phase: C values at the symbolic structure.

    a_idx/a_val: (m, rA); b_idx/b_val: (n, rB); c_idx: (m, rC) symbolic
    structure (padded slots arbitrary); c_nnz: (m,). Returns (m, rC) values.
    Dense accumulator semantics (KKDENSE): scatter products into a dense row,
    gather at the structure's columns.
    """
    m, rA = a_idx.shape
    n, rB = b_idx.shape

    def row(ai, av, ci, cn):
        bi = b_idx[ai.clip(0, n - 1)]  # (rA, rB)
        bv = b_val[ai.clip(0, n - 1)]
        prod = av[:, None] * bv  # (rA, rB) — padded a_val==0 kills phantom rows
        acc = jnp.zeros((k,), prod.dtype).at[bi.reshape(-1)].add(prod.reshape(-1))
        out = acc[ci.clip(0, k - 1)]
        return jnp.where(jnp.arange(ci.shape[0]) < cn, out, 0)

    return jax.vmap(row)(a_idx, a_val, c_idx, c_nnz)


def segsum_reuse_ref(a_slot_s, b_slot_s, seg_ids, a_values, b_values, nnz_cap):
    """Reuse-case numeric replay: C[seg] += A[a_slot] * B[b_slot].

    a_slot_s/b_slot_s/seg_ids: (fm_cap,) int32 in sorted product order;
    padding products carry the sentinel ``seg_ids == nnz_cap`` (dropped).
    Returns (nnz_cap,) values in result_type(a, b) — the precomposed-plan
    contract of ``core.spgemm.numeric_reuse``.

    Deliberately NOT the gather/scatter formulation the implementations use:
    a host-side python loop over live products, so it can catch a bug in the
    shared vectorized expression.
    """
    import numpy as np

    a_np, b_np = np.asarray(a_values), np.asarray(b_values)
    a_idx, b_idx = np.asarray(a_slot_s), np.asarray(b_slot_s)
    segs = np.asarray(seg_ids)
    acc_dtype = jnp.result_type(a_values, b_values)
    out = np.zeros(nnz_cap, np.dtype(acc_dtype))
    for t, s in enumerate(segs):
        if 0 <= s < nnz_cap:
            out[s] += a_np[a_idx[t]] * b_np[b_idx[t]]
    return jnp.asarray(out)


def spgemm_lp_ref(a_idx, a_val, a_nnz, b_idx, b_val, b_nnz, c_idx, c_nnz,
                  l1_size: int):
    """Bitwise oracle for the KKLP kernel: per row, replay the Gustavson
    insert stream through ``core.accumulators.accumulate_row(kind="lp")``
    (L1 size ``l1_size`` with the 50% max-occupancy rule, L2 sized to hold
    every spill — the MAXRF guarantee) and read the merged L1+L2 tables at
    the symbolic structure ``c_idx``/``c_nnz``.

    The stream order is the kernel's: A slots row-major, then the B row's
    slots; products are f32 multiplies. Host-side loop on purpose — the
    accumulator ports are the semantic ground truth, not a re-derivation of
    the kernel's vectorized probe.
    """
    import numpy as np

    from repro.core.accumulators import accumulate_row

    a_idx_n, a_nnz_n = np.asarray(a_idx), np.asarray(a_nnz)
    b_idx_n, b_nnz_n = np.asarray(b_idx), np.asarray(b_nnz)
    a_val_n = np.asarray(a_val, np.float32)
    b_val_n = np.asarray(b_val, np.float32)
    c_idx_n, c_nnz_n = np.asarray(c_idx), np.asarray(c_nnz)
    m, r_c = c_idx_n.shape

    streams = []
    for i in range(m):
        keys, vals = [], []
        for r in range(int(a_nnz_n[i])):
            j = int(a_idx_n[i, r])
            for t in range(int(b_nnz_n[j])):
                keys.append(int(b_idx_n[j, t]))
                vals.append(np.float32(a_val_n[i, r]) * np.float32(b_val_n[j, t]))
        streams.append((keys, vals))
    cap = max([len(k) for k, _ in streams] + [1])

    out = np.zeros((m, r_c), np.float32)
    for i, (keys, vals) in enumerate(streams):
        n_p = len(keys)
        k_arr = np.zeros(cap, np.int32)
        v_arr = np.zeros(cap, np.float32)
        k_arr[:n_p] = keys
        v_arr[:n_p] = vals
        valid = np.arange(cap) < n_p
        l1, l2, _ = accumulate_row(
            jnp.asarray(k_arr), jnp.asarray(v_arr), jnp.asarray(valid),
            l1_size, l1_size, cap + 1, "lp",
        )
        got: dict[int, np.float32] = {}
        for key, v, ok in zip(np.asarray(l1.ids), np.asarray(l1.values),
                              np.asarray(l1.ids) >= 0):
            if ok:
                got[int(key)] = v
        l2_live = np.arange(l2.values.shape[0]) < int(l2.used)
        for key, v, ok in zip(np.asarray(l2.ids), np.asarray(l2.values), l2_live):
            if ok:
                got[int(key)] = got.get(int(key), np.float32(0.0)) + v
        for s in range(int(c_nnz_n[i])):
            out[i, s] = got.get(int(c_idx_n[i, s]), np.float32(0.0))
    return jnp.asarray(out)


def grouped_matmul_ref(x, w, group_ids):
    """Per-token expert matmul: y[t] = x[t] @ w[group_ids[t]].

    x: (T, d); w: (E, d, f); group_ids: (T,) int32. Returns (T, f).
    """
    return jnp.einsum("td,tdf->tf", x, w[group_ids])


def flash_attention_ref(q, k, v, *, causal=True, window=None, softcap=None,
                        segment_pos=None):
    """Reference attention. q: (Hq, Tq, D), k/v: (Hkv, Tk, D) — GQA via
    head-group broadcasting. Scores in f32. window = sliding-window size
    (gemma2 local layers); softcap = logit soft-capping value.
    segment_pos: (Tq,) absolute positions of q (for decode; default arange).
    """
    hq, tq, d = q.shape
    hkv = k.shape[0]
    group = hq // hkv
    kq = jnp.repeat(k, group, axis=0)
    vq = jnp.repeat(v, group, axis=0)
    scores = jnp.einsum("htd,hsd->hts", q.astype(jnp.float32),
                        kq.astype(jnp.float32)) / jnp.sqrt(d).astype(jnp.float32)
    if softcap is not None:
        scores = jnp.tanh(scores / softcap) * softcap
    tk = k.shape[1]
    qpos = (jnp.arange(tq, dtype=jnp.int32) if segment_pos is None
            else segment_pos.astype(jnp.int32))
    kpos = jnp.arange(tk, dtype=jnp.int32)
    mask = jnp.ones((tq, tk), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        mask &= qpos[:, None] - kpos[None, :] < window
    scores = jnp.where(mask[None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("hts,hsd->htd", p, vq.astype(jnp.float32)).astype(q.dtype)
