"""CLI for the contract linter: ``python -m repro.analysis``.

Exit status is the gate: 0 when no *new* findings (suppressed and
baselined ones are reported but pass), 1 otherwise. CI runs this with
``--json`` and uploads the report as an artifact.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.analysis.context import default_root
from repro.analysis.findings import save_baseline
from repro.analysis.registry import RULES, all_rule_ids
from repro.analysis.runner import run_analysis

# repo-root/analysis/baseline.json (cli.py lives at src/repro/analysis/)
DEFAULT_BASELINE = Path(__file__).resolve().parents[3] / "analysis" / "baseline.json"

EPILOG = """\
suppression:
  inline   # repro: allow[RULE] <why>      on the flagged line or the line
           above; RULE is a rule id (taxonomy), a sub-check code
           (taxonomy.broad-except), a comma list, or *.
  baseline analysis/baseline.json          fingerprints of grandfathered
           findings (content-hashed: rule|path|normalized line, so line
           drift does not resurrect them). Refresh with --update-baseline.

exit status: 0 = no new findings, 1 = new findings (or baseline drift).
"""


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static contract linter for the SpGEMM stack: "
                    "jit-boundary, telemetry-key, taxonomy, span, and env "
                    "discipline (see ROADMAP, 'The analysis layer').",
        epilog=EPILOG,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    parser.add_argument(
        "--root", type=Path, default=None,
        help="package tree to scan (default: the installed repro package)")
    parser.add_argument(
        "--rules", nargs="+", metavar="RULE", default=None,
        help=f"subset of rules to run (default: all of {all_rule_ids()})")
    parser.add_argument(
        "--json", type=Path, metavar="PATH", default=None,
        help="write the full report as JSON to PATH (CI artifact)")
    parser.add_argument(
        "--baseline", type=Path, default=DEFAULT_BASELINE,
        help="baseline file of grandfathered fingerprints "
             "(default: %(default)s; missing file = empty baseline)")
    parser.add_argument(
        "--update-baseline", action="store_true",
        help="rewrite the baseline to grandfather every current new "
             "finding, then exit 0")
    parser.add_argument(
        "--list-rules", action="store_true",
        help="list registered rules and exit")
    args = parser.parse_args(argv)

    if args.list_rules:
        for rule_id in all_rule_ids():
            print(f"{rule_id:15s} {RULES[rule_id].doc}")
        return 0

    root = args.root if args.root is not None else default_root()
    report = run_analysis(root, rules=args.rules, baseline_path=args.baseline)

    if args.update_baseline:
        args.baseline.parent.mkdir(parents=True, exist_ok=True)
        save_baseline(args.baseline, report.new + report.baselined)
        print(f"baseline updated: {args.baseline} "
              f"({len(report.new) + len(report.baselined)} findings)")
        return 0

    if args.json is not None:
        args.json.parent.mkdir(parents=True, exist_ok=True)
        with open(args.json, "w") as fh:
            json.dump(report.to_dict(), fh, indent=2)
            fh.write("\n")

    for finding in report.new:
        print(finding.render())
    for finding in report.suppressed:
        print(f"{finding.path}:{finding.line}: [{finding.code}] suppressed "
              f"(inline allow)")
    for finding in report.baselined:
        print(f"{finding.path}:{finding.line}: [{finding.code}] baselined")

    counts = (f"{len(report.new)} new, {len(report.suppressed)} suppressed, "
              f"{len(report.baselined)} baselined")
    mods = report.stats.get("modules", 0)
    if report.ok:
        print(f"repro.analysis: OK — {mods} modules, {counts}")
        return 0
    print(f"repro.analysis: FAIL — {mods} modules, {counts}",
          file=sys.stderr)
    return 1


if __name__ == "__main__":
    sys.exit(main())
