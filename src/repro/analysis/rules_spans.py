"""Rule ``span`` — every ``span("...")`` literal is in the taxonomy.

``obs/trace.py`` exports ``SPAN_NAMES``, the fixed span taxonomy that the
ROADMAP table, the flight recorder's ring schema, and the latency
histograms all key on. A free-typed span name creates a series no
dashboard knows about and silently drops out of the phase-latency story.

Sub-checks:

  * ``span.unknown-name`` — a ``span("...")``/``start_span("...")`` call
    whose literal name is not in ``SPAN_NAMES``.
  * ``span.dynamic-name`` — a span call with a non-literal name (can't be
    checked statically; build the name from taxonomy constants instead).
  * ``span.no-registry`` — ``obs/trace.py`` exists but exports no
    ``SPAN_NAMES`` literal (the registry this rule checks against).
"""
from __future__ import annotations

import ast

from repro.analysis.asthelpers import calls_in, dotted, string_value
from repro.analysis.context import TRACE_MODULE, Project
from repro.analysis.findings import Finding
from repro.analysis.registry import rule

RULE = "span"

SPAN_CALLS = {"span", "start_span"}


@rule(RULE, "span name literals come from obs.trace.SPAN_NAMES")
def check(project: Project):
    trace = project.module(TRACE_MODULE)
    names = project.span_names()
    if trace is not None and names is None:
        yield Finding(
            rule=RULE, code=f"{RULE}.no-registry",
            path=TRACE_MODULE, line=1,
            message="obs/trace.py exports no SPAN_NAMES literal",
            hint="add SPAN_NAMES = frozenset({...}) listing the span "
                 "taxonomy (ROADMAP phase table)",
            snippet=trace.snippet(1))
        return
    if names is None:
        return  # no trace module under this root: nothing to check

    for mod in project.modules:
        if mod.rel == TRACE_MODULE:
            continue  # the registry module itself (defines the machinery)
        for call in calls_in(mod.tree):
            last = dotted(call.func).rsplit(".", 1)[-1]
            if last not in SPAN_CALLS or not call.args:
                continue
            value = string_value(call.args[0])
            if value is None:
                yield Finding(
                    rule=RULE, code=f"{RULE}.dynamic-name",
                    path=mod.rel, line=call.lineno,
                    message=f"{last}(...) with a non-literal span name",
                    hint="pass a literal from obs.trace.SPAN_NAMES so the "
                         "taxonomy stays statically checkable",
                    snippet=mod.snippet(call.lineno))
            elif value not in names:
                yield Finding(
                    rule=RULE, code=f"{RULE}.unknown-name",
                    path=mod.rel, line=call.lineno,
                    message=(f"span name '{value}' is not in "
                             f"obs.trace.SPAN_NAMES"),
                    hint="add it to SPAN_NAMES (and the ROADMAP phase "
                         "table) in the same commit, or fix the typo",
                    snippet=mod.snippet(call.lineno))
