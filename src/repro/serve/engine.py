"""Serving engine: batched prefill -> decode with static-shape caches.

The prefill->decode cache handoff pads full-length prefill KV into the
max_len decode buffers (ring-compacting 'local' layers to their window).
A minimal continuous-batching engine for the examples; the dry-run lowers
prefill/decode steps directly via launch/cells.py.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import decode_step, forward, init_cache
from repro.models.layers import AttnCache
from repro.models.model import _cache_len  # noqa: PLC2701 (intra-package)
from repro.models.sharding import NO_SHARDING, ShardingRules


def _pad_attn_cache(prefill_c: AttnCache, kind: str, cfg: ModelConfig,
                    t: int, max_len: int, stacked: bool) -> AttnCache:
    """Place (B, T, Hkv, hd) prefill KV into the (B, S, Hkv, hd) decode
    buffer. Local layers keep the last `window` positions at ring slots
    consistent with absolute positions."""
    s = _cache_len(cfg, kind, max_len)
    k, v = prefill_c.k, prefill_c.v
    t_axis = 2 if stacked else 1

    def place(x):
        if s >= x.shape[t_axis]:
            pad = [(0, 0)] * x.ndim
            pad[t_axis] = (0, s - x.shape[t_axis])
            return jnp.pad(x, pad)
        # ring: keep last s positions; absolute position p -> slot p % s
        start = x.shape[t_axis] - s
        sl = jax.lax.slice_in_dim(x, start, x.shape[t_axis], axis=t_axis)
        shift = start % s  # slot of absolute position `start`
        return jnp.roll(sl, shift, axis=t_axis)

    return AttnCache(k=place(k), v=place(v))


def prefill_to_cache(prefill_caches, cfg: ModelConfig, t: int, max_len: int):
    """Convert forward(return_caches=True) output into decode buffers."""
    out_blocks = []
    for kind, c in zip(cfg.pattern, prefill_caches["blocks"]):
        if isinstance(c, AttnCache):
            out_blocks.append(_pad_attn_cache(c, kind, cfg, t, max_len, True))
        else:
            out_blocks.append(c)  # ssm / rec states are already final
    out_tail = []
    for kind, c in zip(cfg.tail, prefill_caches["tail"]):
        if isinstance(c, AttnCache):
            out_tail.append(_pad_attn_cache(c, kind, cfg, t, max_len, False))
        else:
            out_tail.append(c)
    return {"blocks": out_blocks, "tail": out_tail}


class ServeEngine:
    """Minimal batched serving: prefill a prompt batch, then greedy decode."""

    def __init__(self, params, cfg: ModelConfig,
                 rules: Optional[ShardingRules] = None, mesh=None,
                 max_len: int = 512):
        self.params = params
        self.cfg = cfg
        self.rules = rules or NO_SHARDING
        self.mesh = mesh
        self.max_len = max_len
        self._decode = jax.jit(
            partial(decode_step, cfg=cfg, rules=self.rules, mesh=mesh,
                    max_len=max_len),
            donate_argnums=(1,),
        )

    def prefill(self, tokens: jax.Array):
        """tokens: (B, T). Returns (last_logits, caches, next_pos)."""
        t = tokens.shape[1]
        logits, caches = forward(
            self.params, {"tokens": tokens}, self.cfg, self.rules,
            mesh=self.mesh, return_caches=True, remat=False,
            max_len=self.max_len,
        )
        caches = prefill_to_cache(caches, self.cfg, t, self.max_len)
        return logits[:, -1], caches, t

    def generate(self, prompts: jax.Array, steps: int,
                 temperature: float = 0.0, rng=None):
        """Greedy (or sampled) continuation of a (B, T) prompt batch."""
        last, caches, pos = self.prefill(prompts)
        outs = []
        tok = jnp.argmax(last, axis=-1)[:, None].astype(jnp.int32)
        for i in range(steps):
            outs.append(tok)
            logits, caches = self._decode(
                self.params, caches, tok, jnp.int32(pos + i)
            )
            lg = logits[:, 0]
            if temperature > 0:
                rng, sub = jax.random.split(rng)
                tok = jax.random.categorical(sub, lg / temperature)[:, None]
                tok = tok.astype(jnp.int32)
            else:
                tok = jnp.argmax(lg, axis=-1)[:, None].astype(jnp.int32)
        return jnp.concatenate(outs, axis=1)
