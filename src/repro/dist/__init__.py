"""repro.dist — the sharded two-phase SpGEMM subsystem.

The paper's Reuse case pays off when symbolic structures are reused across
numeric calls; Buluç & Gilbert (arXiv:1006.2183) and Azad et al.
(arXiv:1510.00844) show SpGEMM only reaches scale when that node-level
kernel composes with a distributed decomposition. This package is that
composition: the full plan lifecycle lifted onto a JAX mesh.

    ShardedPlan          — stacked per-shard SpgemmPlan, uniform bucketed
                           caps, pinned value-routing perms (plan.py)
    build_sharded_plan   — one sharded symbolic pass + one host cap-sync
    ShardedReuseExecutor — pin per-shard plans once, replay numeric under
                           shard_map as ONE dispatch; apply_batched vmaps
                           stacked values across the mesh (executor.py)
    sharded_spgemm       — the entry point behind spgemm(..., mesh=...)
    dist_plan_key        — mesh-aware cache key: (structure, S, placement)
    default_dist_plan_cache — bytes-bounded LRU of sharded plans

B placements (see core/distributed.py, the partitioning/halo layer):
``replicated`` trades memory for zero communication — the right default
when B fits every device (the paper notes each row of B is read ~delta_A
times). ``allgather`` row-shards B and pays one all-gather per replay —
but only of *values*: the structure all-gather and concat are hoisted to
plan-build time, which is what makes pinning a sharded plan worthwhile for
serving loops. Pin a sharded plan whenever the same structure replays more
than a handful of times per mesh (multigrid V-cycles, graph analytics with
changing weights); for one-shot multiplies ``distributed_spgemm`` is
simpler and equally fast.

Also here: compressed collectives for bandwidth-bound exchanges
(collectives.py) and GPipe-style pipeline parallelism (pipeline.py) — the
communication substrate the scaled system runs on.
"""
from repro.dist.collectives import (
    compressed_psum,
    dequantize_int8,
    quantize_int8,
    topk_compress,
    topk_decompress,
)
from repro.dist.executor import ShardedReuseExecutor, sharded_spgemm
from repro.dist.pipeline import pipeline_forward
from repro.dist.plan import (
    B_PLACEMENTS,
    ShardedPlan,
    build_sharded_plan,
    dist_expand_and_sort,
)
from repro.dist.plan_cache import (
    DEFAULT_DIST_CACHE_BYTES,
    default_dist_plan_cache,
    dist_plan_key,
)

__all__ = [
    "B_PLACEMENTS",
    "ShardedPlan",
    "ShardedReuseExecutor",
    "build_sharded_plan",
    "dist_expand_and_sort",
    "sharded_spgemm",
    "dist_plan_key",
    "default_dist_plan_cache",
    "DEFAULT_DIST_CACHE_BYTES",
    "compressed_psum",
    "quantize_int8",
    "dequantize_int8",
    "topk_compress",
    "topk_decompress",
    "pipeline_forward",
]
