"""Pallas TPU kernels: the paper's §3.1.2 linear-probing hash accumulator.

KKLP position (``core.meta.choose_kernel`` -> "flat_lp"): for flop-heavy rows
the dense accumulator's O(k) zero/scan per row loses to a hash table sized by
the row's *output*, not the column space. Two kernels share the LP discipline:

``spgemm_lp``
    Gustavson numeric phase over ELL operands, one C row per outer grid step
    (grid ``(m, rA)`` — rows tiled over grid steps, exactly the partitioning
    of ``spgemm_numeric``). The accumulator is the paper's two-level scheme
    in VMEM scratch: an L1 linear-probing table with the 50% max-occupancy
    rule (new keys are rejected past the cutoff while existing keys still
    accumulate) and an L2 table sized to hold every spill (the MAXRF
    guarantee the memory pool gives the paper's CHUNKSIZE). The semantic
    oracle is ``core.accumulators.accumulate_row(kind="lp")``: the kernel
    replays the exact insert stream (row-major over A slots, then B slots)
    with the same occupancy cutoff and the same f32 adds, so its output is
    **bitwise** the oracle's merged L1+L2 extraction — including rows that
    spill.

``lp_reuse`` / ``lp_reuse_arrays``
    The Reuse-case replay (same contract as ``kernels.segsum_reuse``) with
    the in-tile reduction done through an LP table instead of the direct
    one-hot window matmul: products of an FM-tile hash their segment offsets
    into a scratch table, and the table is flushed into the tile's output
    window with one one-hot matmul. The table is sized at 2x the tile (the
    MAXRF bound of a tile), so the 50% rule never spills here — this variant
    exists to make the accumulator trade-off *measurable* on the replay hot
    loop (``benchmarks.run bench_accumulators``), not to win it everywhere.

Probe-loop totality: the probe is evaluated as a vectorized argmin over probe
distance (first empty-or-matching slot in cyclic order), so a full table
cannot hang the kernel — an unservable insert simply resolves to a rejected
candidate and spills, mirroring the clamped-cutoff fix in
``core.accumulators.lp_insert``.

Precision: tables accumulate in f32 and the result is cast to
``result_type(a, b)`` — f64/int operands belong on the XLA fallback, which is
what ``kernels.ops.numeric_values`` and ``ReuseExecutor`` route them to.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.accumulators import MAX_OCCUPANCY
from repro.kernels.segsum_reuse import LANES, _gather_row, _pad_to
from repro.kernels.spgemm_numeric import _pad_width

# products per grid step of the LP replay kernel (lane-aligned); its scratch
# table is 2x this, so in-tile occupancy can never exceed the 50% cutoff
LP_TILE = 128


def _next_pow2(x: int) -> int:
    # deliberately NOT core.meta.round_capacity("pow2"): table sizes are a
    # hash invariant (the & mask needs a power of two) and must not follow
    # the tunable capacity-bucketing policy, even though the numbers
    # coincide today
    return 1 << (max(int(x), 1) - 1).bit_length()


def default_l1_size(r_c: int) -> int:
    """Default L1 table size for an rC-wide output: next pow2 >= 2*rC, which
    the 50% max-occupancy rule can never spill. Exposed so tests build their
    oracle with the same size the kernel actually uses."""
    return _next_pow2(max(2 * r_c, 8))


def _lp_probe(ids: jax.Array, key: jax.Array):
    """First slot from hash(key), cyclically, that is empty (-1) or holds
    ``key`` — the linear probe, evaluated without a data-dependent loop.

    Probing order is increasing cyclic distance from the hash slot, and the
    probe stops at the first empty-or-match slot; that slot is exactly the
    minimum-distance candidate, so one vectorized argmin replaces the while
    loop (and is total even when the table has no candidate at all).
    Returns (slot, key_already_present).
    """
    size = ids.shape[0]
    mask = size - 1
    h = key & mask
    dist = (jax.lax.iota(jnp.int32, size) - h) & mask
    cand = (ids == -1) | (ids == key)
    p = jnp.argmin(jnp.where(cand, dist, size)).astype(jnp.int32)
    id_at_p = jnp.sum(jnp.where(jax.lax.iota(jnp.int32, size) == p, ids, 0))
    return p, id_at_p == key


# --------------------------------------------------------------------------
# Gustavson numeric phase (the KKLP kernel proper)
# --------------------------------------------------------------------------


def _kernel(a_idx_ref, a_nnz_ref, b_nnz_ref, c_nnz_ref,  # scalar prefetch
            a_val_ref, b_idx_ref, b_val_ref, c_idx_ref,  # VMEM inputs
            out_ref,  # VMEM output (1, rC)
            l1_ids_ref, l1_val_ref, l2_ids_ref, l2_val_ref,  # VMEM scratch
            used_ref):  # SMEM scratch (1,) — L1 occupancy counter
    i = pl.program_id(0)
    r = pl.program_id(1)
    n_r = pl.num_programs(1)
    s1 = l1_ids_ref.shape[1]
    s2 = l2_ids_ref.shape[1]
    r_b = b_idx_ref.shape[1]
    r_c = out_ref.shape[1]
    # the paper's 50% rule, clamped so an empty sentinel always survives —
    # same formula as the (fixed) core.accumulators.lp_insert oracle
    cutoff = min(int(s1 * MAX_OCCUPANCY), s1 - 1)

    @pl.when(r == 0)
    def _reset():
        l1_ids_ref[...] = jnp.full_like(l1_ids_ref, -1)
        l1_val_ref[...] = jnp.zeros_like(l1_val_ref)
        l2_ids_ref[...] = jnp.full_like(l2_ids_ref, -1)
        l2_val_ref[...] = jnp.zeros_like(l2_val_ref)
        used_ref[0] = 0

    live_a = r < a_nnz_ref[i]
    n_live_b = jnp.where(live_a, b_nnz_ref[a_idx_ref[i, r]], 0)
    a_val = a_val_ref[0, r].astype(jnp.float32)
    cols = b_idx_ref[0, :]  # (rB,) — the B row steered by a_idx[i, r]
    prods = a_val * b_val_ref[0, :].astype(jnp.float32)  # (rB,)

    def insert(t, used):
        key = jax.lax.dynamic_index_in_dim(cols, t, keepdims=False)
        val = jax.lax.dynamic_index_in_dim(prods, t, keepdims=False)
        ok = t < n_live_b  # padded B slots must not mint phantom keys
        ids1 = l1_ids_ref[0, :]
        p1, found1 = _lp_probe(ids1, key)
        accept = found1 | (used < cutoff)
        upd1 = (jax.lax.iota(jnp.int32, s1) == p1) & ok & accept
        l1_ids_ref[0, :] = jnp.where(upd1, key, ids1)
        l1_val_ref[0, :] = l1_val_ref[0, :] + jnp.where(upd1, val, 0.0)
        # rejected new keys spill to L2 (sized for every spill: no cutoff)
        spill = ok & ~accept
        ids2 = l2_ids_ref[0, :]
        p2, _ = _lp_probe(ids2, key)
        upd2 = (jax.lax.iota(jnp.int32, s2) == p2) & spill
        l2_ids_ref[0, :] = jnp.where(upd2, key, ids2)
        l2_val_ref[0, :] = l2_val_ref[0, :] + jnp.where(upd2, val, 0.0)
        return used + (ok & accept & ~found1).astype(jnp.int32)

    used_ref[0] = jax.lax.fori_loop(0, r_b, insert, used_ref[0])

    @pl.when(r == n_r - 1)
    def _emit():
        c_cols = c_idx_ref[0, :]  # (rC,)
        eq1 = l1_ids_ref[0, :][:, None] == c_cols[None, :]  # (s1, rC)
        vals = jnp.sum(jnp.where(eq1, l1_val_ref[0, :][:, None], 0.0), axis=0)
        eq2 = l2_ids_ref[0, :][:, None] == c_cols[None, :]  # (s2, rC)
        vals = vals + jnp.sum(
            jnp.where(eq2, l2_val_ref[0, :][:, None], 0.0), axis=0
        )
        mask = jax.lax.iota(jnp.int32, r_c)[None, :] < c_nnz_ref[i]
        out_ref[...] = jnp.where(mask, vals[None, :], 0.0).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("l1_size", "interpret"))
def spgemm_lp(a_idx, a_val, a_nnz, b_idx, b_val, b_nnz, c_idx, c_nnz, *,
              l1_size: int | None = None, interpret: bool = False) -> jax.Array:
    """LP-hash numeric phase: C values (ELL layout, (m, rC)) at the given
    structure, accumulated through the paper's two-level L1/L2 LP scheme.

    a_idx/a_val: (m, rA) ELL of A; a_nnz: (m,); b_idx/b_val: (n, rB) ELL of B;
    b_nnz: (n,) — live B widths (padded B slots are *masked*, not relied on
    to carry zero values: a phantom key would corrupt table occupancy);
    c_idx: (m, rC) symbolic structure of C; c_nnz: (m,).

    l1_size: L1 table size (power of two). The default sizes L1 at the next
    power of two >= 2*rC, which the 50% rule can never spill; pass a smaller
    size to exercise the spill path. L2 is always sized to hold every
    possible spill (next pow2 >= 2*rC), the MAXRF guarantee.
    """
    m, r_a = a_idx.shape
    n, r_b = b_idx.shape
    r_c = c_idx.shape[1]
    if l1_size is None:
        l1_size = default_l1_size(r_c)
    if l1_size & (l1_size - 1) or l1_size < 2:
        from repro.runtime.validate import SpgemmConfigError  # cycle-free
        raise SpgemmConfigError(
            f"l1_size must be a power of two >= 2; got {l1_size}")
    s2 = default_l1_size(r_c)  # L2 holds every possible spill (MAXRF)
    out_dtype = jnp.result_type(a_val, b_val)

    grid = (m, r_a)
    out = pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, r_a), lambda i, r, ai, an, bn, cn: (i, 0)),
                pl.BlockSpec((1, r_b), lambda i, r, ai, an, bn, cn: (ai[i, r], 0)),
                pl.BlockSpec((1, r_b), lambda i, r, ai, an, bn, cn: (ai[i, r], 0)),
                pl.BlockSpec((1, r_c), lambda i, r, ai, an, bn, cn: (i, 0)),
            ],
            out_specs=pl.BlockSpec((1, r_c), lambda i, r, ai, an, bn, cn: (i, 0)),
            scratch_shapes=[
                pltpu.VMEM((1, l1_size), jnp.int32),
                pltpu.VMEM((1, l1_size), jnp.float32),
                pltpu.VMEM((1, s2), jnp.int32),
                pltpu.VMEM((1, s2), jnp.float32),
                pltpu.SMEM((1,), jnp.int32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((m, r_c), out_dtype),
        interpret=interpret,
    )(a_idx, a_nnz, b_nnz, c_nnz, a_val, b_idx, b_val, c_idx)
    return out


def spgemm_lp_bucketed(a_idx, a_val, a_nnz, b_idx, b_val, b_nnz, c_idx, c_nnz,
                       *, l1_size: int | None = None,
                       pad_policy: str | None = None,
                       interpret: bool = False) -> jax.Array:
    """``spgemm_lp`` with ELL widths rA/rB/rC padded to capacity buckets
    (same contract as ``spgemm_numeric_bucketed``); output sliced back to the
    caller's rC. Padded A slots are masked by ``a_nnz``, padded B slots by
    ``b_nnz``, padded C slots by ``c_nnz``."""
    from repro.core.meta import DEFAULT_PAD_POLICY, round_capacity

    policy = DEFAULT_PAD_POLICY if pad_policy is None else pad_policy
    r_c = c_idx.shape[1]
    a_idx = _pad_width(a_idx, round_capacity(a_idx.shape[1], policy))
    a_val = _pad_width(a_val, a_idx.shape[1])
    b_idx = _pad_width(b_idx, round_capacity(b_idx.shape[1], policy))
    b_val = _pad_width(b_val, b_idx.shape[1])
    c_idx_p = _pad_width(c_idx, round_capacity(r_c, policy))
    out = spgemm_lp(a_idx, a_val, a_nnz, b_idx, b_val, b_nnz, c_idx_p, c_nnz,
                    l1_size=l1_size, interpret=interpret)
    return out[:, :r_c]


# --------------------------------------------------------------------------
# Reuse-case replay through the LP accumulator
# --------------------------------------------------------------------------


def _reuse_kernel(a_val_ref, b_val_ref, a_slot_ref, b_slot_ref, seg_ref,
                  out_ref, ids_ref, val_ref):
    step = pl.program_id(0)
    fm_t = a_slot_ref.shape[1]
    s1 = ids_ref.shape[1]
    win = fm_t + LANES
    nnz_cap = out_ref.shape[1] - win  # wrapper pads the output by one window

    @pl.when(step == 0)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    # fresh table per tile: the tile's segments are its whole key space
    ids_ref[...] = jnp.full_like(ids_ref, -1)
    val_ref[...] = jnp.zeros_like(val_ref)

    segs = seg_ref[0, :]  # (fm_t,) non-decreasing; sentinel nnz_cap on pad
    prod = _gather_row(a_val_ref, a_slot_ref[0, :]) * _gather_row(
        b_val_ref, b_slot_ref[0, :]
    )  # (1, fm_t)
    live = segs < nnz_cap
    # sortedness: live segments of a tile land in a contiguous window of
    # width <= fm_t; align its start down to a lane group (as segsum_reuse)
    base = (segs[0] // LANES) * LANES
    local = segs - base  # live keys in [0, win)
    prod_v = prod[0, :]

    def insert(t, _):
        key = jax.lax.dynamic_index_in_dim(local, t, keepdims=False)
        val = jax.lax.dynamic_index_in_dim(prod_v, t, keepdims=False)
        ok = jax.lax.dynamic_index_in_dim(live, t, keepdims=False)
        ids = ids_ref[0, :]
        p, _found = _lp_probe(ids, key)
        # table is 2x the tile: distinct keys <= fm_t == the 50% cutoff, so
        # every live insert is accepted (in-tile MAXRF bound)
        upd = (jax.lax.iota(jnp.int32, s1) == p) & ok
        ids_ref[0, :] = jnp.where(upd, key, ids)
        val_ref[0, :] = val_ref[0, :] + jnp.where(upd, val, 0.0)
        return 0

    jax.lax.fori_loop(0, fm_t, insert, 0)

    # flush the table into the tile's output window with one one-hot matmul
    eq = ids_ref[0, :][:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (s1, win), 1
    )  # (s1, win); empty slots (-1) match nothing
    window = jnp.sum(jnp.where(eq, val_ref[0, :][:, None], 0.0), axis=0)[None, :]

    cur = pl.load(out_ref, (slice(None), pl.dslice(base, win)))
    pl.store(
        out_ref,
        (slice(None), pl.dslice(base, win)),
        cur + window.astype(out_ref.dtype),
    )


@functools.partial(jax.jit, static_argnames=("nnz_cap", "interpret"))
def lp_reuse_arrays(a_slot_s, b_slot_s, seg_ids, a_values, b_values, *,
                    nnz_cap: int, interpret: bool = False) -> jax.Array:
    """LP-accumulator replay on raw plan arrays. Returns (nnz_cap,) C values.

    Same contract as ``segsum_reuse_arrays`` (sorted product order, sentinel
    ``seg_ids == nnz_cap`` on padding, f32 accumulation cast to
    ``result_type(a, b)``) — only the in-tile reduction differs.
    """
    from repro.kernels.segsum_reuse import VAL_TILE

    out_dtype = jnp.result_type(a_values, b_values)
    fm_cap = a_slot_s.shape[0]
    fm_pad = -(-fm_cap // LP_TILE) * LP_TILE
    a_slot_s = _pad_to(a_slot_s.astype(jnp.int32), fm_pad)[None, :]
    b_slot_s = _pad_to(b_slot_s.astype(jnp.int32), fm_pad)[None, :]
    seg_ids = _pad_to(seg_ids.astype(jnp.int32), fm_pad, fill=nnz_cap)[None, :]
    na = -(-a_values.shape[0] // VAL_TILE) * VAL_TILE
    nb = -(-b_values.shape[0] // VAL_TILE) * VAL_TILE
    a_values = _pad_to(a_values, na)[None, :]
    b_values = _pad_to(b_values, nb)[None, :]

    s1 = _next_pow2(2 * LP_TILE)
    grid = (fm_pad // LP_TILE,)
    out = pl.pallas_call(
        _reuse_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, na), lambda t: (0, 0)),
            pl.BlockSpec((1, nb), lambda t: (0, 0)),
            pl.BlockSpec((1, LP_TILE), lambda t: (0, t)),
            pl.BlockSpec((1, LP_TILE), lambda t: (0, t)),
            pl.BlockSpec((1, LP_TILE), lambda t: (0, t)),
        ],
        out_specs=pl.BlockSpec((1, nnz_cap + LP_TILE + LANES), lambda t: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, nnz_cap + LP_TILE + LANES), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((1, s1), jnp.int32),
            pltpu.VMEM((1, s1), jnp.float32),
        ],
        interpret=interpret,
    )(a_values, b_values, a_slot_s, b_slot_s, seg_ids)
    return out[0, :nnz_cap].astype(out_dtype)


def lp_reuse(plan, a_values, b_values, *, interpret: bool = False) -> jax.Array:
    """Replay a ``SpgemmPlan`` numerically through the LP-hash accumulator.

    Same structure contract as ``core.spgemm.numeric_reuse`` / ``segsum_reuse``
    but with hash-table in-tile accumulation — select it through
    ``ReuseExecutor(..., backend="pallas_lp")`` or ``spgemm(method="lp")``.
    f32 accumulation: f64/int operands belong on the XLA path.
    """
    return lp_reuse_arrays(
        plan.a_slot_s, plan.b_slot_s, plan.seg_ids, a_values, b_values,
        nnz_cap=plan.indices.shape[0], interpret=interpret,
    )
