"""Overload-safe SpGEMM request serving over the executor stack.

The paper's two-phase split is the shape of a serving workload: millions of
requests whose *structures* repeat, so the symbolic phase is paid once per
structure and every request replays a pinned plan. ``SparseService`` is that
workload's front door, built so its headline property is *graceful behavior
at and past saturation*:

  * **Bounded admission queue with backpressure.** ``submit`` never queues
    unboundedly: a full queue rejects with typed ``AdmissionRejected``.
    Deadline-aware load shedding happens at both ends — admission refuses a
    request whose deadline is infeasible given the measured backlog
    (``AdmissionRejected``), and the batch loop sheds queued requests whose
    deadline expired before dispatch (``DeadlineExceeded``). Every request
    gets a typed verdict; nothing is silently dropped.
  * **Validation at the door.** Operands are checked with
    ``runtime.validate.check_csr`` (default ``validate="host"``) at
    admission, so one corrupt request is rejected before it can poison a
    batched dispatch shared with healthy requests.
  * **Grouped dispatch over pinned plans.** Admitted requests are grouped by
    ``structure_key`` + operand dtypes (one hash per request, paid at
    admission); each group replays a pinned ``ReuseExecutor`` plan — one
    ``apply_batched`` dispatch per multi-request group, one ``apply`` per
    singleton — with plans resolved through the plan cache so repeated
    structures never re-expand. The batch loop handles the empty tick
    explicitly (an all-shed batch dispatches nothing).
  * **Per-kernel circuit breaker** (``serve.breaker``) on top of the PR-7
    degradation ladder: the ladder keeps a faulting fast kernel *correct*
    (bitwise XLA fallback), the breaker keeps it *cheap* — repeated
    ``fault:*`` fallbacks open the breaker and subsequent singleton traffic
    routes straight to the recorded-safe XLA kernel; after a cooldown a
    half-open probe re-admits the fast path. Transitions land in
    ``telemetry.BREAKER_COUNTS``. Batched groups always use the vmapped XLA
    formulation (one fused dispatch is the point of batching), so the
    breaker governs singleton dispatches only.
  * **Watchdog + retry.** Every group dispatch runs under a shared
    ``StepWatchdog`` and ``runtime.retry.retry_call`` (label
    ``serve.dispatch`` in ``telemetry.RETRY_COUNTS``): transient failures —
    stragglers, injected chaos — are retried with bounded backoff;
    deterministic typed errors fail the group immediately; exhaustion is a
    typed ``RetryExhaustedError`` on every response in the group.
  * **Plan-cache warming** (``serve.warmer``): the service logs the
    structures it serves (zero extra hashes — the admission key is reused)
    and ``warm()`` prefetches the hottest plans; eviction mid-stream is
    tolerated everywhere (``resolve_plan`` transparently rebuilds, pinned
    executors keep their plans regardless).

Single-threaded by design: ``submit`` enqueues, ``step`` pumps one batch,
``drain`` runs until empty. Determinism is the chaos suite's foundation —
the clock is injectable, retry backoff is seeded, and there is no hidden
thread to race a failpoint. A driver loop (or ``bench_serve``) provides the
concurrency story by interleaving submits and steps.
"""
from __future__ import annotations

import dataclasses
import math
import time
from collections import OrderedDict
from typing import Callable

import jax.numpy as jnp

from repro.core.executor import BACKENDS, ReuseExecutor
from repro.core.meta import DEFAULT_PAD_POLICY
from repro.core.plan_cache import PlanCache, structure_key
from repro.core.spgemm import prepare_sparse_inputs, resolve_plan
from repro.obs import metrics as obs_metrics
from repro.obs import recorder as obs_recorder
from repro.obs import trace as obs_trace
from repro.runtime.retry import retry_call
from repro.runtime.validate import (AdmissionRejected, DeadlineExceeded,
                                    SpgemmConfigError,
                                    KernelFallbackError, SpgemmError,
                                    check_csr, resolve_mode)
from repro.runtime.watchdog import StepWatchdog
from repro.serve.breaker import CircuitBreaker
from repro.serve.warmer import TrafficLog, warm_plan_cache
from repro.sparse.formats import CSR

RETRY_LABEL = "serve.dispatch"


@dataclasses.dataclass
class SparseResponse:
    """The service's promise for one request; filled by the batch loop.

    Exactly one of ``value`` (a CSR product) / ``error`` (a typed
    ``SpgemmError``) is set once ``done``. ``backend``/``group_size``/
    ``degraded`` record how the dispatch ran (None/0/False for rejected
    requests that never dispatched). ``trace_id`` is the request's identity
    in the observability layer: every span the dispatch path opens for this
    request (admission, grouping, plan build, executor dispatch, retries)
    carries it, so an exported Chrome trace can be filtered to one request
    end-to-end.
    """

    request_id: int
    submitted_at: float
    priority: int = 0
    deadline_s: float | None = None
    trace_id: str | None = None
    done: bool = False
    value: CSR | None = None
    error: Exception | None = None
    completed_at: float | None = None
    backend: str | None = None
    group_size: int = 0
    degraded: bool = False

    @property
    def ok(self) -> bool:
        return self.done and self.error is None

    @property
    def latency_s(self) -> float | None:
        if self.completed_at is None:
            return None
        return self.completed_at - self.submitted_at


@dataclasses.dataclass
class _Pending:
    """An admitted request waiting in the queue (operands already prepared
    and structure-hashed at admission)."""

    seq: int
    a: CSR  # prepared (capacity-bucketed) operands
    b: CSR
    fm_cap: int
    skey: str
    priority: int
    deadline: float | None  # absolute, on the service clock
    response: SparseResponse


class SparseService:
    """Bounded-queue, deadline-aware SpGEMM serving over pinned plans.

    backend: the fast replay path for singleton dispatches ("auto" resolves
        to "xla"; "pallas"/"pallas_lp" opt into the Pallas kernels, guarded
        by a per-kernel circuit breaker). Batched groups always take the
        vmapped XLA formulation.
    validate: admission-time operand validation mode (default "host" — the
        serving tier rejects corruption at the door; "off" is the caller's
        risk).
    max_queue / max_batch: admission bound (backpressure past it) and the
        largest request count one ``step`` dispatches.
    plan_cache: the structure-keyed plan LRU (default: a private
        ``PlanCache(name="serve")``); ``warm()`` prefetches into it.
    max_executors: LRU bound on pinned per-structure executors (each pins
        plan arrays on device — the cache must not hoard them).
    retries: transient-failure retries per group dispatch (via
        ``retry_call``; deterministic typed errors never retry).
    watchdog: a ``StepWatchdog`` for dispatch deadlines (default: 60 s,
        policy "warn" — a straggling replay is recorded, not killed; pass
        policy="raise" to convert stragglers into retried failures).
    breaker_*: circuit-breaker tuning for the fast kernel (threshold within
        a sliding window; cooldown before the half-open probe).
    clock: injectable monotonic clock (tests/chaos drive deadlines and
        cooldowns deterministically).
    """

    def __init__(self, *, backend: str = "auto", validate: str | None = "host",
                 max_queue: int = 256, max_batch: int = 16,
                 pad_policy: str | None = None, plan_cache: PlanCache | None = None,
                 max_executors: int = 32, retries: int = 1,
                 retry_base_delay_s: float = 0.01,
                 watchdog: StepWatchdog | None = None,
                 breaker_threshold: int = 3, breaker_window_s: float = 30.0,
                 breaker_cooldown_s: float = 5.0,
                 interpret: bool | None = None,
                 admission_slack: float = 1.0,
                 clock: Callable[[], float] = time.monotonic,
                 sleep: Callable[[float], None] = time.sleep,
                 traffic_log: TrafficLog | None = None):
        if backend not in BACKENDS:
            raise SpgemmConfigError(
                f"unknown backend {backend!r}; expected one of {BACKENDS}")
        if max_queue < 1 or max_batch < 1:
            raise SpgemmConfigError(
                f"max_queue and max_batch must be >= 1, got "
                f"max_queue={max_queue}, max_batch={max_batch}")
        self.fast_backend = "xla" if backend == "auto" else backend
        self.validate_mode = resolve_mode(validate)
        self.max_queue = max_queue
        self.max_batch = max_batch
        self.pad_policy = DEFAULT_PAD_POLICY if pad_policy is None else pad_policy
        self.plan_cache = (PlanCache(capacity=32, name="serve")
                           if plan_cache is None else plan_cache)
        self.max_executors = max_executors
        self.retries = retries
        self.retry_base_delay_s = retry_base_delay_s
        self.watchdog = watchdog or StepWatchdog(deadline_s=60.0, policy="warn")
        self.interpret = interpret
        self.admission_slack = admission_slack
        self.clock = clock
        self._sleep = sleep
        self.traffic_log = TrafficLog(self.pad_policy) if traffic_log is None \
            else traffic_log
        self._breakers: dict[str, CircuitBreaker] = {}
        if self.fast_backend != "xla":
            self._breakers[self.fast_backend] = CircuitBreaker(
                self.fast_backend, failure_threshold=breaker_threshold,
                window_s=breaker_window_s, cooldown_s=breaker_cooldown_s,
                clock=clock)
        self._queue: list[_Pending] = []
        self._executors: OrderedDict[str, ReuseExecutor] = OrderedDict()
        self._seq = 0
        # Per-service latency distributions (PR 9): "serve.step" (batch-loop
        # tick) and "serve.request" (admission->completion). The step
        # histogram's median replaces the old single-EWMA wait estimator;
        # step_hint_s seeds the estimator before the first step lands (and is
        # what tests/benchmarks set to pin admission behavior).
        self.metrics = obs_metrics.MetricsRegistry(name="serve")
        self.step_hint_s: float | None = None
        self.counters = {
            "submitted": 0, "admitted": 0, "completed": 0, "failed": 0,
            "shed_queue_full": 0, "shed_deadline_infeasible": 0,
            "shed_deadline_expired": 0, "rejected_validation": 0,
            "steps": 0, "group_dispatches": 0, "degraded_dispatches": 0,
        }

    # ------------------------------------------------------------------
    # Admission
    # ------------------------------------------------------------------

    @property
    def queue_depth(self) -> int:
        return len(self._queue)

    def _est_step_s(self) -> float | None:
        """Current step-latency estimate: the measured ``serve.step``
        histogram's median once real steps landed, else ``step_hint_s``
        (a caller-provided seed), else None (no information yet)."""
        h = self.metrics.histogram("serve.step")
        if h.count > 0:
            return h.percentile(50.0)
        return self.step_hint_s

    def _est_wait_s(self) -> float:
        """Predicted queue wait for a request admitted right now: estimated
        step latency x the number of batch ticks ahead of it. Zero until the
        first step lands (an idle service admits everything)."""
        est = self._est_step_s()
        if est is None:
            return 0.0
        ticks = math.ceil((len(self._queue) + 1) / self.max_batch)
        return ticks * est

    def _reject(self, resp: SparseResponse, err: SpgemmError,
                reason: str) -> SparseResponse:
        resp.done = True
        resp.error = err
        resp.completed_at = self.clock()
        self.counters[reason] += 1
        return resp

    def submit(self, a: CSR, b: CSR, *, deadline_s: float | None = None,
               priority: int = 0) -> SparseResponse:
        """Offer one multiply to the service; returns its response promise.

        Rejections complete the response immediately with a typed error
        (``AdmissionRejected`` for backpressure/infeasible deadlines, the
        validation taxonomy for corrupt operands) — ``submit`` itself never
        raises for per-request conditions, so a driver loop handles mixed
        outcomes uniformly.
        """
        now = self.clock()
        resp = SparseResponse(request_id=self._seq, submitted_at=now,
                              priority=priority, deadline_s=deadline_s,
                              trace_id=f"req-{self._seq}")
        self._seq += 1
        self.counters["submitted"] += 1
        if not obs_trace.enabled():
            return self._admit(a, b, resp, deadline_s, now)
        with obs_trace.trace_context(resp.trace_id):
            with obs_trace.span("serve.admit", request_id=resp.request_id):
                return self._admit(a, b, resp, deadline_s, now)

    def _admit(self, a: CSR, b: CSR, resp: SparseResponse,
               deadline_s: float | None, now: float) -> SparseResponse:
        """Admission proper (validation, prep, feasibility, enqueue) — split
        out of ``submit`` so tracing can wrap it without touching it."""
        if len(self._queue) >= self.max_queue:
            return self._reject(resp, AdmissionRejected(
                f"admission queue full ({self.max_queue} pending): "
                f"backpressure — shed upstream or retry later"),
                "shed_queue_full")
        if self.validate_mode != "off":
            try:
                check_csr(a, self.validate_mode, name="A")
                check_csr(b, self.validate_mode, name="B")
            except SpgemmError as e:
                return self._reject(resp, e, "rejected_validation")
        try:
            pa, pb, _, _, fm_cap = prepare_sparse_inputs(a, b, self.pad_policy)
        except SpgemmError as e:  # e.g. CapacityOverflowError from repad
            return self._reject(resp, e, "rejected_validation")
        if deadline_s is not None:
            est = self._est_wait_s() * self.admission_slack
            if est > deadline_s:
                return self._reject(resp, AdmissionRejected(
                    f"deadline {deadline_s:.4f}s infeasible: estimated "
                    f"queue wait {est:.4f}s at depth {len(self._queue)}"),
                    "shed_deadline_infeasible")
        skey = structure_key(pa, pb, fm_cap, self.pad_policy)
        self.traffic_log.record_prepared(skey, pa, pb, fm_cap)
        self._queue.append(_Pending(
            seq=resp.request_id, a=pa, b=pb, fm_cap=fm_cap, skey=skey,
            priority=resp.priority,
            deadline=None if deadline_s is None else now + deadline_s,
            response=resp))
        self.counters["admitted"] += 1
        return resp

    # ------------------------------------------------------------------
    # Batch loop
    # ------------------------------------------------------------------

    def _finish(self, p: _Pending, *, value: CSR | None = None,
                error: Exception | None = None, backend: str | None = None,
                group_size: int = 0, degraded: bool = False) -> None:
        r = p.response
        r.done = True
        r.value = value
        r.error = error
        r.completed_at = self.clock()
        r.backend = backend
        r.group_size = group_size
        r.degraded = degraded
        if error is None:
            self.counters["completed"] += 1
            self.metrics.observe("serve.request", r.latency_s)
        else:
            self.counters["failed"] += 1

    def _executor_for(self, p: _Pending) -> ReuseExecutor:
        """Pinned executor for one structure (LRU-bounded). A plan-cache
        eviction between steps is invisible here: an already-pinned executor
        keeps its plan, and a missing entry is transparently rebuilt by
        ``resolve_plan``."""
        ex = self._executors.get(p.skey)
        if ex is not None:
            self._executors.move_to_end(p.skey)
            return ex
        plan, _, _ = resolve_plan(p.a, p.b, p.fm_cap, self.pad_policy,
                                  self.plan_cache, key=p.skey)
        ex = ReuseExecutor(plan, backend="auto", interpret=self.interpret,
                           watchdog=self.watchdog,
                           on_kernel_failure="fallback")
        self._executors[p.skey] = ex
        while len(self._executors) > self.max_executors:
            self._executors.popitem(last=False)
        return ex

    def _dispatch_group(self, items: list[_Pending]) -> None:
        """One structure+dtype group -> ONE device dispatch (plus ladder /
        retry re-dispatches), under breaker routing for singletons.

        Tracing: the group dispatch runs under the requests' trace IDs
        (``trace_context``), so the nested ``plan.build`` /
        ``numeric.dispatch`` / retry spans — and the flight-recorder events
        they leave — are attributable to the admitted requests end-to-end.
        """
        if not obs_trace.enabled():
            return self._dispatch_group_inner(items, None)
        tids = [p.response.trace_id for p in items]
        with obs_trace.trace_context(
                tids[0] if len(tids) == 1 else "+".join(tids)):
            with obs_trace.span("serve.dispatch", group=len(items),
                                structure_key=items[0].skey) as sp:
                return self._dispatch_group_inner(items, sp)

    def _dispatch_group_inner(self, items: list[_Pending], sp) -> None:
        ex = self._executor_for(items[0])
        breaker = None
        backend = "xla"
        if len(items) == 1 and self.fast_backend != "xla":
            breaker = self._breakers[self.fast_backend]
            backend = self.fast_backend if breaker.allow() else "xla"
        took_fast = breaker is not None and backend == self.fast_backend
        ex.backend = backend
        ex.kernel_source = "static"
        if sp is not None:
            sp.set("kernel", backend)

        def dispatch():
            if len(items) == 1:
                p = items[0]
                return [ex.apply(p.a.values, p.b.values)]
            a_stack = jnp.stack([p.a.values for p in items])
            b_stack = jnp.stack([p.b.values for p in items])
            out = ex.apply_batched(a_stack, b_stack)
            return [out[i] for i in range(len(items))]

        self.counters["group_dispatches"] += 1
        try:
            vals = retry_call(dispatch, retries=self.retries,
                              base_delay_s=self.retry_base_delay_s,
                              label=RETRY_LABEL, sleep=self._sleep)
        except SpgemmError as e:
            if took_fast:
                breaker.record_failure()  # a raising fast path counts too
            for p in items:
                self._finish(p, error=e, backend=backend,
                             group_size=len(items))
            return
        except Exception as e:  # non-taxonomy leak: wrap typed, never bare
            err = KernelFallbackError(
                f"group dispatch failed outside the taxonomy: {e!r}")
            err.__cause__ = e
            if took_fast:
                breaker.record_failure()
            for p in items:
                self._finish(p, error=err, backend=backend,
                             group_size=len(items))
            return
        degraded = ex.kernel_source == "fallback"
        if degraded:
            self.counters["degraded_dispatches"] += 1
            if sp is not None:
                sp.set("fallback", f"{backend}->xla")
        if took_fast:
            (breaker.record_failure if degraded
             else breaker.record_success)()
        for p, v in zip(items, vals):
            self._finish(p, value=ex.to_csr(v), backend=backend,
                         group_size=len(items), degraded=degraded)

    def step(self) -> int:
        """Pump one batch: shed expired requests, group up to ``max_batch``
        admitted ones by structure+dtype, one dispatch per group. Returns
        the number of responses resolved (completions + sheds)."""
        self.counters["steps"] += 1
        now = self.clock()
        resolved = 0
        # priority order, FIFO within a priority level
        self._queue.sort(key=lambda p: (-p.priority, p.seq))
        batch: list[_Pending] = []
        rest: list[_Pending] = []
        for p in self._queue:
            if p.deadline is not None and now > p.deadline:
                self._finish(p, error=DeadlineExceeded(
                    f"request {p.seq} deadline expired in queue "
                    f"({now - p.deadline:.4f}s past)"))
                self.counters["failed"] -= 1  # reclassify: shed, not failed
                self.counters["shed_deadline_expired"] += 1
                resolved += 1
            elif len(batch) < self.max_batch:
                batch.append(p)
            else:
                rest.append(p)
        self._queue = rest
        if not batch:  # the empty tick: dispatch nothing (cf. spgemm_grouped)
            return resolved
        t0 = self.clock()
        groups: OrderedDict[tuple, list[_Pending]] = OrderedDict()
        for p in batch:
            gkey = (p.skey, str(p.a.values.dtype), str(p.b.values.dtype))
            groups.setdefault(gkey, []).append(p)
        for items in groups.values():
            self._dispatch_group(items)
            resolved += len(items)
        step_s = self.clock() - t0
        self.metrics.observe("serve.step", step_s)
        return resolved

    def drain(self, max_steps: int | None = None) -> int:
        """Run ``step`` until the queue empties (or ``max_steps``); returns
        total responses resolved."""
        total = 0
        steps = 0
        while self._queue and (max_steps is None or steps < max_steps):
            total += self.step()
            steps += 1
        return total

    # ------------------------------------------------------------------
    # Warming + reporting
    # ------------------------------------------------------------------

    def warm(self, log: TrafficLog | None = None,
             limit: int | None = None) -> dict:
        """Prefetch plans for the hottest structures of ``log`` (default:
        the service's own traffic log) into the plan cache."""
        return warm_plan_cache(log or self.traffic_log, self.plan_cache,
                               limit=limit)

    def latency_percentiles(self, qs=(50.0, 99.0)) -> dict[str, float]:
        """{"p50": s, "p99": s, ...} over completed-request latencies (the
        ``serve.request`` histogram — log-bucketed, interpolated)."""
        h = self.metrics.histogram("serve.request")
        return {f"p{q:g}": h.percentile(q) for q in qs}

    def stats(self, debug: bool = False) -> dict:
        """Service counters + distributions (+ forensics with debug=True).

        ``step_latency`` / ``request_latency`` are real histogram summaries
        (count/mean/p50/p95/p99/min/max) — what replaced the old single
        EWMA; ``est_step_s`` is the admission estimator's current value.
        ``debug=True`` additionally dumps the flight recorder (the last-N
        dispatch events — kernels, fallback hops, errors) and the service's
        full metrics snapshot, the first thing to pull on a sick service.
        """
        from repro.core.telemetry import RETRY_COUNTS

        total = self.counters["submitted"]
        shed = (self.counters["shed_queue_full"]
                + self.counters["shed_deadline_infeasible"]
                + self.counters["shed_deadline_expired"])
        out = {
            **self.counters,
            "queue_depth": len(self._queue),
            "executors": len(self._executors),
            "est_step_s": self._est_step_s(),
            "step_latency": self.metrics.histogram("serve.step").summary(),
            "request_latency":
                self.metrics.histogram("serve.request").summary(),
            "shed_rate": (shed / total) if total else 0.0,
            "plan_cache": self.plan_cache.stats(),
            "breakers": {n: b.snapshot() for n, b in self._breakers.items()},
            "retry": {
                "attempts": RETRY_COUNTS[f"{RETRY_LABEL}:attempt"],
                "retries": RETRY_COUNTS[f"{RETRY_LABEL}:retry"],
                "giveups": RETRY_COUNTS[f"{RETRY_LABEL}:giveup"],
            },
        }
        if debug:
            out["flight_recorder"] = obs_recorder.default_recorder().dump(
                reason="stats(debug=True)")
            out["metrics"] = self.metrics.snapshot()
        return out
