"""repro.obs acceptance suite: tracing, histograms, flight recorder.

Two contracts anchor this file (ISSUE "acceptance criteria"):

  * tracing OFF — the pinned-replay hot path is *dispatch-identical* to the
    untraced build: zero added trace/hash counters, zero buffered events,
    zero recorder entries on success (test_tracing_off_is_dispatch_identical);
  * tracing ON — a chaos run through ``SparseService`` exports a valid
    Chrome trace whose spans carry request trace ids end-to-end, per-phase
    histograms report nonzero p50/p99, and the injected kernel failure left
    a flight-recorder trail naming the kernel and its fallback hop
    (test_service_chaos_traced_end_to_end).

Everything else here pins the unit surfaces those two lean on.
"""
import json
import math

import jax.numpy as jnp
import pytest

from repro import obs
from repro.core import telemetry
from repro.core.executor import ReuseExecutor
from repro.core.plan_cache import PlanCache
from repro.core.spgemm import spgemm
from repro.obs.trace import _NOOP, SPAN_NAMES
from repro.runtime import faults
from repro.runtime.watchdog import Heartbeat
from repro.sparse import random_csr


@pytest.fixture
def ab():
    return random_csr(32, 24, 4.0, seed=1), random_csr(24, 40, 4.0, seed=2)


# --------------------------------------------------------------------------
# trace: mode resolution and the $REPRO_TRACE default
# --------------------------------------------------------------------------


def test_resolve_trace_mode_args_and_aliases():
    assert obs.resolve_trace_mode(True) == "on"
    assert obs.resolve_trace_mode(False) == "off"
    for m in obs.TRACE_MODES:
        assert obs.resolve_trace_mode(m) == m
    with pytest.raises(ValueError, match="unknown trace mode"):
        obs.resolve_trace_mode("verbose")


def test_trace_env_default(monkeypatch):
    monkeypatch.delenv(obs.TRACE_ENV_VAR, raising=False)
    assert obs.resolve_trace_mode(None) == "off"
    for raw, want in (("1", "on"), ("true", "on"), ("on", "on"),
                      ("0", "off"), ("false", "off"), ("xprof", "xprof")):
        monkeypatch.setenv(obs.TRACE_ENV_VAR, raw)
        assert obs.resolve_trace_mode(None) == want
    monkeypatch.setenv(obs.TRACE_ENV_VAR, "banana")
    with pytest.raises(ValueError, match="REPRO_TRACE"):
        obs.resolve_trace_mode(None)


def test_env_drives_enabled_lazily(monkeypatch):
    # set_tracing(None) re-defers to the env, resolved on next check
    monkeypatch.setenv(obs.TRACE_ENV_VAR, "on")
    obs.set_tracing(None)
    assert obs.enabled()
    monkeypatch.setenv(obs.TRACE_ENV_VAR, "off")
    obs.set_tracing(None)
    assert not obs.enabled()


# --------------------------------------------------------------------------
# trace: spans
# --------------------------------------------------------------------------


def test_disabled_span_is_the_shared_noop():
    assert not obs.enabled()  # conftest reset -> off
    assert obs.span("plan.build") is _NOOP
    assert obs.trace_context("req-1") is _NOOP
    assert obs.trace_scope(None) is _NOOP
    with obs.span("plan.build", fm_cap=8) as sp:
        sp.set("nnz_cap", 64)  # settable, still a no-op
    assert obs.events() == []


def test_span_records_nesting_and_attrs():
    obs.set_tracing("on")
    with obs.span("outer", method="sparse") as sp:
        sp.set("kernel", "xla")
        with obs.span("inner"):
            pass
    evs = obs.events()
    assert [e["name"] for e in evs] == ["inner", "outer"]  # close order
    inner, outer = evs
    assert inner["depth"] == 1 and outer["depth"] == 0
    assert outer["args"] == {"method": "sparse", "kernel": "xla"}
    assert outer["dur"] >= inner["dur"] >= 0.0


def test_span_records_exception_and_reraises():
    obs.set_tracing("on")
    with pytest.raises(RuntimeError):
        with obs.span("doomed"):
            raise RuntimeError("boom")
    (ev,) = obs.events()
    assert ev["args"]["error"] == "RuntimeError"


def test_span_feeds_phase_and_kernel_histograms():
    obs.set_tracing("on")
    with obs.span("numeric.dispatch", kernel="pallas"):
        pass
    reg = obs.default_registry()
    assert reg.histogram("numeric.dispatch").count == 1
    assert reg.histogram("numeric.dispatch[pallas]").count == 1


def test_trace_scope_restores_ambient_mode():
    assert not obs.enabled()
    with obs.trace_scope("on"):
        assert obs.enabled()
        with obs.span("scoped"):
            pass
    assert not obs.enabled()
    assert [e["name"] for e in obs.events()] == ["scoped"]


def test_trace_context_stamps_and_restores_id():
    obs.set_tracing("on")
    assert obs.current_trace_id() is None
    with obs.trace_context("req-7"):
        assert obs.current_trace_id() == "req-7"
        with obs.span("inside"):
            pass
    assert obs.current_trace_id() is None
    with obs.span("outside"):
        pass
    inside, outside = obs.events()
    assert inside["args"]["trace_id"] == "req-7"
    assert "trace_id" not in outside["args"]


def test_export_chrome_trace_file(tmp_path):
    obs.set_tracing("on")
    with obs.trace_context(obs.new_trace_id("req")):
        with obs.span("plan.build", structure_key="k1"):
            pass
    path = tmp_path / "trace.json"
    payload = obs.export_chrome_trace(str(path))
    loaded = json.loads(path.read_text())
    assert loaded == json.loads(json.dumps(payload))
    (ev,) = loaded["traceEvents"]
    assert ev["ph"] == "X" and ev["cat"] == "repro"
    assert ev["name"] == "plan.build"
    assert isinstance(ev["ts"], (int, float)) and ev["dur"] >= 0
    assert ev["args"]["structure_key"] == "k1"
    assert ev["args"]["trace_id"] == "req-1"
    assert loaded["otherData"]["dropped_events"] == 0


def test_spgemm_trace_kwarg(ab):
    a, b = ab
    cache = PlanCache()  # fresh: the traced call must pay the plan build
    traced = spgemm(a, b, method="sparse", plan_cache=cache, trace=True)
    names = {e["name"] for e in obs.events()}
    # the three single-device phases fired, and every recorded span name
    # comes from the exported taxonomy (no free-typed strings)
    assert {"spgemm.prepare", "plan.build", "numeric.dispatch"} <= names
    assert names <= SPAN_NAMES, names - SPAN_NAMES
    assert not obs.enabled()  # trace=True scoped to the one call
    n_events = len(obs.events())
    res = spgemm(a, b, method="sparse", plan_cache=cache)  # ambient: off
    assert len(obs.events()) == n_events  # added no events
    assert bool(jnp.all(traced.c.values == res.c.values))


# --------------------------------------------------------------------------
# metrics: histograms, gauges, exporters
# --------------------------------------------------------------------------


def test_histogram_percentiles():
    h = obs.Histogram("t")
    assert math.isnan(h.percentile(50.0))
    h.observe(0.004)
    assert h.percentile(50.0) == pytest.approx(0.004)  # single obs: exact
    for _ in range(99):
        h.observe(0.001)
    s = h.summary()
    assert s["count"] == 100
    assert s["p50"] == pytest.approx(0.001, rel=0.5)  # in the 1ms bucket
    assert s["p99"] <= 0.004 and s["p99"] > s["p50"]
    assert s["min"] == 0.001 and s["max"] == 0.004
    assert s["mean"] == pytest.approx(h.sum / 100)


def test_gauge_live_callback():
    reg = obs.MetricsRegistry("t")
    box = {"v": 1.0}
    reg.gauge("box", fn=lambda: box["v"])
    assert reg.snapshot()["gauges"]["box"] == 1.0
    box["v"] = 5.0
    assert reg.snapshot()["gauges"]["box"] == 5.0  # read at export time
    reg.set_gauge("box", 2.0)  # set() unbinds the callback
    box["v"] = 9.0
    assert reg.snapshot()["gauges"]["box"] == 2.0


def test_exporters_unify_counters_histograms_gauges():
    reg = obs.MetricsRegistry("t")
    reg.observe("serve.step", 0.25)
    reg.set_gauge("queue_depth", 3)
    telemetry.DISPATCH_COUNTS["apply"] += 2  # counters come from telemetry
    lines = [json.loads(l) for l in reg.to_jsonl().splitlines()]
    kinds = {l["type"] for l in lines}
    assert kinds == {"counter", "histogram", "gauge"}
    assert {"group": "dispatch", "key": "apply", "value": 2}.items() <= next(
        l for l in lines if l["type"] == "counter").items()
    prom = reg.to_prometheus()
    assert 'repro_dispatch_total{key="apply"} 2' in prom
    assert 'repro_serve_step_seconds{quantile="0.5"}' in prom
    assert "repro_serve_step_seconds_count 1" in prom
    assert "repro_queue_depth 3" in prom


# --------------------------------------------------------------------------
# recorder: ring bounding and the auto-dump hook
# --------------------------------------------------------------------------


def test_flight_recorder_ring_bounds_and_dump():
    rec = obs.FlightRecorder(capacity=4)
    for i in range(10):
        rec.record("dispatch", kernel="pallas", seqno=i)
    assert len(rec) == 4
    assert [e["seqno"] for e in rec.events()] == [6, 7, 8, 9]  # oldest gone
    d = rec.dump(reason="test")
    assert d["recorded"] == 10 and d["capacity"] == 4
    assert len(d["events"]) == 4


def test_recorder_note_error_auto_dumps(capsys):
    rec = obs.FlightRecorder(capacity=8)
    rec.record("dispatch", kernel="pallas", verdict="ok")
    dump = rec.note_error(RuntimeError("kernel died"), kernel="pallas",
                          site="executor")
    assert rec.last_dump is dump
    assert "RuntimeError" in dump["reason"]
    last = dump["events"][-1]
    assert last["event"] == "error" and last["kernel"] == "pallas"
    assert "FLIGHT-RECORDER" in capsys.readouterr().err


# --------------------------------------------------------------------------
# telemetry.diff (satellite: the snapshot-diff helper)
# --------------------------------------------------------------------------


def test_telemetry_diff_semantics():
    before = telemetry.snapshot()
    assert telemetry.diff(before, telemetry.snapshot()) == {}
    telemetry.DISPATCH_COUNTS["apply"] += 3
    telemetry.HASH_COUNTS["structure_key"] += 1
    delta = telemetry.diff(before, telemetry.snapshot())
    assert delta == {"dispatch": {"apply": 3}, "hash": {"structure_key": 1}}
    telemetry.reset_all()  # vanished keys surface as negative deltas
    assert telemetry.diff(delta and telemetry.snapshot() or before,
                          telemetry.snapshot()) == {}
    after_reset = telemetry.diff(
        {"dispatch": {"apply": 3}}, telemetry.snapshot())
    assert after_reset["dispatch"]["apply"] == -3


# --------------------------------------------------------------------------
# heartbeat gauge (satellite: live write_errors visibility)
# --------------------------------------------------------------------------


def test_heartbeat_write_errors_is_a_live_gauge(tmp_path):
    hb = Heartbeat(str(tmp_path / "beat.json"), interval_s=60.0)
    hb.start()
    try:
        reg = obs.default_registry()
        assert reg.snapshot()["gauges"]["heartbeat.write_errors"] == 0
        hb.write_errors = 2  # simulate failed liveness writes
        assert reg.snapshot()["gauges"]["heartbeat.write_errors"] == 2
        assert "repro_heartbeat_write_errors 2" in reg.to_prometheus()
    finally:
        hb.stop()


# --------------------------------------------------------------------------
# the OFF contract: dispatch-identical hot path
# --------------------------------------------------------------------------


def test_tracing_off_is_dispatch_identical(ab):
    a, b = ab
    ex = ReuseExecutor.from_matrices(a, b)
    ex.apply(a.values, b.values)  # warm
    before = telemetry.snapshot()
    for _ in range(10):
        ex.apply(a.values, b.values)
    delta = telemetry.diff(before, telemetry.snapshot())
    # replay adds dispatches and NOTHING else: no traces, no hashes
    assert delta == {"dispatch": {"apply": 10}}
    assert obs.events() == []                      # no spans buffered
    assert len(obs.default_recorder()) == 0        # no ring entries
    assert obs.default_registry().snapshot()["histograms"] == {}


# --------------------------------------------------------------------------
# the ON contract: traced chaos run through the serving tier
# --------------------------------------------------------------------------


def test_service_chaos_traced_end_to_end(tmp_path):
    """The ISSUE's acceptance run: SparseService under an injected kernel
    failure with tracing on. The exported Chrome trace must carry request
    trace ids end-to-end, per-phase histograms must have real latencies, and
    the flight recorder must name the failing kernel and its fallback hop."""
    from repro.serve import SparseService

    structures = [
        (random_csr(32, 24, 4.0, seed=1), random_csr(24, 40, 4.0, seed=2)),
        (random_csr(16, 24, 3.0, seed=7), random_csr(24, 8, 3.0, seed=8)),
    ]
    refs = [spgemm(a, b, method="sparse").c.to_dense() for a, b in structures]
    obs.set_tracing("on")
    svc = SparseService(backend="pallas", max_batch=2, breaker_threshold=3,
                        retries=1, sleep=lambda _: None)

    resps = []
    with faults.failpoint("kernel:pallas"):  # the injected kernel failure
        resps.append(svc.submit(*structures[0]))
        svc.drain()
    for i in range(1, 4):  # recovery traffic
        resps.append(svc.submit(*structures[i % 2]))
    svc.drain()
    for i, r in enumerate(resps):
        assert r.ok and bool(jnp.all(r.value.to_dense() == refs[i % 2]))

    # -- every request got a trace id, and it reached the nested spans -----
    assert [r.trace_id for r in resps] == ["req-0", "req-1", "req-2", "req-3"]
    payload = obs.export_chrome_trace(str(tmp_path / "chaos_trace.json"))
    loaded = json.loads((tmp_path / "chaos_trace.json").read_text())
    assert loaded["traceEvents"] == payload["traceEvents"]  # valid JSON file
    by_tid = {}
    for ev in payload["traceEvents"]:
        assert ev["ph"] == "X" and ev["dur"] >= 0
        by_tid.setdefault(ev["args"].get("trace_id"), set()).add(ev["name"])
    for tid in ("req-0", "req-1", "req-2", "req-3"):
        # admission and the executor dispatch both carry the request's id:
        # end-to-end propagation, not just a stamp at the door
        assert "serve.admit" in by_tid[tid], tid
        assert "numeric.dispatch" in by_tid[tid], tid
    all_names = set().union(*by_tid.values())
    assert "plan.build" in all_names
    assert all_names <= SPAN_NAMES, all_names - SPAN_NAMES  # taxonomy-closed

    # -- per-phase histograms have real, nonzero latency distributions -----
    reg = obs.default_registry()
    for phase in ("plan.build", "numeric.dispatch"):
        h = reg.histogram(phase)
        assert h.count > 0, phase
        assert h.percentile(50.0) > 0.0, phase
        assert h.percentile(99.0) >= h.percentile(50.0) > 0.0, phase

    # -- the flight recorder caught the kernel failure and the hop ---------
    ring = obs.default_recorder().events()
    hops = [e for e in ring if e.get("fallback")]
    assert hops and hops[0]["kernel"] == "pallas"
    assert hops[0]["fallback"] == "pallas->xla"
    assert any(e.get("trace_id") == "req-0" for e in ring)

    # -- stats(debug=True) exposes the dump + metrics on demand ------------
    dbg = svc.stats(debug=True)
    assert dbg["flight_recorder"]["events"] == ring
    assert dbg["metrics"]["histograms"]["serve.request"]["count"] == 4
    assert "flight_recorder" not in svc.stats()


def test_stats_debug_off_by_default(ab):
    from repro.serve import SparseService

    a, b = ab
    svc = SparseService(sleep=lambda _: None)
    svc.submit(a, b)
    svc.drain()
    out = svc.stats()
    assert "flight_recorder" not in out and "metrics" not in out
    assert out["request_latency"]["count"] == 1
    assert "est_step_s" in out
