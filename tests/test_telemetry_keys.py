"""One test per documented telemetry key family (core/telemetry.py).

The key conventions in ``telemetry.KEY_FAMILIES`` are load-bearing:
dashboards, the Prometheus exporter and the serving tier's stats() all
parse them. Each test here drives the real code path that bumps a family
and asserts the *exact* key strings — derived from the machine-readable
grammars where the family templates them — so renaming a key without
updating the registry (or vice versa) fails loudly. The static half of
this contract is ``python -m repro.analysis`` (rule ``telemetry-key``),
which checks every mutation site against the same KEY_FAMILIES dict.
"""
import jax.numpy as jnp
import pytest

from repro.core import telemetry
from repro.core.executor import ReuseExecutor
from repro.core.spgemm import numeric_reuse, spgemm
from repro.kernels.ops import numeric_values
from repro.runtime import faults
from repro.runtime.retry import RetryExhaustedError, retry_call
from repro.serve.breaker import CircuitBreaker
from repro.sparse import CSR, csr_to_ell, random_csr


@pytest.fixture
def ab():
    return random_csr(32, 24, 4.0, seed=1), random_csr(24, 40, 4.0, seed=2)


def _int_operands():
    a = random_csr(24, 16, 3.0, seed=5)
    b = random_csr(16, 20, 3.0, seed=6)
    to_int = lambda m: CSR(indptr=m.indptr, indices=m.indices,
                           values=jnp.ones_like(m.values, jnp.int32),
                           shape=m.shape)
    return to_int(a), to_int(b)


# --------------------------------------------------------------------------
# "fault:<kernel>-><next>" — degradation-ladder step after a kernel fault
# --------------------------------------------------------------------------


def test_fault_key_names_both_kernels_of_the_hop(ab):
    a, b = ab
    ex = ReuseExecutor.from_matrices(a, b, backend="pallas")
    oracle = numeric_reuse(ex.plan, a.values, b.values)
    with faults.failpoint("kernel:pallas"):
        out = ex.apply(a.values, b.values)
    assert bool(jnp.all(out == oracle))
    assert ex.kernel_source == "fallback"
    assert telemetry.FALLBACK_COUNTS["fault:pallas->xla"] == 1
    # exactly one fault key, and it encodes <from>-><to>, nothing else
    fault_keys = [k for k in telemetry.FALLBACK_COUNTS if k.startswith("fault:")]
    assert fault_keys == ["fault:pallas->xla"]


# --------------------------------------------------------------------------
# "dtype:<site>->xla" — f32-accumulation guard, one key per entry point
# --------------------------------------------------------------------------


def test_dtype_keys_cover_all_three_sites():
    a, b = _int_operands()
    spgemm(a, b, method="lp")
    ReuseExecutor.from_matrices(a, b, backend="pallas_lp").apply(
        a.values, b.values)
    res = spgemm(a, b, method="sparse")
    c_ell = csr_to_ell(res.c)
    numeric_values(a, b, c_ell.indices, c_ell.row_nnz, kernel="auto")
    dtype_keys = sorted(k for k in telemetry.FALLBACK_COUNTS
                        if k.startswith("dtype:"))
    assert dtype_keys == ["dtype:executor->xla", "dtype:lp->xla",
                          "dtype:numeric_auto->xla"]


# --------------------------------------------------------------------------
# "nan_guard:rerun/recovered/data" — NaN-guard verdict triplet
# --------------------------------------------------------------------------


def test_nan_guard_keys_rerun_recovered_and_data(ab):
    a, b = ab
    ex = ReuseExecutor.from_matrices(a, b, nan_guard=True)
    with faults.failpoint("executor:poison_output"):
        ex.apply(a.values, b.values)
    assert telemetry.FALLBACK_COUNTS["nan_guard:rerun"] == 1
    assert telemetry.FALLBACK_COUNTS["nan_guard:recovered"] == 1
    assert telemetry.FALLBACK_COUNTS["nan_guard:data"] == 0

    bad = faults.inject_csr("nan_values", a)
    ex.apply(bad.values, b.values)
    assert telemetry.FALLBACK_COUNTS["nan_guard:rerun"] == 2
    assert telemetry.FALLBACK_COUNTS["nan_guard:data"] == 1
    # recovered did NOT move: a data NaN is flagged, never "recovered"
    assert telemetry.FALLBACK_COUNTS["nan_guard:recovered"] == 1


# --------------------------------------------------------------------------
# "<label>:attempt/retry/giveup" — retry_call accounting
# --------------------------------------------------------------------------


def test_retry_keys_attempt_retry_giveup():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("transient")
        return "ok"

    assert retry_call(flaky, retries=3, sleep=lambda _: None,
                      label="keytest") == "ok"
    assert telemetry.RETRY_COUNTS["keytest:attempt"] == 3
    assert telemetry.RETRY_COUNTS["keytest:retry"] == 2
    assert telemetry.RETRY_COUNTS["keytest:giveup"] == 0

    def doomed():
        raise RuntimeError("permanent")

    with pytest.raises(RetryExhaustedError):
        retry_call(doomed, retries=1, sleep=lambda _: None, label="keytest")
    assert telemetry.RETRY_COUNTS["keytest:attempt"] == 5
    assert telemetry.RETRY_COUNTS["keytest:retry"] == 3
    assert telemetry.RETRY_COUNTS["keytest:giveup"] == 1
    # the family grammar covers exactly the keys the mechanism produced
    assert sorted(telemetry.RETRY_COUNTS) == sorted(
        t.replace("{}", "keytest") for t in telemetry.KEY_FAMILIES["retry"])


# --------------------------------------------------------------------------
# "<name>:open/half_open/close/reopen/short_circuit" — breaker transitions
# --------------------------------------------------------------------------


def test_breaker_keys_all_five_transitions():
    t = {"now": 0.0}
    br = CircuitBreaker("keybrk", failure_threshold=2, window_s=30.0,
                        cooldown_s=5.0, clock=lambda: t["now"])

    br.record_failure()
    br.record_failure()                       # threshold hit -> open
    assert telemetry.BREAKER_COUNTS["keybrk:open"] == 1

    assert br.allow() is False                # still cooling -> short_circuit
    assert telemetry.BREAKER_COUNTS["keybrk:short_circuit"] == 1

    t["now"] += 5.0                           # cooldown elapsed -> half_open
    assert br.allow() is True                 # the probe
    assert telemetry.BREAKER_COUNTS["keybrk:half_open"] == 1

    br.record_failure()                       # probe failed -> reopen
    assert telemetry.BREAKER_COUNTS["keybrk:reopen"] == 1

    t["now"] += 5.0
    assert br.allow() is True                 # half_open again, second probe
    br.record_success()                       # probe succeeded -> close
    assert telemetry.BREAKER_COUNTS["keybrk:close"] == 1

    # all five transition keys, derived from the documented grammar rather
    # than re-listed inline — KEY_FAMILIES is the single source of truth
    assert sorted(telemetry.BREAKER_COUNTS) == sorted(
        t.replace("{}", "keybrk") for t in telemetry.KEY_FAMILIES["breaker"])
    assert telemetry.BREAKER_COUNTS["keybrk:half_open"] == 2


# --------------------------------------------------------------------------
# the grammar registry itself
# --------------------------------------------------------------------------


def test_key_families_cover_all_registered_counters():
    assert set(telemetry.KEY_FAMILIES) == set(telemetry.ALL_COUNTERS)


def test_every_live_key_matches_its_family_grammar(ab):
    """After driving the fault/retry flows above, every key in every
    registered counter must fit its family's documented templates."""
    a, b = ab
    ex = ReuseExecutor.from_matrices(a, b, backend="pallas")
    with faults.failpoint("kernel:pallas"):
        ex.apply(a.values, b.values)
    retry_call(lambda: "ok", retries=0, sleep=lambda _: None, label="g")
    for family, counter in telemetry.ALL_COUNTERS.items():
        for key in counter:
            assert telemetry.key_matches_family(family, key), (family, key)


def test_key_matches_family_rejects_drift():
    assert telemetry.key_matches_family("fallback", "fault:pallas->xla")
    assert telemetry.key_matches_family("fallback", "nan_guard:rerun")
    assert not telemetry.key_matches_family("fallback", "nan_guard:re-run")
    assert not telemetry.key_matches_family("breaker", "b:exploded")
    assert not telemetry.key_matches_family("nope", "anything")
