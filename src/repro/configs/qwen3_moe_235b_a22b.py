"""qwen3-moe-235b-a22b [moe] — hf:Qwen/Qwen3-235B-A22B (family per spec).

94L, d_model=4096, 64 heads (GQA kv=4), per-expert d_ff=1536, vocab=151936,
MoE 128 experts top-8, QK-norm.

SpGEMM applicability: YES — dispatch/combine is the two-phase SpGEMM
specialization (routing = symbolic; grouped matmul = numeric). See
DESIGN.md §4. long_500k: skipped (full attention).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=151_936,
    pattern=("moe",),
    head_dim=128,
    qk_norm=True,
    rope_theta=1_000_000.0,
    num_experts=128,
    experts_per_token=8,
    moe_d_ff=1536,
)

SMOKE = ModelConfig(
    name="qwen3-moe-235b-a22b-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=0,
    vocab_size=256,
    pattern=("moe",),
    head_dim=16,
    qk_norm=True,
    num_experts=8,
    experts_per_token=2,
    moe_d_ff=32,
)

SKIP_SHAPES = {"long_500k": "pure full-attention arch (per-spec skip)"}
