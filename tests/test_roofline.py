"""HLO cost-model validation: the multiplicity-aware parser must match
XLA's cost_analysis on unrolled programs (where XLA is exact) and correct
the known while-loop undercount on scanned ones."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.launch.hlo_cost import analyze_hlo
from repro.launch.roofline import Roofline, collective_bytes, model_flops_for


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_parser_matches_xla_unrolled():
    n = 256

    def f(x):
        for _ in range(8):
            x = x @ x
        return x

    comp = _compile(f, jax.ShapeDtypeStruct((n, n), jnp.float32))
    got = analyze_hlo(comp.as_text())
    ca = comp.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    np.testing.assert_allclose(got["flops"], ca["flops"], rtol=1e-6)
    np.testing.assert_allclose(got["flops"], 8 * 2 * n ** 3, rtol=1e-6)


def test_parser_corrects_scan_undercount():
    n = 256

    def f(x):
        def body(c, _):
            return c @ c, None
        return jax.lax.scan(body, x, None, length=8)[0]

    comp = _compile(f, jax.ShapeDtypeStruct((n, n), jnp.float32))
    got = analyze_hlo(comp.as_text())
    ca = comp.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    assert ca["flops"] < got["flops"]  # XLA undercounts the loop
    np.testing.assert_allclose(got["flops"], 8 * 2 * n ** 3, rtol=1e-6)


def test_parser_nested_scans():
    n = 128

    def f(x):
        def inner(c, _):
            return c @ c, None

        def outer(c, _):
            c, _ = jax.lax.scan(inner, c, None, length=3)
            return c, None

        return jax.lax.scan(outer, x, None, length=4)[0]

    comp = _compile(f, jax.ShapeDtypeStruct((n, n), jnp.float32))
    got = analyze_hlo(comp.as_text())
    np.testing.assert_allclose(got["flops"], 12 * 2 * n ** 3, rtol=1e-6)


def test_parser_batched_einsum():
    def f(q, k):
        return jnp.einsum("bhqd,bhkd->bhqk", q, k)

    s = jax.ShapeDtypeStruct((2, 4, 128, 64), jnp.float32)
    comp = _compile(f, s, s)
    got = analyze_hlo(comp.as_text())
    np.testing.assert_allclose(got["flops"], 2 * 2 * 4 * 128 * 128 * 64,
                               rtol=1e-6)


def test_collective_regex():
    hlo = """
ENTRY %main (x: f32[16,128]) -> f32[16,128] {
  %x = f32[16,128]{1,0} parameter(0)
  %ag = f32[64,128]{1,0} all-gather(%x), replica_groups={}
  %ar = f32[16,128]{1,0} all-reduce(%x), to_apply=%add
  ROOT %out = f32[16,128]{1,0} copy(%ar)
}
"""
    got = collective_bytes(hlo)
    assert got["all-gather"] == 64 * 128 * 4
    assert got["all-reduce"] == 16 * 128 * 4


def test_roofline_terms():
    r = Roofline(
        arch="x", shape="train_4k", mesh="16x16", chips=256,
        hlo_flops=197e12, hlo_bytes=819e9, coll_bytes_per_chip=50e9,
        coll_breakdown={}, bytes_per_chip_peak=0.0, model_flops=197e12 * 256,
    )
    assert abs(r.t_compute - 1.0) < 1e-9
    assert abs(r.t_memory - 1.0) < 1e-9
    assert abs(r.t_collective - 1.0) < 1e-9
    assert abs(r.useful_ratio - 1.0) < 1e-9


def test_model_flops_kinds():
    from repro.configs import SHAPES, get_config

    cfg = get_config("llama3.2-1b")
    n = cfg.active_param_count()
    assert model_flops_for(cfg, SHAPES["train_4k"]) == 6 * n * 256 * 4096
    assert model_flops_for(cfg, SHAPES["decode_32k"]) == 2 * n * 128
    moe = get_config("qwen3-moe-235b-a22b")
    # MoE counts ACTIVE params only
    assert moe.active_param_count() < 0.15 * moe.param_count()
