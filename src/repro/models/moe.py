"""Mixture-of-Experts block: the paper's two-phase SpGEMM discipline applied
to token->expert dispatch (DESIGN.md §4).

The dispatch matrix (tokens x experts, top-k one-hot) is a sparse matrix in
CSR spirit: per-expert counts are its row pointers. We split the layer into

  * symbolic phase  — routing: top-k expert ids + in-expert positions via a
    cumulative one-hot (counts only, no FLOPs on activations — exactly the
    paper's symbolic contract; capacity plays the role of the memory pool's
    CHUNKSIZE bound, with overflowing tokens dropped);
  * numeric phase   — gather tokens into (E_local, C, d) expert buffers and
    run the expert FFNs as one batched einsum per matrix (dense-block
    accumulation on the MXU), then scatter-combine weighted by router probs.

Distribution: expert parallelism over the 'model' axis via shard_map —
each model shard owns E/tp experts and computes their contribution for all
of its data-shard's tokens; the combine is a single psum over 'model'.
Token activations stay sharded over ('pod','data') throughout.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import ModelConfig
from repro.models.layers import rms_norm
from repro.models.sharding import ShardingRules


def moe_params_template(cfg: ModelConfig):
    d, e, f = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    return {
        "router": ((d, e), "norm"),
        "w1": ((e, d, f), "moe"),
        "w3": ((e, d, f), "moe"),
        "w2": ((e, f, d), "moe"),
        "norm": ((d,), "norm"),
    }


def routing_symbolic(logits: jax.Array, k: int, capacity: int,
                     num_experts: int):
    """Symbolic phase: (weights, expert_ids, slot_pos, keep_mask).

    logits: (T, E). slot_pos[t, j] = position of assignment j of token t
    inside its expert's capacity buffer; keep = slot_pos < capacity (the
    CHUNKSIZE bound — overflow drops, mirroring pool exhaustion).

    Positions come from the sort-based structure discovery the core SpGEMM
    path uses (argsort by expert, rank within group) — O(T*k) memory, no
    (T*k, E) one-hot materialization.
    """
    t = logits.shape[0]
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    weights, ids = jax.lax.top_k(probs, k)  # (T, k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    flat_ids = ids.reshape(-1)  # (T*k,) — assignment stream
    n = flat_ids.shape[0]
    order = jnp.argsort(flat_ids, stable=True)
    sorted_ids = flat_ids[order]
    counts = jnp.zeros((num_experts,), jnp.int32).at[sorted_ids].add(
        1, mode="drop", indices_are_sorted=True
    )
    starts = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1].astype(jnp.int32)]
    )
    rank_sorted = jnp.arange(n, dtype=jnp.int32) - starts[sorted_ids]
    slot = jnp.zeros((n,), jnp.int32).at[order].set(rank_sorted)
    keep = slot < capacity
    return weights, ids, slot.reshape(t, k), keep.reshape(t, k)


def moe_ffn_local(x, router_w, w1, w3, w2, *, k: int, capacity: int,
                  num_experts: int, e_start, act):
    """Numeric phase for one model shard owning experts
    [e_start, e_start + E_local). x: (T, d) local tokens (full d)."""
    t, d = x.shape
    e_local = w1.shape[0]
    logits = x.astype(jnp.float32) @ router_w.astype(jnp.float32)  # (T, E)
    weights, ids, slot, keep = routing_symbolic(logits, k, capacity, num_experts)

    local = (ids >= e_start) & (ids < e_start + e_local) & keep  # (T, k)
    local_e = jnp.where(local, ids - e_start, 0)
    local_slot = jnp.where(local, slot, capacity)  # capacity slot == dropped

    # gather: scatter token rows into (E_local, capacity+1, d); slot
    # 'capacity' is the drop bin. One scatter per top-k slot keeps the
    # largest temporary at (T, d) — never (T*k, d).
    buf = jnp.zeros((e_local, capacity + 1, d), x.dtype)
    for j in range(k):
        buf = buf.at[local_e[:, j], local_slot[:, j]].add(
            jnp.where(local[:, j][:, None], x, 0), mode="drop"
        )
    xe = buf[:, :capacity]  # (E_local, C, d)

    # expert FFNs: batched dense-block matmuls (MXU-native numeric phase)
    gate_act = jax.nn.silu if act == "silu" else jax.nn.gelu
    h = gate_act(jnp.einsum("ecd,edf->ecf", xe, w1.astype(xe.dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", xe, w3.astype(xe.dtype))
    ye = jnp.einsum("ecf,efd->ecd", h, w2.astype(xe.dtype))  # (E_local, C, d)

    # combine: gather each assignment's output row, weight, sum over k
    ye_pad = jnp.concatenate([ye, jnp.zeros((e_local, 1, d), ye.dtype)], axis=1)
    out = jnp.zeros((t, d), ye.dtype)
    for j in range(k):
        rows = ye_pad[local_e[:, j], local_slot[:, j]]  # (T, d)
        rows = rows * weights[:, j][:, None].astype(rows.dtype)
        out = out + jnp.where(local[:, j][:, None], rows, 0)
    return out


def moe_layer(p, x, cfg: ModelConfig, rules: ShardingRules,
              mesh=None, capacity_factor: float = 1.25):
    """Full MoE block: norm -> EP-sharded expert FFN -> residual delta.

    x: (B, T, d). With a mesh + tp axis: shard_map over the full mesh,
    experts split over 'model', tokens over ('pod','data'); one psum('model')
    combines expert contributions. Without a mesh (smoke tests): single-shard
    fast path.
    """
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    b, t, d = h.shape
    k = cfg.experts_per_token
    e = cfg.num_experts

    def capacity_for(tokens: int, e_local: int) -> int:
        cap = int(tokens * k / e * capacity_factor) + 1
        return max(-(-cap // 8) * 8, 8)

    if mesh is None or not rules.enabled or rules.tp_axis is None:
        cap = capacity_for(b * t, e)
        y = moe_ffn_local(
            h.reshape(b * t, d), p["router"], p["w1"], p["w3"], p["w2"],
            k=k, capacity=cap, num_experts=e, e_start=0, act=cfg.act,
        )
        return y.reshape(b, t, d)

    tp = rules.tp_axis
    dp = rules.dp_axes
    tp_size = rules.tp_size
    e_local = e // tp_size
    dp_size = 1
    for ax in dp:
        dp_size *= mesh.shape[ax]
    tokens_local = (b // dp_size) * t
    cap = capacity_for(tokens_local, e_local)
    # FSDP on expert weights (§Perf iteration for the 235B arch): at rest
    # each chip holds E/tp experts' (d/dp)-slice; the full (bf16) expert
    # block is all-gathered over the data axes per layer. The all_gather
    # transpose gives reduce-scattered (ZeRO-2 style) expert grads for free.
    dp_flat = dp if len(dp) > 1 else dp[0]
    fsdp = (d % dp_size == 0) and (cfg.moe_d_ff % dp_size == 0) and dp_size > 1
    w_spec = P(tp, dp_flat, None) if fsdp else P(tp)
    # sequence-parallel boundary (§Perf iteration 2 for qwen3-235b): tokens
    # arrive seq-sharded over 'model', all-gather in, psum_scatter out —
    # halves the MoE collective bytes vs replicated-in + full psum.
    sp = t % tp_size == 0 and tp_size > 1
    h_spec = P(dp, tp if sp else None, None)

    def shard_fn(h_sh, router_w, w1, w3, w2):
        # h_sh: (B_loc, T[/tp], d); w1/w3: (E_local, d[/dp], f)
        tp_idx = jax.lax.axis_index(tp)
        e_start = tp_idx * e_local
        if fsdp:
            w1 = _fsdp_gather(w1, dp, axis=1)
            w3 = _fsdp_gather(w3, dp, axis=1)
            w2 = _fsdp_gather(w2, dp, axis=1)
        if sp:
            h_full = jax.lax.all_gather(h_sh, tp, axis=1, tiled=True)
        else:
            h_full = h_sh
        y = moe_ffn_local(
            h_full.reshape(-1, d), router_w, w1, w3, w2,
            k=k, capacity=cap, num_experts=e, e_start=e_start, act=cfg.act,
        )
        y = y.reshape(h_full.shape)
        if sp:
            return jax.lax.psum_scatter(y, tp, scatter_dimension=1, tiled=True)
        return jax.lax.psum(y, tp)

    y = shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=(
            h_spec,
            P(),  # router replicated
            w_spec, w_spec,  # experts: EP (x FSDP at rest)
            w_spec,
        ),
        out_specs=h_spec,
    )(h, p["router"], p["w1"], p["w3"], p["w2"])
    return y


def _fsdp_gather(w, dp_axes: tuple, axis: int):
    """All-gather an FSDP-sharded weight over the data axes, in bf16."""
    out = w.astype(jnp.bfloat16)
    for ax in reversed(dp_axes):
        out = jax.lax.all_gather(out, ax, axis=axis, tiled=True)
    return out
