"""KKSPGEMM meta-algorithm (paper §3.3, Table 1).

The paper's selection constants are kept verbatim:
  * CPUs/KNLs: KKDENSE when k < 250 000, KKMEM otherwise.
  * GPUs:      KKMEM when average row flops < 256, KKLP otherwise.
  * ARS estimate for symbolic sizing: f_m / 8 ("every 8th multiplication
    reduces to the same nonzero").

TPU mapping (DESIGN.md §2): "dense" = dense-accumulator paths (XLA scatter /
Pallas dense-tile kernel), "sparse" = sorted-segment flat-parallel path,
"hash" = Pallas LP-hash kernel. The k cutoff doubles as a memory guard for
the O(m*k) dense accumulator.

Threshold precedence (static < fitted < measured; see ``core/autotune.py``):

  static   — the paper constants above. The default, and the documented
             fallback whenever nothing better is available.
  fitted   — when a ``TunedThresholds`` table is active
             (``autotune.set_tuned_thresholds``) and has a row for the
             current backend, ``choose_kernel``/``choose_method`` use its
             per-backend cutoffs instead of the constants. Backends without
             a fitted row stay on static.
  measured — ``tune="measure"`` callers bypass the threshold rule entirely:
             candidates are micro-benchmarked on the real operands and the
             cached winner is dispatched. The choosers still run (their
             advisory pick lands in stats), but the measured winner decides.

Each chooser records its decision provenance in the stats dict it is passed
(``kernel_source``/``method_source`` in {"static", "fitted"}); ``spgemm``
overwrites ``kernel_source`` with "measured" when measure mode decided.

Tie directions at the cutoffs are part of the contract:
``avg_row_flops == cutoff`` selects 'flat_lp' (the rule is ``< cutoff`` →
'dense_acc'), and ``dense_bytes == DENSE_BYTES_BUDGET`` still selects
'dense' (the guard is ``<= budget``).
"""
from __future__ import annotations

import numpy as np

from repro.sparse.formats import CSR

DENSE_K_CUTOFF = 250_000  # paper §3.3
AVG_ROW_FLOPS_CUTOFF = 256  # paper §3.3 (GPU variant selection)
ARS_REDUCTION_GUESS = 8  # paper §3.3: every 8th multiply collides
DENSE_BYTES_BUDGET = 1 << 30  # 1 GiB guard for the XLA dense accumulator

# Capacity padding policies for the static-shape caps (fm_cap / nnz_cap / ELL
# widths). "exact8" is the tightest lane-aligned cap; "pow2" rounds up to
# geometric x2 buckets so matrices of similar size share one compiled
# executable instead of each minting its own (the recompile amortization that
# makes the paper's Reuse case pay off under XLA).
PAD_POLICIES = ("exact8", "pow2")
DEFAULT_PAD_POLICY = "pow2"
CAPACITY_FLOOR = 8  # minimum cap: one 8-lane sublane


def round_capacity(x: int, policy: str = DEFAULT_PAD_POLICY) -> int:
    """Round a size up to a static capacity under the given pad policy.

    "exact8": next multiple of 8 (tight; every distinct size recompiles).
    "pow2":   next power of two (geometric buckets; sizes within a x2 band
              share the same compiled executable).
    """
    x = max(int(x), 1)
    if policy == "exact8":
        return max(-(-x // 8) * 8, CAPACITY_FLOOR)
    if policy == "pow2":
        return max(1 << (x - 1).bit_length(), CAPACITY_FLOOR)
    from repro.runtime.validate import SpgemmConfigError  # cycle-free
    raise SpgemmConfigError(
        f"unknown pad_policy {policy!r}; expected one of {PAD_POLICIES}")


def f32_accumulation_ok(a_dtype, b_dtype) -> bool:
    """May the f32-accumulating Pallas kernels see these operand dtypes?

    The one shared predicate behind every kernel-routing decision
    (``spgemm(method="lp")``, ``ReuseExecutor._replay``,
    ``kernels.ops.resolve_numeric_kernel``): floating accumulation of at
    most 4 bytes. f64 would halve double precision; integers would break
    exactness past 2^24 — both belong on the XLA path.
    """
    import jax.numpy as jnp  # local: keep module import-light for the host

    acc = np.result_type(a_dtype, b_dtype)
    # jnp.issubdtype, not np: numpy does not class ml_dtypes.bfloat16 as
    # floating, and bf16 operands are exactly what the kernels should accept
    return bool(jnp.issubdtype(acc, jnp.floating)) and acc.itemsize <= 4


def choose_method(a: CSR, b: CSR, stats: dict) -> str:
    """Return 'dense' or 'sparse' for the XLA numeric phase.

    The dense accumulator is an (m, k) values array in the accumulation dtype
    plus an (m, k) int32 occupancy mask, so the memory guard must scale with
    the operand value dtype: hard-coding 4-byte values would undercount f64
    inputs 2x and let them breach DENSE_BYTES_BUDGET.

    ``stats`` is written, not read: the decision inputs (``dense_bytes``)
    and provenance (``method_source``) land there so dispatch is observable
    without recomputing. The k cutoff comes from the active fitted table
    when one covers this backend (see module docstring), else the paper
    constant. ``dense_bytes == DENSE_BYTES_BUDGET`` is still 'dense'.
    """
    from repro.core import autotune  # local: meta must import without jax

    k = b.k
    # numpy promotion on purpose: jnp.result_type would canonicalize f64 to
    # f32 when x64 is disabled and silently restore the undercount
    val_itemsize = np.result_type(a.values.dtype, b.values.dtype).itemsize
    dense_bytes = a.m * k * (val_itemsize + 4)  # values + int32 occupancy
    k_cutoff, source = autotune.dense_k_cutoff()
    stats["dense_bytes"] = dense_bytes
    stats["method_source"] = source
    if k < k_cutoff and dense_bytes <= DENSE_BYTES_BUDGET:
        return "dense"
    return "sparse"


def choose_kernel(a: CSR, b: CSR, stats: dict) -> str:
    """Return 'dense_acc' (KKMEM-position: thread-sequential, modest rows) or
    'flat_lp' (KKLP-position: LP-hash accumulator for flop-heavy rows) for
    the Pallas path — the paper's GPU rule on average row flops.

    ``stats`` must carry ``fm`` (the total multiplication count, from
    ``flops_stats``); a missing ``fm`` raises ``KeyError`` rather than
    silently defaulting to 0, which would always select 'dense_acc' and hide
    meta-dispatch bugs. The decision inputs (``avg_row_flops``) and
    provenance (``kernel_source`` in {"static", "fitted"}) are written back
    so dispatch is observable without recomputing.

    The cutoff comes from the active fitted table when one covers this
    backend (see module docstring), else the paper's 256. The tie at
    ``avg_row_flops == cutoff`` goes to 'flat_lp': the paper's rule selects
    KKMEM strictly *below* the cutoff, and at the boundary the LP hash's
    occupancy advantage is already in play.
    """
    from repro.core import autotune  # local: meta must import without jax

    if "fm" not in stats:
        raise KeyError(
            "choose_kernel requires stats['fm'] (total multiplications; see "
            "flops_stats) — a silent fm=0 default would always pick "
            "'dense_acc'"
        )
    fm = max(int(stats["fm"]), 1)
    avg_row_flops = fm / max(a.m, 1)
    cutoff, source = autotune.avg_row_flops_cutoff()
    stats["avg_row_flops"] = avg_row_flops
    stats["kernel_source"] = source
    return "dense_acc" if avg_row_flops < cutoff else "flat_lp"


def estimate_ars(fm: int) -> int:
    """Average output row size estimate used before symbolic (paper §3.3)."""
    return max(fm // ARS_REDUCTION_GUESS, 1)
