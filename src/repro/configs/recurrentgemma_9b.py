"""recurrentgemma-9b [hybrid] — arXiv:2402.19427 (Griffin/RG-LRU).

38L, d_model=4096, 16 heads (MQA kv=1), d_ff=12288, vocab=256000,
RG-LRU : local-attention at 2:1 (pattern rec,rec,attn), window 2048,
lru_width=4096. 38 = 12*(rec,rec,attn) + (rec,rec) tail.

SpGEMM applicability: none. long_500k: RUN — recurrence carries O(1) state
and local attention keeps a bounded 2048-token KV window.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    d_ff=12_288,
    vocab_size=256_000,
    pattern=("rec", "rec", "local"),
    tail=("rec", "rec"),
    head_dim=256,
    window=2_048,
    lru_width=4096,
    tie_embeddings=True,
    act="gelu",
)

SMOKE = ModelConfig(
    name="recurrentgemma-9b-smoke",
    family="hybrid",
    num_layers=5,
    d_model=64,
    num_heads=4,
    num_kv_heads=1,
    d_ff=128,
    vocab_size=256,
    pattern=("rec", "rec", "local"),
    tail=("rec", "rec"),
    head_dim=16,
    window=16,
    lru_width=64,
    tie_embeddings=True,
    act="gelu",
)

SKIP_SHAPES = {}
