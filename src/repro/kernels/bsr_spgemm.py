"""Pallas TPU kernel: block-sparse (BSR) SpGEMM numeric phase.

The MXU-native end of the accumulator spectrum (DESIGN.md §2.1): for
block-structured matrices (FEM/multigrid with dense node blocks), the
element-wise accumulators collapse into dense (bs, bs) block products —
each grid step is ONE MXU matmul A_block @ B_block accumulated into its C
block.

Two-phase discipline at block granularity:
  * symbolic (host/XLA, `plan_bsr_numeric`): for every C block, the list of
    contributing (A-block, B-block) index pairs — the paper's structure
    discovery, reusable across value changes;
  * numeric (this kernel): grid = (C blocks, max_contrib); the plan's
    scalar-prefetched indices steer the A/B block gathers via index_maps,
    and contributions accumulate in a VMEM tile (contiguous revisiting —
    Thread-Sequential semantics, no atomics).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def plan_bsr_numeric(a_indptr, a_indices, b_indptr, b_indices):
    """Host-side symbolic phase on the block graph.

    Inputs: BSR structure arrays (numpy). Returns (c_indptr, c_indices,
    contrib_a, contrib_b, contrib_n) where contrib_* have shape
    (nnzb_C, T_max) listing contributing A/B block slots per C block.
    """
    a_indptr = np.asarray(a_indptr)
    a_indices = np.asarray(a_indices)
    b_indptr = np.asarray(b_indptr)
    b_indices = np.asarray(b_indices)
    mb = len(a_indptr) - 1

    c_cols: list[list[int]] = []
    contribs: list[dict] = []
    c_indptr = [0]
    for i in range(mb):
        acc: dict[int, list] = {}
        for e in range(a_indptr[i], a_indptr[i + 1]):
            j = int(a_indices[e])
            for f in range(b_indptr[j], b_indptr[j + 1]):
                c = int(b_indices[f])
                acc.setdefault(c, []).append((e, f))
        cols = sorted(acc)
        c_cols.append(cols)
        contribs.append(acc)
        c_indptr.append(c_indptr[-1] + len(cols))

    nnzb_c = c_indptr[-1]
    t_max = max(
        (len(v) for row in contribs for v in row.values()), default=1
    )
    contrib_a = np.zeros((nnzb_c, t_max), np.int32)
    contrib_b = np.zeros((nnzb_c, t_max), np.int32)
    contrib_n = np.zeros((nnzb_c,), np.int32)
    c_indices = np.zeros((nnzb_c,), np.int32)
    slot = 0
    for i in range(mb):
        for c in c_cols[i]:
            pairs = contribs[i][c]
            contrib_n[slot] = len(pairs)
            for t, (e, f) in enumerate(pairs):
                contrib_a[slot, t] = e
                contrib_b[slot, t] = f
            c_indices[slot] = c
            slot += 1
    return (
        np.asarray(c_indptr, np.int32), c_indices,
        contrib_a, contrib_b, contrib_n,
    )


def _kernel(ca_ref, cb_ref, cn_ref, a_ref, b_ref, out_ref, acc_ref):
    s = pl.program_id(0)  # C block slot
    t = pl.program_id(1)  # contribution index
    n_t = pl.num_programs(1)

    @pl.when(t == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    live = t < cn_ref[s]
    prod = jnp.dot(
        a_ref[0].astype(jnp.float32), b_ref[0].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    acc_ref[...] += jnp.where(live, prod, 0.0)

    @pl.when(t == n_t - 1)
    def _emit():
        out_ref[0] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def bsr_spgemm_numeric(a_blocks, b_blocks, contrib_a, contrib_b, contrib_n,
                       *, interpret: bool = False):
    """Numeric phase. a_blocks: (nnzb_A, bs, bs); b_blocks: (nnzb_B, bs, bs);
    plan arrays from plan_bsr_numeric. Returns (nnzb_C, bs, bs)."""
    nnzb_c, t_max = contrib_a.shape
    bs = a_blocks.shape[1]
    grid = (nnzb_c, t_max)
    return pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, bs, bs), lambda s, t, ca, cb, cn: (ca[s, t], 0, 0)),
                pl.BlockSpec((1, bs, bs), lambda s, t, ca, cb, cn: (cb[s, t], 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, bs, bs), lambda s, t, ca, cb, cn: (s, 0, 0)),
            scratch_shapes=[pltpu.VMEM((bs, bs), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((nnzb_c, bs, bs), a_blocks.dtype),
        interpret=interpret,
    )(contrib_a, contrib_b, contrib_n, a_blocks, b_blocks)


def bsr_spgemm_ref(a_blocks, a_indptr, a_indices, b_blocks, b_indptr,
                   b_indices, c_indptr, c_indices):
    """Pure-numpy oracle: per-C-block sum of A_ie @ B_ef products."""
    a_blocks = np.asarray(a_blocks)
    b_blocks = np.asarray(b_blocks)
    bs = a_blocks.shape[1]
    out = np.zeros((c_indptr[-1], bs, bs), a_blocks.dtype)
    mb = len(a_indptr) - 1
    for i in range(mb):
        cmap = {
            int(c): s for s, c in enumerate(c_indices[c_indptr[i]: c_indptr[i + 1]],
                                            start=c_indptr[i])
        }
        for e in range(a_indptr[i], a_indptr[i + 1]):
            j = int(a_indices[e])
            for f in range(b_indptr[j], b_indptr[j + 1]):
                c = int(b_indices[f])
                out[cmap[c]] += a_blocks[e].astype(np.float32) @ \
                    b_blocks[f].astype(np.float32)
    return out
