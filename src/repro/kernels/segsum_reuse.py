"""Pallas TPU kernel: the Reuse-case hot loop (gather-multiply-segment-sum).

Replays a precomposed ``SpgemmPlan`` (v2) numerically: for every product t in
sorted order, ``C[seg_ids[t]] += A_values[a_slot_s[t]] * B_values[b_slot_s[t]]``.
This is the paper's Thread-Flat-Parallel numeric variant mapped to the TPU's
regime (DESIGN.md §2): the flat multiplication space is tiled over the grid,
gathers become one-hot MXU matmuls (the same scatter==matmul trick as
``spgemm_numeric``), and the sorted-segment property replaces GPU atomics.

Why sortedness makes this a windowed kernel: consecutive sorted products have
segment ids differing by 0 or 1, so an FM_TILE-long product tile touches a
*contiguous* output window of width <= FM_TILE starting at its first segment
id. Each grid step reduces its tile into that window with one one-hot matmul
and accumulates read-modify-write — safe because the TPU grid is sequential.
The window's store offset is rounded down to a LANES (128) boundary and its
width widened by one lane group, so the dynamic store on the minor-most
dimension stays lane-aligned for Mosaic. Padding products carry the sentinel
``seg_ids == nnz_cap``; they are masked to zero before the reduction, so
they contribute nothing wherever their window rows land.

The output buffer is over-allocated by one window (``nnz_cap + FM_TILE +
LANES``) so a tail window still stores in bounds; the wrapper slices the
live prefix back off.

Precision: accumulation is f32 (the MXU regime), and the result is cast to
``result_type(a, b)`` — so unlike ``numeric_reuse`` this kernel does NOT
widen f64 operands. ``ReuseExecutor`` therefore routes f64 replays to the
XLA path, and keeps the kernel as an explicit ``backend="pallas"`` opt-in.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# products per grid step (the f_m tile) and one-hot gather tile width along
# the value buffers — both MXU-friendly multiples of 128
FM_TILE = 512
VAL_TILE = 512
LANES = 128  # lane-group alignment for the windowed dynamic store


def _gather_row(val_ref, slots):
    """Gather ``val_ref[0, slots]`` as (1, FM_TILE) f32 via tiled one-hot
    matmuls — the MXU replacement for an unsupported vector gather."""
    n = val_ref.shape[1]
    t = slots.shape[0]

    def body(c, acc):
        base = c * VAL_TILE
        chunk = pl.load(
            val_ref, (slice(None), pl.dslice(base, VAL_TILE))
        ).astype(jnp.float32)  # (1, VAL_TILE)
        onehot = (
            base + jax.lax.broadcasted_iota(jnp.int32, (VAL_TILE, t), 0)
            == slots[None, :]
        ).astype(jnp.float32)  # (VAL_TILE, t)
        return acc + jnp.dot(chunk, onehot, preferred_element_type=jnp.float32)

    return jax.lax.fori_loop(0, n // VAL_TILE, body, jnp.zeros((1, t), jnp.float32))


def _kernel(a_val_ref, b_val_ref, a_slot_ref, b_slot_ref, seg_ref, out_ref):
    step = pl.program_id(0)
    fm_t = a_slot_ref.shape[1]
    win = fm_t + LANES
    nnz_cap = out_ref.shape[1] - win  # wrapper pads the output by one window

    @pl.when(step == 0)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    segs = seg_ref[0, :]  # (fm_t,) non-decreasing; sentinel nnz_cap at tail
    prod = _gather_row(a_val_ref, a_slot_ref[0, :]) * _gather_row(
        b_val_ref, b_slot_ref[0, :]
    )  # (1, fm_t)
    prod = jnp.where((segs < nnz_cap)[None, :], prod, 0.0)

    # in-tile sorted-segment reduction: ids step by <= 1 per product, so all
    # live segments land in [seg0, seg0 + fm_t); aligning the window start
    # down to a lane group keeps the dynamic store lane-aligned and one
    # one-hot matmul computes every window slot's partial sum at once
    base = (segs[0] // LANES) * LANES
    local = segs - base  # live products: in [0, fm_t + LANES)
    onehot = (
        local[:, None] == jax.lax.broadcasted_iota(jnp.int32, (fm_t, win), 1)
    ).astype(jnp.float32)  # (fm_t, win); masked rows contribute zero
    window = jnp.dot(prod, onehot, preferred_element_type=jnp.float32)

    cur = pl.load(out_ref, (slice(None), pl.dslice(base, win)))
    pl.store(
        out_ref,
        (slice(None), pl.dslice(base, win)),
        cur + window.astype(out_ref.dtype),
    )


def _pad_to(x: jax.Array, size: int, fill=0) -> jax.Array:
    return x if x.shape[0] == size else jnp.pad(
        x, (0, size - x.shape[0]), constant_values=fill
    )


@functools.partial(jax.jit, static_argnames=("nnz_cap", "interpret"))
def segsum_reuse_arrays(a_slot_s, b_slot_s, seg_ids, a_values, b_values, *,
                        nnz_cap: int, interpret: bool = False) -> jax.Array:
    """Kernel entry on raw plan arrays. Returns (nnz_cap,) C values.

    a_slot_s/b_slot_s/seg_ids: (fm_cap,) int32, sorted product order with
    sentinel ``seg_ids == nnz_cap`` on padding; a_values/b_values: operand
    value buffers. Accumulates in f32 and casts to result_type(a, b) — f64
    operands lose precision here; use ``numeric_reuse`` for those.
    """
    out_dtype = jnp.result_type(a_values, b_values)
    fm_cap = a_slot_s.shape[0]
    fm_pad = -(-fm_cap // FM_TILE) * FM_TILE
    # grid padding: slots clip to 0 (any live value — masked), segs to sentinel
    a_slot_s = _pad_to(a_slot_s.astype(jnp.int32), fm_pad)[None, :]
    b_slot_s = _pad_to(b_slot_s.astype(jnp.int32), fm_pad)[None, :]
    seg_ids = _pad_to(seg_ids.astype(jnp.int32), fm_pad, fill=nnz_cap)[None, :]
    na = -(-a_values.shape[0] // VAL_TILE) * VAL_TILE
    nb = -(-b_values.shape[0] // VAL_TILE) * VAL_TILE
    a_values = _pad_to(a_values, na)[None, :]
    b_values = _pad_to(b_values, nb)[None, :]

    grid = (fm_pad // FM_TILE,)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, na), lambda t: (0, 0)),
            pl.BlockSpec((1, nb), lambda t: (0, 0)),
            pl.BlockSpec((1, FM_TILE), lambda t: (0, t)),
            pl.BlockSpec((1, FM_TILE), lambda t: (0, t)),
            pl.BlockSpec((1, FM_TILE), lambda t: (0, t)),
        ],
        out_specs=pl.BlockSpec((1, nnz_cap + FM_TILE + LANES), lambda t: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, nnz_cap + FM_TILE + LANES), jnp.float32),
        interpret=interpret,
    )(a_values, b_values, a_slot_s, b_slot_s, seg_ids)
    return out[0, :nnz_cap].astype(out_dtype)


def segsum_reuse(plan, a_values, b_values, *, interpret: bool = False) -> jax.Array:
    """Replay a ``SpgemmPlan`` numerically with the Pallas kernel.

    Same structure contract as ``core.spgemm.numeric_reuse``, but f32
    accumulation (see module docstring — f64 operands belong on the XLA
    path). Select it through ``ReuseExecutor(..., backend="pallas")``. Pass
    ``interpret=True`` off-TPU.
    """
    return segsum_reuse_arrays(
        plan.a_slot_s, plan.b_slot_s, plan.seg_ids, a_values, b_values,
        nnz_cap=plan.indices.shape[0], interpret=interpret,
    )
