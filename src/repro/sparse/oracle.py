"""Pure-numpy SpGEMM oracles used by every test in the repo.

``gustavson_numpy`` is a literal transcription of the paper's Algorithm 1
(row-wise Gustavson with a dict accumulator) — the semantic ground truth.
``dense_spgemm_oracle`` is the O(m*n*k) densified check.
"""
from __future__ import annotations

import numpy as np

from repro.sparse.formats import CSR


def dense_spgemm_oracle(a: CSR, b: CSR) -> np.ndarray:
    return np.asarray(a.to_dense()) @ np.asarray(b.to_dense())


def gustavson_numpy(a: CSR, b: CSR):
    """Algorithm 1 of the paper. Returns (indptr, indices, values) with
    per-row sorted column indices, plus per-row flops f_m (for MAXRF checks).
    """
    a_indptr = np.asarray(a.indptr)
    a_indices = np.asarray(a.indices)
    a_values = np.asarray(a.values)
    b_indptr = np.asarray(b.indptr)
    b_indices = np.asarray(b.indices)
    b_values = np.asarray(b.values)
    m = a.m

    indptr = np.zeros(m + 1, np.int32)
    all_cols, all_vals = [], []
    row_flops = np.zeros(m, np.int64)
    for i in range(m):
        acc: dict[int, float] = {}
        for e in range(a_indptr[i], a_indptr[i + 1]):
            j = int(a_indices[e])
            av = a_values[e]
            lo, hi = int(b_indptr[j]), int(b_indptr[j + 1])
            row_flops[i] += hi - lo
            for f in range(lo, hi):
                c = int(b_indices[f])
                acc[c] = acc.get(c, 0.0) + av * b_values[f]
        cols = np.array(sorted(acc.keys()), np.int32)
        all_cols.append(cols)
        all_vals.append(np.array([acc[int(c)] for c in cols], a_values.dtype))
        indptr[i + 1] = indptr[i] + len(cols)
    indices = np.concatenate(all_cols) if all_cols else np.zeros(0, np.int32)
    values = np.concatenate(all_vals) if all_vals else np.zeros(0, a_values.dtype)
    return indptr, indices, values, row_flops


def gustavson_ell_structure(a: CSR, b: CSR):
    """Symbolic structure of C = A*B in ELL layout, from the numpy oracle.

    Returns ``(c_idx, c_nnz)`` numpy arrays — ``c_idx`` (m, rC) per-row
    sorted columns (padded slots 0), ``c_nnz`` (m,) live widths — the
    numeric-phase kernels' structure inputs. Shared by the kernel tests and
    the accumulator-crossover example.
    """
    ip, ind, _, _ = gustavson_numpy(a, b)
    r_c = max(int(np.diff(ip).max()), 1)
    c_idx = np.zeros((a.m, r_c), np.int32)
    c_nnz = np.diff(ip).astype(np.int32)
    for i in range(a.m):
        c_idx[i, : c_nnz[i]] = ind[ip[i]: ip[i + 1]]
    return c_idx, c_nnz
