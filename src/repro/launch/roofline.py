"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch, shape, mesh), in seconds (TPU v5e constants):
  compute    = HLO_FLOPs / (chips * 197e12)
  memory     = HLO_bytes / (chips * 819e9)
  collective = collective_bytes_per_chip / 50e9   (per-link, ICI)

CALIBRATION (verified empirically in this container): with SPMD
partitioning, compiled.cost_analysis() and memory_analysis() describe the
PER-CHIP module — a 16-way-sharded 2N^3-FLOP matmul reports 2N^3/16. So
per-chip flops/peak == HLO_total/(chips*peak): the spec formula, one chip at
a time. Collective result shapes in the per-chip HLO are per-chip bytes.
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s / chip
ICI_BW = 50e9  # B/s / link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-kind result bytes of every collective in the (SPMD, per-chip)
    module. Returns {'all-reduce': bytes, ..., 'total': bytes, 'count': n}."""
    out: dict[str, float] = {}
    count = 0
    for m in _COLL_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        # skip the *-done wrappers (they repeat the shape but have no '(')
        b = _shape_bytes(shape_str)
        out[kind] = out.get(kind, 0) + b
        count += 1
    out["total"] = sum(v for k, v in out.items() if k != "total")
    out["count"] = count
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float  # per-chip (SPMD module; see calibration note)
    hlo_bytes: float
    coll_bytes_per_chip: float
    coll_breakdown: dict
    bytes_per_chip_peak: float  # from memory_analysis
    model_flops: float  # 6*N*D (or 6*N_active*D)

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / PEAK_FLOPS  # per-chip flops

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / HBM_BW  # per-chip bytes

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_chip / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / max(self.chips * self.hlo_flops, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """compute term / total (how close the dominant mix is to pure
        compute — 1.0 == perfectly compute-bound at the roofline)."""
        bound = max(self.t_compute, self.t_memory, self.t_collective)
        return self.t_compute / bound if bound else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh,
            "hlo_flops_per_chip": self.hlo_flops,
            "hlo_bytes_per_chip": self.hlo_bytes,
            "model_flops": self.model_flops,
            "xla_flops_raw": getattr(self, "xla_flops", None),
            "xla_bytes_raw": getattr(self, "xla_bytes", None),
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "useful_flops_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
            "bytes_per_chip_peak": self.bytes_per_chip_peak,
            "coll_breakdown": self.coll_breakdown,
        }


def model_flops_for(cfg, shape) -> float:
    """6*N*D per the spec: N = (active) params, D = tokens per step.

    decode steps process global_batch tokens; train/prefill process
    global_batch * seq_len.
    """
    n = cfg.active_param_count()
    if shape.kind == "decode":
        d = shape.global_batch
    else:
        d = shape.global_batch * shape.seq_len
    mult = 6 if shape.kind == "train" else 2
    return float(mult * n * d)


def analyze(compiled, *, arch: str, shape, mesh, cfg) -> Roofline:
    """Primary costs come from the multiplicity-aware HLO parser
    (launch/hlo_cost.py) because XLA's cost_analysis() counts while-loop
    (scan) bodies once — verified empirically; see hlo_cost docstring."""
    from repro.launch.hlo_cost import analyze_hlo

    chips = 1
    for n in mesh.axis_names:
        chips *= mesh.shape[n]
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    hlo = compiled.as_text()
    parsed = analyze_hlo(hlo)
    flops = float(parsed["flops"])
    byts = float(parsed["bytes"])
    coll = dict(parsed["collectives"])
    mem = compiled.memory_analysis()
    peak = 0.0
    if mem is not None:
        try:
            peak = float(
                mem.temp_size_in_bytes + mem.argument_size_in_bytes
                + mem.output_size_in_bytes
            )
        except AttributeError:
            peak = 0.0
    mesh_name = "x".join(str(mesh.shape[n]) for n in mesh.axis_names)
    r = Roofline(
        arch=arch, shape=shape.name, mesh=mesh_name, chips=chips,
        hlo_flops=flops, hlo_bytes=byts,
        coll_bytes_per_chip=float(coll["total"]),
        coll_breakdown=coll, bytes_per_chip_peak=peak,
        model_flops=model_flops_for(cfg, shape),
    )
    # keep XLA's raw (scan-undercounting) numbers for reference
    r.xla_flops = float(cost.get("flops", 0.0))  # type: ignore[attr-defined]
    r.xla_bytes = float(cost.get("bytes accessed", 0.0))  # type: ignore[attr-defined]
    return r
