"""KKSPGEMM meta-algorithm (paper §3.3, Table 1).

The paper's selection constants are kept verbatim:
  * CPUs/KNLs: KKDENSE when k < 250 000, KKMEM otherwise.
  * GPUs:      KKMEM when average row flops < 256, KKLP otherwise.
  * ARS estimate for symbolic sizing: f_m / 8 ("every 8th multiplication
    reduces to the same nonzero").

TPU mapping (DESIGN.md §2): "dense" = dense-accumulator paths (XLA scatter /
Pallas dense-tile kernel), "sparse" = sorted-segment flat-parallel path,
"hash" = Pallas LP-hash kernel. The k cutoff doubles as a memory guard for
the O(m*k) dense accumulator.
"""
from __future__ import annotations

from repro.sparse.formats import CSR

DENSE_K_CUTOFF = 250_000  # paper §3.3
AVG_ROW_FLOPS_CUTOFF = 256  # paper §3.3 (GPU variant selection)
ARS_REDUCTION_GUESS = 8  # paper §3.3: every 8th multiply collides
DENSE_BYTES_BUDGET = 1 << 30  # 1 GiB guard for the XLA dense accumulator

# Capacity padding policies for the static-shape caps (fm_cap / nnz_cap / ELL
# widths). "exact8" is the tightest lane-aligned cap; "pow2" rounds up to
# geometric x2 buckets so matrices of similar size share one compiled
# executable instead of each minting its own (the recompile amortization that
# makes the paper's Reuse case pay off under XLA).
PAD_POLICIES = ("exact8", "pow2")
DEFAULT_PAD_POLICY = "pow2"
CAPACITY_FLOOR = 8  # minimum cap: one 8-lane sublane


def round_capacity(x: int, policy: str = DEFAULT_PAD_POLICY) -> int:
    """Round a size up to a static capacity under the given pad policy.

    "exact8": next multiple of 8 (tight; every distinct size recompiles).
    "pow2":   next power of two (geometric buckets; sizes within a x2 band
              share the same compiled executable).
    """
    x = max(int(x), 1)
    if policy == "exact8":
        return max(-(-x // 8) * 8, CAPACITY_FLOOR)
    if policy == "pow2":
        return max(1 << (x - 1).bit_length(), CAPACITY_FLOOR)
    raise ValueError(f"unknown pad_policy {policy!r}; expected one of {PAD_POLICIES}")


def choose_method(a: CSR, b: CSR, stats: dict) -> str:
    """Return 'dense' or 'sparse' for the XLA numeric phase."""
    k = b.k
    dense_bytes = a.m * k * 4 * 2  # values + occupancy
    if k < DENSE_K_CUTOFF and dense_bytes <= DENSE_BYTES_BUDGET:
        return "dense"
    return "sparse"


def choose_kernel(a: CSR, b: CSR, stats: dict) -> str:
    """Return 'dense_acc' (KKMEM-position: thread-sequential, modest rows) or
    'flat_lp' (KKLP-position: flat-parallel for flop-heavy rows) for the
    Pallas path — the paper's GPU rule on average row flops."""
    fm = max(stats.get("fm", 0), 1)
    avg_row_flops = fm / max(a.m, 1)
    return "dense_acc" if avg_row_flops < AVG_ROW_FLOPS_CUTOFF else "flat_lp"


def estimate_ars(fm: int) -> int:
    """Average output row size estimate used before symbolic (paper §3.3)."""
    return max(fm // ARS_REDUCTION_GUESS, 1)
