"""End-to-end driver: train a ~100M-param llama-style model for a few
hundred steps on CPU with checkpoint/restart.

    PYTHONPATH=src python examples/train_lm.py --steps 300

This uses the same train_step / data / checkpoint stack the production
launcher (repro.launch.train) lowers onto the 256/512-chip meshes.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import latest_step, restore, save
from repro.configs.base import ModelConfig
from repro.data import SyntheticLMDataset
from repro.models import NO_SHARDING, init_params
from repro.train import AdamWConfig, adamw_init, make_train_step

# ~100M params: 8 layers, d=512, vocab 32k
CONFIG_100M = ModelConfig(
    name="demo-100m",
    family="dense",
    num_layers=8,
    d_model=512,
    num_heads=8,
    num_kv_heads=4,
    d_ff=1536,
    vocab_size=32_000,
    head_dim=64,
    tie_embeddings=True,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_demo_ckpt")
    args = ap.parse_args()

    cfg = CONFIG_100M
    print(f"params: {cfg.param_count() / 1e6:.1f}M")
    data = SyntheticLMDataset(vocab_size=cfg.vocab_size, seq_len=args.seq,
                              global_batch=args.batch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    start = 0
    last = latest_step(args.ckpt_dir)
    if last is not None:
        (params, opt), _ = restore(args.ckpt_dir, last, (params, opt))
        start = last
        print(f"resumed from step {start}")

    step = jax.jit(make_train_step(cfg, NO_SHARDING,
                                   AdamWConfig(lr=1e-3, warmup_steps=50)),
                   donate_argnums=(0, 1))
    t0 = time.time()
    for s in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in data.get_batch(s).items()}
        params, opt, m = step(params, opt, batch)
        if (s + 1) % 20 == 0:
            loss = float(m["loss"])
            rate = args.batch * args.seq * 20 / (time.time() - t0)
            t0 = time.time()
            print(f"step {s + 1:4d}  loss {loss:.4f}  {rate:,.0f} tok/s")
            assert np.isfinite(loss)
        if (s + 1) % 100 == 0:
            save(args.ckpt_dir, s + 1, (params, opt))
            print(f"checkpoint @ {s + 1}")
    print("done")


if __name__ == "__main__":
    main()
