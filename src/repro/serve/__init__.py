"""repro.serve — the serving tier.

engine:         token-serving ServeEngine (prefill/decode over pinned plans).
spgemm_service: overload-safe SpGEMM request serving (bounded admission,
                deadlines, grouped dispatch, circuit-broken degradation).
breaker:        per-kernel circuit breaker over the degradation ladder.
warmer:         traffic-log driven plan-cache warming.
"""
from repro.serve.breaker import CircuitBreaker
from repro.serve.engine import ServeEngine, prefill_to_cache
from repro.serve.spgemm_service import SparseResponse, SparseService
from repro.serve.warmer import TrafficEntry, TrafficLog, warm_plan_cache

__all__ = [
    "ServeEngine",
    "prefill_to_cache",
    "SparseService",
    "SparseResponse",
    "CircuitBreaker",
    "TrafficLog",
    "TrafficEntry",
    "warm_plan_cache",
]
