"""Batched reuse executor + v2 precomposed plan + Pallas segsum kernel tests.

Hypothesis-free (runs on the bare container). Covers the PR 3 contracts:
  * plan v2 precomposition is exactly the jnp.lexsort reference composition
  * numeric_reuse accumulates in result_type (mixed dtypes don't downcast)
  * ReuseExecutor.apply never retraces and never re-hashes across calls
  * apply_batched == per-call numeric_reuse loop, bitwise
  * spgemm_grouped: mixed structures -> one batched dispatch per group,
    results correct and in input order
  * Pallas segsum_reuse (interpret) == numeric_reuse / ref oracle
  * _repad_csr refuses to truncate live entries
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DISPATCH_COUNTS,
    PlanCache,
    ReuseExecutor,
    numeric_reuse,
    reset_dispatch_counts,
    spgemm,
    spgemm_grouped,
)
from repro.core.spgemm import _repad_csr, expand_products
from repro.kernels import ref, segsum_reuse, segsum_reuse_arrays
from repro.sparse import CSR, dense_spgemm_oracle, galerkin_triple, random_csr


def _with_values(mat: CSR, seed: int, dtype=jnp.float32) -> CSR:
    rng = np.random.default_rng(seed)
    vals = jnp.asarray(rng.standard_normal(mat.nnz_cap), dtype)
    return CSR(mat.indptr, mat.indices, vals, mat.shape)


def _reference_plan_arrays(a: CSR, b: CSR, fm_cap: int, nnz_cap: int):
    """Independent v2-plan construction: expansion + jnp.lexsort composition."""
    ex = expand_products(a, b, fm_cap)
    order = jnp.lexsort((ex.col, ex.row))
    rows_s, cols_s, valid_s = ex.row[order], ex.col[order], ex.valid[order]
    heads = jnp.concatenate(
        [jnp.ones((1,), jnp.bool_),
         (rows_s[1:] != rows_s[:-1]) | (cols_s[1:] != cols_s[:-1])]
    ) & valid_s
    seg = (jnp.cumsum(heads.astype(jnp.int32)) - 1).clip(0)
    seg = jnp.where(valid_s, seg, nnz_cap)
    return ex.a_slot[order], ex.b_slot[order], seg.astype(jnp.int32)


def test_plan_v2_precomposed_matches_lexsort_reference():
    """plan.a_slot_s/b_slot_s/seg_ids must equal composing the expansion with
    a jnp.lexsort permutation by hand — and the replay must match bitwise."""
    a = random_csr(33, 41, 3.0, 1)
    b = random_csr(41, 29, 2.5, 2)
    res = spgemm(a, b, method="sparse", plan_cache=PlanCache())
    plan = res.plan
    fm_cap = plan.seg_ids.shape[0]
    nnz_cap = plan.indices.shape[0]
    ref_a, ref_b, ref_seg = _reference_plan_arrays(a, b, fm_cap, nnz_cap)
    np.testing.assert_array_equal(np.asarray(plan.seg_ids), np.asarray(ref_seg))
    # slots only matter where the product is live (sentinel seg == nnz_cap)
    live = np.asarray(ref_seg) < nnz_cap
    np.testing.assert_array_equal(np.asarray(plan.a_slot_s)[live],
                                  np.asarray(ref_a)[live])
    np.testing.assert_array_equal(np.asarray(plan.b_slot_s)[live],
                                  np.asarray(ref_b)[live])
    got = numeric_reuse(plan, a.values, b.values)
    want = ref.segsum_reuse_ref(ref_a, ref_b, ref_seg, a.values, b.values,
                                nnz_cap)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_numeric_reuse_mixed_dtype_accumulates_in_result_type():
    """f16 * f32 must accumulate (and return) f32, not downcast to f16."""
    a = random_csr(24, 30, 3.0, 7)
    b = random_csr(30, 20, 2.0, 8)
    res = spgemm(a, b, method="sparse", plan_cache=PlanCache())
    a16 = _with_values(a, 3, jnp.float16)
    out = numeric_reuse(res.plan, a16.values, b.values)
    assert out.dtype == jnp.result_type(jnp.float16, jnp.float32) == jnp.float32
    want = numeric_reuse(res.plan, a16.values.astype(jnp.float32), b.values)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-3, atol=1e-3)


def test_executor_apply_zero_retraces_zero_rehashes():
    """Acceptance: after the first apply, repeated replays on a pinned plan
    trigger zero retraces of ANY jitted stage and zero structure hashes."""
    from repro.core import telemetry

    jax.clear_caches()
    a = random_csr(48, 48, 4.0, 11)
    b = random_csr(48, 48, 3.0, 12)
    ex = ReuseExecutor.from_matrices(a, b, plan_cache=PlanCache())
    ex.apply(a.values, b.values)  # warm the dispatch
    before = telemetry.snapshot()
    rng = np.random.default_rng(0)
    for _ in range(10):
        av = jnp.asarray(rng.standard_normal(a.nnz_cap), jnp.float32)
        bv = jnp.asarray(rng.standard_normal(b.nnz_cap), jnp.float32)
        jax.block_until_ready(ex.apply(av, bv))
    delta = telemetry.diff(before, telemetry.snapshot())
    assert "trace" not in delta, delta  # zero retraces
    assert "hash" not in delta, delta  # zero structure re-hashes
    assert delta == {"dispatch": {"apply": 10}}, delta  # ...and nothing else


def test_apply_batched_matches_per_call_loop_bitwise():
    a = random_csr(30, 40, 3.0, 21)
    b = random_csr(40, 35, 2.0, 22)
    ex = ReuseExecutor.from_matrices(a, b, plan_cache=PlanCache())
    rng = np.random.default_rng(1)
    a_stack = jnp.asarray(rng.standard_normal((8, a.nnz_cap)), jnp.float32)
    b_stack = jnp.asarray(rng.standard_normal((8, b.nnz_cap)), jnp.float32)
    got = ex.apply_batched(a_stack, b_stack)
    assert got.shape == (8, ex.nnz_cap)
    loop = jnp.stack(
        [numeric_reuse(ex.plan, a_stack[i], b_stack[i]) for i in range(8)]
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(loop))


def test_apply_batched_broadcast_unbatched_operand():
    """Fixed P against a batch of A values (the multigrid serving shape)."""
    _, a, p = galerkin_triple(16, 16, 4)
    ex = ReuseExecutor.from_matrices(a, p, plan_cache=PlanCache())
    rng = np.random.default_rng(2)
    a_stack = jnp.asarray(rng.standard_normal((5, a.nnz_cap)), jnp.float32)
    got = ex.apply_batched(a_stack, p.values)
    loop = jnp.stack(
        [numeric_reuse(ex.plan, a_stack[i], p.values) for i in range(5)]
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(loop))
    with pytest.raises(ValueError):
        ex.apply_batched(a_stack[0], p.values)  # neither operand stacked


def test_spgemm_grouped_empty_batch_is_noop():
    """An empty request list (a serving tick with nothing admitted) is a
    legal no-op: empty result, zero dispatches — and generators work too."""
    reset_dispatch_counts()
    assert spgemm_grouped([]) == []
    assert spgemm_grouped(iter(())) == []
    assert DISPATCH_COUNTS["apply"] == 0
    assert DISPATCH_COUNTS["apply_batched"] == 0


def test_spgemm_grouped_mixed_structures():
    """Interleaved structures: results correct + one dispatch per group."""
    a1 = random_csr(26, 30, 3.0, 31)
    b1 = random_csr(30, 24, 2.0, 32)
    a2 = random_csr(14, 18, 2.0, 33)
    b2 = random_csr(18, 22, 2.0, 34)
    pairs = [
        (a1, b1),
        (a2, b2),
        (_with_values(a1, 41), _with_values(b1, 42)),
        (_with_values(a2, 43), b2),
        (_with_values(a1, 44), b1),
    ]
    reset_dispatch_counts()
    outs = spgemm_grouped(pairs, plan_cache=PlanCache())
    assert len(outs) == len(pairs)
    for (pa, pb), c in zip(pairs, outs):
        np.testing.assert_allclose(
            np.asarray(c.to_dense()), dense_spgemm_oracle(pa, pb),
            rtol=1e-4, atol=1e-4,
        )
    # two structure groups (sizes 3 and 2) -> exactly two batched dispatches
    assert DISPATCH_COUNTS["apply_batched"] == 2
    assert DISPATCH_COUNTS["apply"] == 0


def test_spgemm_grouped_mixed_dtypes_keep_per_call_contract():
    """Same structure, different value dtypes: stacking must not promote —
    each pair's result dtype equals its per-call numeric_reuse dtype."""
    a = random_csr(22, 22, 2.5, 55)
    b = random_csr(22, 22, 2.5, 56)
    pairs = [(a, b), (_with_values(a, 1, jnp.float16), _with_values(b, 2, jnp.float16))]
    outs = spgemm_grouped(pairs, plan_cache=PlanCache())
    assert outs[0].values.dtype == jnp.float32
    assert outs[1].values.dtype == jnp.float16


def test_spgemm_grouped_reuses_plan_cache():
    """A second grouped batch over known structures skips expansion: the
    plans come from the cache (hits == number of groups)."""
    cache = PlanCache()
    a = random_csr(20, 20, 2.5, 51)
    b = random_csr(20, 20, 2.5, 52)
    pairs = [(a, b), (_with_values(a, 1), _with_values(b, 2))]
    spgemm_grouped(pairs, plan_cache=cache)
    misses = cache.misses
    spgemm_grouped(pairs, plan_cache=cache)
    assert cache.misses == misses  # no new plan builds
    assert cache.hits >= 1


@pytest.mark.parametrize("seed,m,n,k,d", [
    (1, 40, 50, 45, 3.0),
    (2, 9, 7, 5, 1.5),
    (3, 150, 150, 150, 6.0),  # fm_cap > FM_TILE: multi-tile grid path
])
def test_pallas_segsum_matches_numeric_reuse(seed, m, n, k, d):
    from repro.kernels.segsum_reuse import FM_TILE

    a = random_csr(m, n, d, seed)
    b = random_csr(n, k, d, seed + 100)
    res = spgemm(a, b, method="sparse", plan_cache=PlanCache())
    if seed == 3:  # construction precondition: cross-tile RMW must exercise
        assert res.plan.seg_ids.shape[0] > FM_TILE
    want = numeric_reuse(res.plan, a.values, b.values)
    got = segsum_reuse(res.plan, a.values, b.values, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_pallas_segsum_matches_ref_oracle():
    a = random_csr(21, 17, 2.0, 61)
    b = random_csr(17, 19, 2.0, 62)
    res = spgemm(a, b, method="sparse", plan_cache=PlanCache())
    p = res.plan
    want = ref.segsum_reuse_ref(p.a_slot_s, p.b_slot_s, p.seg_ids,
                                a.values, b.values, p.indices.shape[0])
    got = segsum_reuse_arrays(p.a_slot_s, p.b_slot_s, p.seg_ids,
                              a.values, b.values,
                              nnz_cap=p.indices.shape[0], interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_executor_pallas_backend_interpret():
    a = random_csr(25, 25, 3.0, 71)
    b = random_csr(25, 25, 3.0, 72)
    ex_xla = ReuseExecutor.from_matrices(a, b, plan_cache=PlanCache(),
                                         backend="xla")
    ex_pl = ReuseExecutor(ex_xla.plan, backend="pallas", interpret=True)
    got = ex_pl.apply(a.values, b.values)
    want = ex_xla.apply(a.values, b.values)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    with pytest.raises(ValueError):
        ReuseExecutor(ex_xla.plan, backend="cuda")


def test_repad_csr_raises_on_truncation():
    a = random_csr(16, 16, 3.0, 81)
    nnz = int(a.indptr[-1])
    assert nnz > 8  # construction precondition for the truncation case
    with pytest.raises(ValueError, match="truncated"):
        _repad_csr(a, 8)
    # growing (and the no-op case) still work
    assert _repad_csr(a, a.nnz_cap).nnz_cap == a.nnz_cap
    grown = _repad_csr(a, a.nnz_cap + 8)
    assert grown.nnz_cap == a.nnz_cap + 8
    np.testing.assert_allclose(np.asarray(grown.to_dense()),
                               np.asarray(a.to_dense()))


def test_executor_rejects_none_plan_and_bad_donate():
    """Dense spgemm returns plan=None (no Reuse path): constructing an
    executor from it must fail at construction, not inside a jit."""
    a = random_csr(10, 12, 2.0, 95)
    b = random_csr(12, 8, 2.0, 96)
    res = spgemm(a, b, method="dense")
    assert res.plan is None
    with pytest.raises(ValueError, match="plan=None"):
        ReuseExecutor(res.plan)
    ex = ReuseExecutor.from_matrices(a, b, plan_cache=PlanCache())
    with pytest.raises(ValueError, match="donate"):
        ex.apply(a.values, b.values, donate="everything")


def test_executor_per_operand_donation():
    """donate='a' must leave the shared B buffer alive across calls (the
    fixed-prolongator serving loop)."""
    a = random_csr(20, 20, 2.0, 97)
    b = random_csr(20, 20, 2.0, 98)
    ex = ReuseExecutor.from_matrices(a, b, plan_cache=PlanCache())
    want = np.asarray(ex.apply(a.values, b.values))
    rng = np.random.default_rng(3)
    for _ in range(3):  # b.values passed every call: must never be donated
        av = jnp.asarray(rng.standard_normal(a.nnz_cap), jnp.float32)
        out = ex.apply(av, b.values, donate="a")
    np.testing.assert_array_equal(np.asarray(ex.apply(a.values, b.values)),
                                  want)


def test_executor_to_csr_roundtrip():
    a = random_csr(18, 20, 2.0, 91)
    b = random_csr(20, 15, 2.0, 92)
    ex = ReuseExecutor.from_matrices(a, b, plan_cache=PlanCache())
    c = ex.to_csr(ex.apply(a.values, b.values))
    np.testing.assert_allclose(np.asarray(c.to_dense()),
                               dense_spgemm_oracle(a, b), rtol=1e-4, atol=1e-4)
