"""BSR block-SpGEMM kernel: two-phase plan + MXU numeric vs numpy oracle."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels.bsr_spgemm import (
    bsr_spgemm_numeric,
    bsr_spgemm_ref,
    plan_bsr_numeric,
)
from repro.sparse import random_csr


def _random_bsr(mb, kb, avg, bs, seed):
    """Random block structure + dense blocks."""
    g = random_csr(mb, kb, avg, seed)
    indptr = np.asarray(g.indptr)
    indices = np.asarray(g.indices)[: indptr[-1]]
    rng = np.random.default_rng(seed + 100)
    blocks = rng.standard_normal((len(indices), bs, bs)).astype(np.float32)
    return indptr, indices, blocks


@pytest.mark.parametrize("mb,nb,kb,bs", [(6, 5, 7, 8), (4, 4, 4, 16)])
def test_bsr_spgemm(mb, nb, kb, bs):
    a_ip, a_ix, a_bl = _random_bsr(mb, nb, 2.0, bs, 1)
    b_ip, b_ix, b_bl = _random_bsr(nb, kb, 2.0, bs, 2)
    c_ip, c_ix, ca, cb, cn = plan_bsr_numeric(a_ip, a_ix, b_ip, b_ix)
    got = bsr_spgemm_numeric(
        jnp.asarray(a_bl), jnp.asarray(b_bl), jnp.asarray(ca),
        jnp.asarray(cb), jnp.asarray(cn), interpret=True,
    )
    want = bsr_spgemm_ref(a_bl, a_ip, a_ix, b_bl, b_ip, b_ix, c_ip, c_ix)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)


def test_bsr_dense_equivalence():
    """Densified BSR product == dense matmul of densified inputs."""
    bs = 8
    a_ip, a_ix, a_bl = _random_bsr(5, 6, 2.0, bs, 3)
    b_ip, b_ix, b_bl = _random_bsr(6, 4, 2.0, bs, 4)
    c_ip, c_ix, ca, cb, cn = plan_bsr_numeric(a_ip, a_ix, b_ip, b_ix)
    got = np.asarray(bsr_spgemm_numeric(
        jnp.asarray(a_bl), jnp.asarray(b_bl), jnp.asarray(ca),
        jnp.asarray(cb), jnp.asarray(cn), interpret=True,
    ))

    def densify(ip, ix, bl, m, k):
        out = np.zeros((m * bs, k * bs), np.float32)
        for i in range(m):
            for e in range(ip[i], ip[i + 1]):
                j = int(ix[e])
                out[i * bs:(i + 1) * bs, j * bs:(j + 1) * bs] = bl[e]
        return out

    ad = densify(a_ip, a_ix, a_bl, 5, 6)
    bd = densify(b_ip, b_ix, b_bl, 6, 4)
    cd = densify(c_ip, c_ix, got, 5, 4)
    np.testing.assert_allclose(cd, ad @ bd, rtol=1e-4, atol=1e-4)


def test_bsr_reuse():
    """Same plan, new block values — the Reuse case at block granularity."""
    bs = 8
    a_ip, a_ix, a_bl = _random_bsr(4, 4, 2.0, bs, 5)
    b_ip, b_ix, b_bl = _random_bsr(4, 4, 2.0, bs, 6)
    c_ip, c_ix, ca, cb, cn = plan_bsr_numeric(a_ip, a_ix, b_ip, b_ix)
    a2 = a_bl * 2.0
    got = np.asarray(bsr_spgemm_numeric(
        jnp.asarray(a2), jnp.asarray(b_bl), jnp.asarray(ca), jnp.asarray(cb),
        jnp.asarray(cn), interpret=True,
    ))
    want = bsr_spgemm_ref(a2, a_ip, a_ix, b_bl, b_ip, b_ix, c_ip, c_ix)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
