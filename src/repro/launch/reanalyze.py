"""Re-derive roofline records from saved HLO artifacts (no recompilation).

The dry-run saves every cell's compiled HLO (hlo/*.hlo.gz); when the cost
model in hlo_cost.py is refined, this tool regenerates the roofline columns
in-place, preserving memory_analysis / compile-time fields.
"""
from __future__ import annotations

import argparse
import gzip
import json
import os

from repro.configs import SHAPES, get_config
from repro.launch.hlo_cost import analyze_hlo
from repro.launch.roofline import HBM_BW, ICI_BW, PEAK_FLOPS, model_flops_for


def reanalyze(rec: dict, hlo_dir: str) -> dict:
    fname = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.hlo.gz"
    path = os.path.join(hlo_dir, fname)
    if not os.path.exists(path) or rec.get("status") != "ok":
        return rec
    with gzip.open(path, "rt") as f:
        parsed = analyze_hlo(f.read())
    chips = 1
    for d in rec["mesh"].split("x"):
        chips *= int(d)
    cfg = get_config(rec["arch"])
    mf = model_flops_for(cfg, SHAPES[rec["shape"]])
    flops, byts = parsed["flops"], parsed["bytes"]
    coll = parsed["collectives"]
    t_c = flops / PEAK_FLOPS
    t_m = byts / HBM_BW
    t_l = coll["total"] / ICI_BW
    bound = max(t_c, t_m, t_l)
    rec.update(
        hlo_flops_per_chip=flops,
        hlo_bytes_per_chip=byts,
        model_flops=mf,
        t_compute_s=t_c,
        t_memory_s=t_m,
        t_collective_s=t_l,
        dominant=max(
            {"compute": t_c, "memory": t_m, "collective": t_l}.items(),
            key=lambda kv: kv[1],
        )[0],
        useful_flops_ratio=mf / max(chips * flops, 1.0),
        roofline_fraction=(t_c / bound) if bound else 0.0,
        coll_breakdown=coll,
    )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--jsonl", default="dryrun_results.jsonl")
    ap.add_argument("--hlo-dir", default="hlo")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    out_path = args.out or args.jsonl
    recs = {}
    for line in open(args.jsonl):
        r = json.loads(line)
        recs[(r["arch"], r["shape"], r["mesh"])] = r
    with open(out_path + ".tmp", "w") as f:
        for key in sorted(recs):
            f.write(json.dumps(reanalyze(recs[key], args.hlo_dir)) + "\n")
    os.replace(out_path + ".tmp", out_path)
    print(f"re-analyzed {len(recs)} records -> {out_path}")


if __name__ == "__main__":
    main()
