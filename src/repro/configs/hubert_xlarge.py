"""hubert-xlarge [audio] — arXiv:2106.07447 (encoder-only, w2v2 arch).

48L, d_model=1280, 16 heads (kv=16 == MHA), d_ff=5120, vocab=504 (unit
targets). Audio frontend is a STUB: input_specs() supplies precomputed
conv-feature frame embeddings (T x 512) projected to d_model.

SpGEMM applicability: none. Encoder-only: no decode step -> decode_32k and
long_500k are skipped; prefill_32k runs as an encoder forward pass.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    head_dim=80,
    causal=False,  # bidirectional encoder
    frontend="audio",
    frontend_dim=512,
    act="gelu2",  # classic 2-matrix transformer FFN
)

SMOKE = ModelConfig(
    name="hubert-xlarge-smoke",
    family="audio",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=64,
    head_dim=16,
    causal=False,
    frontend="audio",
    frontend_dim=32,
    act="gelu2",  # classic 2-matrix transformer FFN
)

SKIP_SHAPES = {
    "decode_32k": "encoder-only arch: no decode step",
    "long_500k": "encoder-only arch: no decode step",
}
