"""Per-kernel circuit breaker for the serving tier's fast replay path.

The PR-7 degradation ladder already guarantees *correctness* under kernel
failure: a faulting Pallas replay re-dispatches the exact-XLA reference,
bitwise-correct, counted in ``telemetry.FALLBACK_COUNTS``. What it does not
bound is *cost*: under sustained traffic a persistently broken kernel makes
every request pay a failed dispatch before landing on the safe path. The
breaker closes that gap with the classic three-state machine:

  closed     — normal operation, traffic takes the fast kernel. Failures
               (ladder fallbacks, i.e. ``fault:*`` events) are timestamped;
               ``failure_threshold`` of them inside ``window_s`` opens.
  open       — traffic is routed straight to the recorded-safe kernel
               (``allow()`` returns False; each refusal is counted as a
               ``short_circuit``). After ``cooldown_s`` the breaker arms a
               probe and moves to half-open.
  half-open  — exactly ONE request is let through on the fast kernel (the
               probe). Success closes the breaker (fast path re-admitted for
               everyone); failure re-opens it for another cooldown.

Every transition is recorded in ``telemetry.BREAKER_COUNTS`` keyed
``"<name>:<event>"`` (open / half_open / close / reopen / short_circuit), so
``bench_serve`` and the chaos suite can assert breaker behavior without
poking at instance state.

Determinism: the clock is injectable (``clock=``, default
``time.monotonic``), so tests and replay harnesses drive cooldowns with a
fake clock instead of sleeping. The breaker is intentionally host-side-only
state — it never touches device dispatch itself; the service consults
``allow()`` and reports outcomes via ``record_success``/``record_failure``.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Callable

from repro.runtime.validate import SpgemmConfigError

CLOSED = "closed"
OPEN = "open"
HALF_OPEN = "half_open"


class CircuitBreaker:
    """Failure-rate gate over one named fast path (usually a kernel).

    failure_threshold: failures within ``window_s`` that trip the breaker.
    window_s:          sliding window the threshold is evaluated over.
    cooldown_s:        open -> half-open delay before the next probe.
    """

    def __init__(self, name: str, *, failure_threshold: int = 3,
                 window_s: float = 30.0, cooldown_s: float = 5.0,
                 clock: Callable[[], float] = time.monotonic):
        if failure_threshold < 1:
            raise SpgemmConfigError(
                f"failure_threshold must be >= 1, got {failure_threshold}")
        if window_s <= 0 or cooldown_s < 0:
            raise SpgemmConfigError(
                f"window_s must be > 0 and cooldown_s >= 0, got "
                f"window_s={window_s}, cooldown_s={cooldown_s}")
        self.name = name
        self.failure_threshold = failure_threshold
        self.window_s = window_s
        self.cooldown_s = cooldown_s
        self.clock = clock
        self.state = CLOSED
        self._failures: deque[float] = deque()
        self._opened_at: float | None = None
        self._probe_in_flight = False

    def _count(self, event: str) -> None:
        from repro.core.telemetry import BREAKER_COUNTS  # lazy: cycle-free

        BREAKER_COUNTS[f"{self.name}:{event}"] += 1

    def _prune(self, now: float) -> None:
        while self._failures and now - self._failures[0] > self.window_s:
            self._failures.popleft()

    def allow(self) -> bool:
        """May the next dispatch take the fast path?

        False means "route to the safe kernel" and is counted as a
        short_circuit — the caller must not silently drop the request.
        In half-open, True is handed out to exactly one caller at a time
        (the probe); everyone else short-circuits until its verdict lands.
        """
        now = self.clock()
        if self.state == OPEN:
            if now - self._opened_at >= self.cooldown_s:
                self.state = HALF_OPEN
                self._probe_in_flight = False
                self._count("half_open")
            else:
                self._count("short_circuit")
                return False
        if self.state == HALF_OPEN:
            if self._probe_in_flight:
                self._count("short_circuit")
                return False
            self._probe_in_flight = True
            return True
        return True

    def record_success(self) -> None:
        """A fast-path dispatch completed without degrading."""
        if self.state == HALF_OPEN:
            self.state = CLOSED
            self._failures.clear()
            self._probe_in_flight = False
            self._count("close")

    def record_failure(self) -> None:
        """A fast-path dispatch degraded (ladder fallback) or raised."""
        now = self.clock()
        if self.state == HALF_OPEN:
            # the probe failed: straight back to open, new cooldown
            self.state = OPEN
            self._opened_at = now
            self._probe_in_flight = False
            self._count("reopen")
            return
        self._failures.append(now)
        self._prune(now)
        if self.state == CLOSED and len(self._failures) >= self.failure_threshold:
            self.state = OPEN
            self._opened_at = now
            self._count("open")

    def snapshot(self) -> dict:
        """Host-side state for stats()/bench rows (no telemetry reads)."""
        now = self.clock()
        self._prune(now)
        return {
            "name": self.name,
            "state": self.state,
            "recent_failures": len(self._failures),
            "failure_threshold": self.failure_threshold,
            "cooldown_remaining_s": (
                max(0.0, self.cooldown_s - (now - self._opened_at))
                if self.state == OPEN else 0.0),
        }
