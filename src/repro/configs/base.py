"""Model/run configuration system.

One ``ModelConfig`` per assigned architecture (exact shapes from the public
sources cited in each config file), plus reduced smoke variants. Layer
heterogeneity (gemma2 local/global alternation, recurrentgemma's 1:2
RG-LRU:attention pattern) is expressed as a repeating ``pattern`` + optional
``tail`` so the layer stack scans over homogeneous pattern groups
(compile-time friendly for 94-layer models).
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | ssm | hybrid | vlm | audio | moe
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    # layer stacking: pattern repeated, then tail. kinds: attn | local |
    # global | rec | moe  (each kind = attention/recurrence + its FFN)
    pattern: tuple = ("attn",)
    tail: tuple = ()

    head_dim: Optional[int] = None
    window: Optional[int] = None  # sliding window for 'local' layers
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    causal: bool = True  # False => encoder (hubert)

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0

    # SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    conv_width: int = 4

    # RG-LRU (recurrentgemma)
    lru_width: Optional[int] = None

    # modality frontend stub: None | "vision" | "audio"
    frontend: Optional[str] = None
    frontend_dim: int = 0
    num_patches: int = 0  # vision: patch embeddings prepended

    norm_eps: float = 1e-6
    act: str = "silu"  # silu (swiglu) | gelu

    def __post_init__(self):
        n_pat = len(self.pattern)
        reps, rem = divmod(self.num_layers - len(self.tail), n_pat)
        if rem:
            from repro.runtime.validate import SpgemmConfigError  # cycle-free
            raise SpgemmConfigError(
                f"{self.name}: {self.num_layers} layers != "
                f"{n_pat}*k + {len(self.tail)}"
            )

    @property
    def pattern_repeats(self) -> int:
        return (self.num_layers - len(self.tail)) // len(self.pattern)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def is_encoder(self) -> bool:
        return not self.causal

    def param_count(self) -> int:
        """Approximate total parameters (embeddings + per-layer)."""
        d, hd = self.d_model, self.resolved_head_dim
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        total = emb
        if self.is_encoder:
            total += 32_768 * d  # learned positions (MAX_ENCODER_POS)
        if self.frontend:
            total += self.frontend_dim * d
        # silu/gelu are gated 3-matrix FFNs (SwiGLU/GeGLU); gelu2 is plain
        ffn = (2 if self.act == "gelu2" else 3) * d * self.d_ff
        kinds = list(self.pattern) * self.pattern_repeats + list(self.tail)
        for kind in kinds:
            if kind in ("attn", "local", "global"):
                attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) + (
                    self.num_heads * hd * d
                )
                total += attn + ffn
            elif kind == "moe":
                attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) + (
                    self.num_heads * hd * d
                )
                moe = d * self.num_experts + self.num_experts * 3 * d * self.moe_d_ff
                total += attn + moe
            elif kind == "rec":
                w = self.lru_width or d
                # block-diagonal gates: 2 * nh * (w/nh)^2 = 2 w^2 / nh
                rec = 2 * d * w + w * d + 2 * w * w // self.num_heads
                total += rec + ffn
            elif kind == "ssm":
                d_in = self.ssm_expand * d
                nheads = d_in // self.ssm_head_dim
                total += d * (2 * d_in + 2 * self.ssm_state + nheads) + d_in * d
            else:
                from repro.runtime.validate import SpgemmConfigError  # cycle-free
                raise SpgemmConfigError(f"unknown block kind {kind!r}")
        return total

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top-k experts only)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        full = self.param_count()
        kinds = list(self.pattern) * self.pattern_repeats + list(self.tail)
        n_moe = sum(1 for k in kinds if k == "moe")
        all_experts = n_moe * self.num_experts * 3 * d * self.moe_d_ff
        active = n_moe * self.experts_per_token * 3 * d * self.moe_d_ff
        return full - all_experts + active


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell: train or serve lowering."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}
