"""Benchmark harness — one function per paper table/figure.

Protocol matches the paper (§4): 1 warmup + average of 5 timed runs.
Output: ``name,us_per_call,derived`` CSV rows.

  bench_methods      — Fig 5/7: GFLOPS/s per method (KKDENSE / KKMEM-analog
                       sparse / KKSPGEMM auto) per matrix
  bench_profile      — Fig 6: performance-profile summary (wins, max
                       slowdown vs best)
  bench_compression  — Table 3 / §4.3: CF, CMRF, symbolic time +/- compression
  bench_reuse        — Fig 6(d)/(f): NoReuse vs Reuse numeric phase
  bench_reuse_batched — batched reuse replay: ReuseExecutor.apply_batched
                       (one dispatch per batch) vs a per-call numeric_reuse
                       loop; throughput in multiplies/s
  bench_compile      — recompile counts + plan-cache hit rate: same-bucket
                       structures share executables, repeats hit the cache
  bench_accumulators — the paper's accumulator trade-off: dense-acc vs
                       sorted-segment vs LP-hash numeric phase across
                       avg-row-flop regimes, with choose_kernel's pick and
                       the measured winner per regime (the Figure-style
                       crossover, tracked per-PR via BENCH_accum_*.json)
  bench_fm_groups    — Fig 8: meta-vs-fixed speedup grouped by f_m
  bench_distributed  — §multi-pod: 1-D row-wise SpGEMM scaling terms
  bench_dist         — repro.dist sharded-plan replay: latency per replay
                       count on a pinned ShardedReuseExecutor (flat curve =
                       zero per-replay host work); mesh shape in the row
  bench_train_smoke  — LM substrate: tokens/s of a smoke train step
  bench_guard        — guarded-mode overhead: replay latency per validate
                       mode (off/host/device), nan_guard and watchdog rows
                       (overhead ratios vs validate=off), plus a retry_call
                       machinery row — the failure-model cost artifact
                       (BENCH_guard_*.json)
  bench_serve        — serving-tier acceptance: sustained QPS + p50/p99
                       latency over a synthetic mixed-structure trace,
                       admission shed rates under a deliberate overload
                       burst, and breaker open/short-circuit/recovery
                       behavior with kernel faults injected mid-stream
                       (BENCH_serve_*.json)
  bench_obs          — observability overhead gate: disabled-span unit
                       cost, tracing-off replay overhead (the <= 2% CI
                       gate, with a telemetry-asserted dispatch-identity
                       bit), tracing-on ratio, and a traced chaos mini-run
                       exported as trace_obs_sample.json
                       (BENCH_obs_*.json)
  bench_autotune     — autotuner regret table: static vs fitted vs measured
                       kernel picks over the accumulator sweep (regret in us
                       vs the static rule; the acceptance artifact for
                       core/autotune), plus a live tune="measure" first-
                       sight + cached-winner replay demo with telemetry

``--quick`` runs a CI-sized smoke subset (2 suite cases; compile, reuse,
batched-reuse and dist benches only). ``--devices N`` forces an N-device
host platform (must be set before jax initializes — the flag is injected at
the top of main()) so the shard_map paths run mesh-wide on CPU-only
runners. ``--json PATH`` additionally writes the rows as machine-readable
JSON (exact derived metric values; the CSV column is a rendering of them)
so CI can archive a BENCH_*.json trajectory. Every row (and the payload)
is stamped with backend/platform/jax_version so fitted thresholds are
keyed per backend, and all bench RNG seeds are fixed constants
(``BENCH_SEED`` plus per-generator literals) so artifacts are comparable
across PRs.

``--fit-thresholds BENCH_JSON`` is a subcommand, not a bench: it loads a
previously archived benchmark payload (any run containing
``accumulators/*`` rows), fits per-backend thresholds with
``repro.core.autotune.fit_thresholds``, writes the ``TunedThresholds``
table to --json (the ``BENCH_autotune_<sha>.json`` CI artifact) and exits.
"""
from __future__ import annotations

import argparse
import json
import os
import time
import uuid

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.suite import suite
from repro.core import (
    PlanCache,
    ReuseExecutor,
    compress_matrix,
    compression_decision,
    numeric_reuse,
    reset_trace_counts,
    round_capacity,
    spgemm,
    symbolic,
)
from repro.core.spgemm import TRACE_COUNTS, numeric_fresh, symbolic_plain, symbolic_compressed
from repro.core.compression import flops_stats
from repro.sparse import CSR, random_csr

ROWS: list[str] = []
RESULTS: list[dict] = []  # structured mirror of ROWS for --json
CASES: list = []  # populated by main(); benches iterate this, not suite()

# One seed for every ad-hoc bench RNG (values-only resamples etc.); matrix
# generators carry their own per-case literals. Fixed so BENCH_*.json
# artifacts are comparable across PRs.
BENCH_SEED = 0


def _fmt_val(v) -> str:
    return f"{v:.6g}" if isinstance(v, float) else str(v)


# One id per harness invocation: lets BENCH_*.json artifacts from different
# runs be ordered (timestamp) and joined (run_id) into a trajectory.
RUN_ID = uuid.uuid4().hex[:12]


def _env_stamp() -> dict:
    """backend/platform/jax-version + run identity stamp attached to every
    result row, so downstream consumers (``autotune.fit_thresholds``, the
    BENCH trajectory) can key per-backend fits and join rows across runs
    without trusting payload-level context."""
    dev = jax.devices()[0]
    return {
        "backend": jax.default_backend(),
        "platform": getattr(dev, "device_kind", "unknown"),
        "jax_version": jax.__version__,
        "run_id": RUN_ID,
        "timestamp": time.time(),
    }


def emit(name: str, us: float, derived: dict | None = None):
    """Record one result row. ``derived`` holds the exact metric values; the
    CSV display string is rendered from it (not the other way around), so
    --json archives full precision."""
    derived = derived or {}
    text = ";".join(f"{k}={_fmt_val(v)}" for k, v in derived.items())
    row = f"{name},{us:.1f},{text}"
    ROWS.append(row)
    RESULTS.append({"name": name, "us_per_call": us, "derived": derived,
                    **_env_stamp()})
    print(row, flush=True)


def timeit(fn, *args, reps: int = 5):
    """Paper protocol: 1 excluded warmup + mean of ``reps``."""
    out = fn(*args)
    jax.block_until_ready(out)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.mean(ts)) * 1e6, out


def _fm(a, b) -> int:
    return int(flops_stats(a, b.row_nnz())[0])


def bench_methods():
    """GFLOPS/s (2*f_m flops, as the paper counts) per method per matrix."""
    results = {}
    for name, a, b in CASES:
        fm = _fm(a, b)
        res = spgemm(a, b)  # warm caches, get caps
        fm_cap = round_capacity(fm)
        nnz_cap = round_capacity(int(res.c.nnz()))
        per_method = {}
        us_sym, _ = timeit(lambda: symbolic(a, b)[0])
        us_num, _ = timeit(lambda: numeric_fresh(a, b, fm_cap, nnz_cap)[0])
        per_method["sparse"] = us_sym + us_num
        if b.k < 250_000 and a.m * b.k * 8 <= (1 << 30):
            from repro.core.spgemm import numeric_dense_acc
            us_dnum, _ = timeit(lambda: numeric_dense_acc(a, b, fm_cap, nnz_cap))
            per_method["dense"] = us_sym + us_dnum
        us_auto = per_method.get(res.stats["method"], per_method["sparse"])
        per_method["kkspgemm"] = us_auto
        results[name] = (fm, per_method)
        for meth, us in per_method.items():
            gflops = 2 * fm / (us * 1e-6) / 1e9
            emit(f"methods/{name}/{meth}", us, {"gflops": gflops, "fm": fm})
    return results


def bench_profile(results):
    """Fig 6 summary: per method, #wins and max slowdown vs per-problem best."""
    methods = ["sparse", "dense", "kkspgemm"]
    wins = {m: 0 for m in methods}
    max_slow = {m: 1.0 for m in methods}
    for name, (fm, per) in results.items():
        best = min(per.values())
        for m in methods:
            if m in per:
                if per[m] <= best * 1.005:
                    wins[m] += 1
                max_slow[m] = max(max_slow[m], per[m] / best)
    for m in methods:
        emit(f"profile/{m}", 0.0,
             {"wins": wins[m], "max_slowdown": max_slow[m]})


def bench_compression():
    """CF / CMRF + symbolic-phase time with vs without compression."""
    for name, a, b in CASES:
        bc = compress_matrix(b)
        cf, cmrf, use = compression_decision(a, b, bc)
        fm = _fm(a, b)
        cap_plain = round_capacity(fm)
        us_plain, _ = timeit(lambda: symbolic_plain(a, b, cap_plain))
        fm_c = int(jnp.sum(jnp.where(
            a.valid_mask(),
            bc.row_nnz()[jnp.minimum(a.indices, bc.indptr.shape[0] - 2)], 0)))
        cap_c = round_capacity(max(fm_c, 1))
        us_comp, _ = timeit(
            lambda: symbolic_compressed(a, bc, a.m, cap_c))
        emit(f"compression/{name}", us_comp,
             {"cf": cf, "cmrf": cmrf, "applied": int(use),
              "plain_us": us_plain, "speedup": us_plain / us_comp})


def bench_reuse():
    """Reuse (numeric only, cached plan) vs NoReuse (symbolic+numeric)."""
    for name, a, b in CASES:
        res = spgemm(a, b, method="sparse")
        fm = _fm(a, b)
        fm_cap = round_capacity(fm)
        nnz_cap = round_capacity(int(res.c.nnz()))
        us_sym, _ = timeit(lambda: symbolic(a, b)[0])
        us_fresh, _ = timeit(lambda: numeric_fresh(a, b, fm_cap, nnz_cap)[0])
        us_reuse, _ = timeit(
            lambda: numeric_reuse(res.plan, a.values, b.values))
        noreuse = us_sym + us_fresh
        emit(f"reuse/{name}", us_reuse,
             {"noreuse_us": noreuse, "speedup": noreuse / us_reuse})


def bench_reuse_batched(batches=(8, 32)):
    """Batched reuse replay (the executor's acceptance benchmark).

    Per case and batch size: stack ``batch`` value sets on one pinned plan
    and compare ONE ``ReuseExecutor.apply_batched`` dispatch against a
    per-call ``numeric_reuse`` loop. Reports both in multiplies/s — the
    north-star serving metric. A small dispatch-bound case rides along so
    the dispatch-amortization effect is visible even when the suite cases
    are compute-bound.
    """
    small = random_csr(256, 256, 4.0, 123)
    cases = [("rand256_AxA", small, small)] + list(CASES[:2])
    for name, a, b in cases:
        ex = ReuseExecutor.from_matrices(a, b, plan_cache=PlanCache())
        rng = np.random.default_rng(BENCH_SEED)
        for batch in batches:
            a_stack = jnp.asarray(
                rng.standard_normal((batch, a.nnz_cap)), jnp.float32)
            b_stack = jnp.asarray(
                rng.standard_normal((batch, b.nnz_cap)), jnp.float32)
            # pre-split so the loop pays dispatch, not slicing
            a_list = [jnp.asarray(a_stack[i]) for i in range(batch)]
            b_list = [jnp.asarray(b_stack[i]) for i in range(batch)]

            us_batched, _ = timeit(lambda: ex.apply_batched(a_stack, b_stack))
            us_loop, _ = timeit(
                lambda: [numeric_reuse(ex.plan, av, bv)
                         for av, bv in zip(a_list, b_list)])
            emit(f"reuse_batched/{name}/b{batch}", us_batched,
                 {"loop_us": us_loop,
                  "speedup": us_loop / us_batched,
                  "mult_per_s": batch / (us_batched * 1e-6),
                  "loop_mult_per_s": batch / (us_loop * 1e-6)})


def bench_compile():
    """Recompile counts + plan-cache hit rate through the public spgemm().

    Three calls tell the whole bucketing/caching story:
      1. fresh structure       -> traces every pipeline stage once (miss)
      2. same-bucket structure -> different graph, same capacity buckets:
                                  zero new traces (executables shared)
      3. repeated structure    -> new values only: plan-cache hit, zero
                                  traces, no expansion/sort at all
    """
    jax.clear_caches()  # measure traces from a clean slate
    reset_trace_counts()
    cache = PlanCache(capacity=8)
    mk = lambda seed: random_csr(256, 256, 5.0, seed)
    a1, b1 = mk(101), mk(102)
    a2, b2 = mk(103), mk(104)  # same shape/density -> same capacity buckets

    def one_call(a, b):
        """Single timed call — compile cost included, that's the point."""
        t0 = time.perf_counter()
        res = spgemm(a, b, method="sparse", plan_cache=cache)
        jax.block_until_ready(res.c.values)
        return (time.perf_counter() - t0) * 1e6, res

    us1, res1 = one_call(a1, b1)
    traces_first = sum(TRACE_COUNTS.values())

    us2, res2 = one_call(a2, b2)
    traces_same_bucket = sum(TRACE_COUNTS.values()) - traces_first

    rng = np.random.default_rng(BENCH_SEED)
    a1v = CSR(a1.indptr, a1.indices,
              jnp.asarray(rng.standard_normal(a1.nnz_cap), jnp.float32), a1.shape)
    us3, res3 = one_call(a1v, b1)
    traces_hit = sum(TRACE_COUNTS.values()) - traces_first - traces_same_bucket

    cs = cache.stats()
    emit("compile/fresh", us1,
         {"traces": traces_first,
          "expansions": TRACE_COUNTS["expand_and_sort"],
          "cache": res1.stats["cache"]})
    emit("compile/same_bucket", us2,
         {"new_traces": traces_same_bucket, "cache": res2.stats["cache"]})
    emit("compile/cache_hit", us3,
         {"new_traces": traces_hit, "cache": res3.stats["cache"]})
    emit("compile/cache", 0.0,
         {"hits": cs["hits"], "misses": cs["misses"],
          "hit_rate": cs["hit_rate"]})


def _accum_regimes(quick: bool) -> list[tuple]:
    """The avg-row-flop regimes straddling the KKLP cutoff — shared by
    bench_accumulators (the crossover artifact) and bench_autotune (the
    regret table), so the fit is evaluated on exactly the sweep it is
    fitted from."""
    regimes = [
        ("low_flops", random_csr(128, 128, 3.0, 41), random_csr(128, 128, 3.0, 42)),
        ("high_flops", random_csr(8, 32, 12.0, 45), random_csr(32, 96, 32.0, 46)),
    ]
    if not quick:
        regimes.insert(1, (
            "mid_flops", random_csr(64, 96, 8.0, 43), random_csr(96, 128, 8.0, 44)))
    return regimes


def _time_accum_arms(a, b, stats: dict, interpret: bool) -> dict[str, float]:
    """Time the three accumulator arms (full from-scratch numeric phase) on
    one problem: {"dense_acc": us, "segsum": us, "lp_hash": us}."""
    from repro.core import numeric_fresh, numeric_lp
    from repro.core.spgemm import numeric_dense_acc

    fm_cap, nnz_cap = stats["fm_cap"], stats["nnz_cap"]
    per: dict[str, float] = {}
    per["dense_acc"], _ = timeit(
        lambda: numeric_dense_acc(a, b, fm_cap, nnz_cap))
    per["segsum"], _ = timeit(
        lambda: numeric_fresh(a, b, fm_cap, nnz_cap)[0])
    per["lp_hash"], _ = timeit(
        lambda: numeric_lp(a, b, fm_cap, nnz_cap, interpret=interpret)[0])
    return per


def bench_accumulators(quick: bool = False):
    """Accumulator crossover (the paper's central performance claim): time
    the FULL numeric phase (structure + values, from-scratch) through each
    accumulator data structure across avg-row-flop regimes straddling the
    KKLP cutoff (256) — all three arms pay their structure-extraction work,
    so the comparison is apples-to-apples:

      dense_acc — XLA dense (m, k) scatter accumulator + nonzero-scan CSR
                  extraction (``numeric_dense_acc``, the KKDENSE position)
      segsum    — single-expansion pipeline + sorted-segment accumulation
                  (``numeric_fresh``, the Thread-Flat-Parallel position)
      lp_hash   — same pipeline, values through the Pallas LP-hash
                  accumulator (``numeric_lp``, the KKLP position)

    Each row records avg_row_flops, ``choose_kernel``'s pick and that arm's
    own backend (dense_acc/segsum are compiled XLA everywhere; lp_hash is
    Pallas on TPU, interpret mode elsewhere); the ``crossover`` row per
    regime names the measured winner so the BENCH_accum_*.json trajectory
    shows where the crossover sits. Off-TPU the LP arm pays interpret
    overhead, so the winner comparison is not hardware-meaningful there —
    the crossover row carries ``comparable=0`` in that case and readers of
    the artifact should track the dense/segsum columns plus the
    choose_kernel pick until real-TPU CI exists.
    """
    from repro.core import choose_kernel

    interpret = jax.default_backend() != "tpu"
    arm_backend = {"dense_acc": "xla", "segsum": "xla",
                   "lp_hash": "interpret" if interpret else "pallas"}
    for name, a, b in _accum_regimes(quick):
        res = spgemm(a, b, method="sparse", plan_cache=PlanCache())
        fm = res.stats["fm"]
        avg_row_flops = fm / max(a.m, 1)
        chosen = choose_kernel(a, b, {"fm": fm})
        per = _time_accum_arms(a, b, res.stats, interpret)
        for acc, us in per.items():
            emit(f"accumulators/{name}/{acc}", us,
                 {"avg_row_flops": avg_row_flops, "fm": fm,
                  "chosen": chosen, "backend": arm_backend[acc],
                  "gflops": 2 * fm / (us * 1e-6) / 1e9})
        winner = min(per, key=per.get)
        emit(f"accumulators/{name}/crossover", 0.0,
             {"avg_row_flops": avg_row_flops, "chosen": chosen,
              "winner": winner, "comparable": int(not interpret),
              "lp_over_segsum": per["lp_hash"] / per["segsum"],
              "dense_over_segsum": per["dense_acc"] / per["segsum"]})


def bench_autotune(quick: bool = False):
    """Autotuner acceptance: regret of each selection mode vs the static rule.

    Reruns the accumulator sweep, then asks each mode which arm it would
    pick per regime and charges it that arm's measured time:

      static   — the paper rule at AVG_ROW_FLOPS_CUTOFF (the baseline;
                 regret 0 by definition)
      fitted   — thresholds fitted (in-run) from this very sweep via
                 ``fit_thresholds``; by construction its TOTAL time over the
                 sweep is <= static's (the fit minimizes exactly that), so
                 ``autotune/regret_total`` must be <= 0 up to timing noise
      measured — the per-regime argmin, what ``tune="measure"`` converges
                 to; pointwise regret <= 0 by definition

    A live ``spgemm(tune="measure")`` demo rides along: first sight pays one
    micro-bench (TUNE_COUNTS delta proves it), the pinned-plan replay
    re-dispatches the cached winner with zero re-tuning (plan_meta_hit, no
    new micro_bench).
    """
    from repro.core import (
        AVG_ROW_FLOPS_CUTOFF,
        fit_thresholds,
        set_tuned_thresholds,
    )
    from repro.core.autotune import ARM_OF_PICK, TUNE_COUNTS

    interpret = jax.default_backend() != "tpu"
    stamp = _env_stamp()
    sweep = []  # (regime, avg_row_flops, per-arm times)
    for name, a, b in _accum_regimes(quick):
        res = spgemm(a, b, method="sparse", plan_cache=PlanCache())
        fm = res.stats["fm"]
        per = _time_accum_arms(a, b, res.stats, interpret)
        sweep.append((name, fm / max(a.m, 1), per))

    # feed the fitter the same row shape bench_accumulators archives
    fit_rows = [
        {"name": f"accumulators/{name}/{arm}", "us_per_call": us,
         "backend": stamp["backend"], "platform": stamp["platform"],
         "derived": {"avg_row_flops": arf}}
        for name, arf, per in sweep for arm, us in per.items()
    ]
    table = fit_thresholds({"rows": fit_rows, **stamp})
    fit = table.for_backend()
    cutoff = fit.avg_row_flops_cutoff if fit else None
    emit("autotune/fit", 0.0,
         {"fitted_cutoff": -1.0 if cutoff is None else float(cutoff),
          "static_cutoff": float(AVG_ROW_FLOPS_CUTOFF),
          "n_points": fit.n_points if fit else 0})

    totals = {"static": 0.0, "fitted": 0.0, "measured": 0.0}
    for name, arf, per in sweep:
        choosable = {k: per[v] for k, v in ARM_OF_PICK.items()}
        static_pick = ("dense_acc" if arf < AVG_ROW_FLOPS_CUTOFF
                       else "flat_lp")
        fitted_pick = (static_pick if cutoff is None
                       else "dense_acc" if arf < cutoff else "flat_lp")
        t_static = choosable[static_pick]
        t_fitted = choosable[fitted_pick]
        t_measured = min(choosable.values())
        totals["static"] += t_static
        totals["fitted"] += t_fitted
        totals["measured"] += t_measured
        emit(f"autotune/{name}/regret", 0.0,
             {"avg_row_flops": arf, "static_pick": static_pick,
              "fitted_pick": fitted_pick,
              "measured_pick": min(choosable, key=choosable.get),
              "static_us": t_static,
              "regret_fitted_us": t_fitted - t_static,
              "regret_measured_us": t_measured - t_static})
    emit("autotune/regret_total", 0.0,
         {"static_us": totals["static"],
          "regret_fitted_us": totals["fitted"] - totals["static"],
          "regret_measured_us": totals["measured"] - totals["static"]})

    # live measure-mode demo on a pinned plan cache
    cache = PlanCache()
    a = random_csr(96, 96, 4.0, 47)
    b = random_csr(96, 96, 4.0, 48)
    mb0, pm0 = TUNE_COUNTS["micro_bench"], TUNE_COUNTS["plan_meta_hit"]
    us_first, _ = timeit(
        lambda: spgemm(a, b, method="sparse", plan_cache=cache,
                       tune="measure").c.values, reps=1)
    mb_first = TUNE_COUNTS["micro_bench"] - mb0
    us_replay, _ = timeit(
        lambda: spgemm(a, b, method="sparse", plan_cache=cache,
                       tune="measure").c.values)
    emit("autotune/measure_demo", us_replay,
         {"first_call_us": us_first,
          "micro_bench_first": mb_first,
          "micro_bench_new_on_replay":
              TUNE_COUNTS["micro_bench"] - mb0 - mb_first,
          "plan_meta_hits": TUNE_COUNTS["plan_meta_hit"] - pm0})


def bench_fm_groups(results):
    """Fig 8: geometric-mean speedup of kkspgemm vs single fixed method,
    grouped by f_m size."""
    rows = sorted(results.items(), key=lambda kv: kv[1][0])
    half = max(len(rows) // 2, 1)
    for label, grp in (("small_fm", rows[:half]), ("large_fm", rows[half:])):
        sp = []
        for name, (fm, per) in grp:
            base = per["sparse"]
            sp.append(base / per["kkspgemm"])
        gm = float(np.exp(np.mean(np.log(np.maximum(sp, 1e-9)))))
        emit(f"fm_groups/{label}", 0.0,
             {"geomean_speedup_vs_sparse": gm, "n": len(grp)})


def bench_distributed():
    """1-D row-wise distributed SpGEMM phase costs (single real device:
    reports the sharded-path overhead vs local)."""
    from repro.compat import make_mesh
    from repro.core import distributed_spgemm

    mesh = make_mesh((1,), ("data",))
    for name, a, b in CASES[:3]:
        us_local, _ = timeit(lambda: spgemm(a, b).c.values)
        us_dist, _ = timeit(
            lambda: distributed_spgemm(a, b, mesh).values)
        emit(f"distributed/{name}", us_dist,
             {"local_us": us_local, "overhead": us_dist / us_local})


def bench_dist(n_windows=5, window=16):
    """repro.dist acceptance benchmark: replay latency flat vs replay count.

    Pins one ShardedReuseExecutor on the full host mesh and runs ONE stream
    of ``n_windows * window`` blocked replays, each individually timed,
    split into DISJOINT equal-sized windows. Row ``r{n}`` reports the
    median latency of the window starting at stream position n — a genuine
    "does the Nth replay cost more than the 1st" measurement (overlapping
    windows would mostly compare samples with themselves), so flatness
    across windows rules out accumulating per-replay host work (cache
    growth, re-partitioning, leak-driven drift). The deterministic half of
    the proof rides in the same rows: retraces and structure hashes counted
    over the whole stream (both must be 0 — constant per-replay overhead
    would not show up as slope, the counters catch it instead). Medians,
    not means: shared CI runners throttle in multi-second windows and the
    spikes land in the tail. The mesh shape rides in every row so the
    --json artifact records the decomposition the numbers were taken on.
    """
    from repro.core import HASH_COUNTS, PlanCache
    from repro.core.spgemm import TRACE_COUNTS
    from repro.dist import ShardedReuseExecutor
    from repro.launch.mesh import make_data_mesh

    mesh = make_data_mesh()
    mesh_shape = "x".join(str(s) for s in mesh.devices.shape)
    a = random_csr(512, 512, 4.0, 7)
    b = random_csr(512, 512, 4.0, 8)
    for placement in ("replicated", "allgather"):
        ex = ShardedReuseExecutor.from_matrices(
            a, b, mesh, b_placement=placement, plan_cache=PlanCache())
        rng = np.random.default_rng(BENCH_SEED)
        av = jnp.asarray(rng.standard_normal(a.nnz_cap), jnp.float32)
        bv = jnp.asarray(rng.standard_normal(b.nnz_cap), jnp.float32)
        for _ in range(3):  # warm the dispatch path
            jax.block_until_ready(ex.apply(av, bv))
        traces0 = sum(TRACE_COUNTS.values())
        hashes0 = sum(HASH_COUNTS.values())
        ts = []
        for _ in range(n_windows * window):
            t0 = time.perf_counter()
            jax.block_until_ready(ex.apply(av, bv))
            ts.append(time.perf_counter() - t0)
        retraces = sum(TRACE_COUNTS.values()) - traces0
        hashes = sum(HASH_COUNTS.values()) - hashes0
        per_window = {}
        for w in range(n_windows):
            n = w * window + 1  # 1-based stream position of window start
            seg = ts[w * window: (w + 1) * window]
            med_us = float(np.median(seg)) * 1e6
            per_window[n] = med_us
            emit(f"dist/{placement}/r{n}", med_us,
                 {"us_per_replay": med_us, "replay_index": n,
                  "window": window,
                  "window_total_us": float(np.sum(seg)) * 1e6,
                  "retraces": retraces, "hashes": hashes,
                  "mesh_shape": mesh_shape, "b_placement": placement})
        flatness = max(per_window.values()) / min(per_window.values())
        emit(f"dist/{placement}/flatness", 0.0,
             {"max_over_min": flatness, "retraces": retraces,
              "hashes": hashes, "mesh_shape": mesh_shape})


def bench_guard(quick: bool = False):
    """Guarded-mode overhead (the failure model's acceptance artifact).

    One pinned ``ReuseExecutor`` per validation mode on the same problem:

      guard/validate_off    — the baseline replay (no guard object at all)
      guard/validate_host   — O(1) host-side PlanGuard checks per replay
      guard/validate_device — + one jitted bitmask reduction per operand
                              (a scalar device sync per replay)
      guard/nan_guard       — + the post-replay finiteness check on clean
                              output (the guard's happy path)
      guard/watchdog        — deadline-wrapped replay: the dispatch blocks
                              via block_until_ready inside the step timer,
                              so the row prices losing async dispatch too

    Every row carries ``overhead`` = us / validate-off us, so the
    BENCH_guard_*.json trajectory answers "what does hardening cost this
    PR". A ``guard/retry`` row rides along: retry_call around a closure
    that fails twice then succeeds, with the deterministic backoff summed
    (sleep stubbed out — the row prices the machinery, not the waiting).
    """
    from repro.runtime import StepWatchdog
    from repro.runtime.retry import backoff_schedule, retry_call

    a = random_csr(256, 256, 4.0, 51)
    b = random_csr(256, 256, 4.0, 52)

    def replay_us(**kw):
        ex = ReuseExecutor.from_matrices(a, b, plan_cache=PlanCache(), **kw)
        us, _ = timeit(lambda: ex.apply(a.values, b.values))
        return us

    base = replay_us()
    emit("guard/validate_off", base, {"overhead": 1.0})
    for mode in ("host", "device"):
        us = replay_us(validate=mode)
        emit(f"guard/validate_{mode}", us, {"overhead": us / base})
    us_nan = replay_us(nan_guard=True)
    emit("guard/nan_guard", us_nan, {"overhead": us_nan / base})
    wd = StepWatchdog(deadline_s=60.0, policy="warn")
    us_wd = replay_us(watchdog=wd)
    emit("guard/watchdog", us_wd,
         {"overhead": us_wd / base, "slow_steps": len(wd.slow_steps)})

    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] % 3:  # fails twice, succeeds on the 3rd, every cycle
            raise RuntimeError("transient")
        return calls["n"]

    us_retry, _ = timeit(
        lambda: retry_call(flaky, retries=3, sleep=lambda d: None,
                           seed=BENCH_SEED))
    sched = backoff_schedule(3, seed=BENCH_SEED)
    emit("guard/retry", us_retry,
         {"attempts_per_success": 3,
          "backoff_total_s": float(sum(sched))})


def bench_serve(quick: bool = False):
    """Serving-tier acceptance (BENCH_serve_*.json): one synthetic trace
    through ``SparseService``, in phases:

      serve/warm     — traffic-log plan prefetch before traffic (built/hits)
      serve/steady   — sustained mixed-structure load: requests round-robin
                       over N structures, stepped as they queue; reports
                       sustained QPS and p50/p99 request latency (admission
                       -> completion, batching wait included)
      serve/overload — a deliberate burst past max_queue plus infeasible
                       deadlines: the shed-rate row (every shed typed, none
                       silent — the counters are the evidence)
      serve/chaos    — kernel:pallas armed mid-stream over singleton
                       traffic: ladder fallbacks until the breaker opens,
                       then short-circuits straight to XLA (the row carries
                       both counts — short_circuits are the requests that
                       SKIPPED paying the fault)
      serve/recovery — fault cleared, cooldown elapsed: the half-open probe
                       re-admits the fast path; breaker_closed=1 is the
                       acceptance bit
    """
    from repro.core import telemetry
    from repro.runtime import faults
    from repro.serve import SparseService

    n_structs = 2 if quick else 4
    n_steady = 32 if quick else 128
    n_chaos = 8 if quick else 16
    structures = [
        (random_csr(64 + 32 * i, 64, 3.0, 61 + i),
         random_csr(64, 48, 3.0, 81 + i))
        for i in range(n_structs)
    ]
    svc = SparseService(backend="pallas", max_batch=8, max_queue=64,
                        breaker_threshold=3, breaker_cooldown_s=0.05,
                        retries=1, sleep=lambda _: None)

    # -- warm: record one request per structure, then prefetch the plans
    for a, b in structures:
        svc.submit(a, b)
    svc.drain()
    svc.plan_cache.clear()  # force the warm to do real work
    ws = svc.warm()
    emit("serve/warm", 0.0, {"structures": len(structures), **ws})

    # -- steady traffic: round-robin structures, step whenever a batch fills
    t0 = time.perf_counter()
    for i in range(n_steady):
        a, b = structures[i % n_structs]
        svc.submit(a, b, deadline_s=60.0)
        if svc.queue_depth >= svc.max_batch:
            svc.step()
    svc.drain()
    steady_s = time.perf_counter() - t0
    pct = svc.latency_percentiles()
    completed = svc.counters["completed"]
    emit("serve/steady", steady_s * 1e6 / max(n_steady, 1),
         {"qps": n_steady / steady_s, "completed": completed,
          "p50_ms": pct["p50"] * 1e3, "p99_ms": pct["p99"] * 1e3,
          "group_dispatches": svc.counters["group_dispatches"]})

    # -- overload: a burst past the queue bound + infeasible deadlines
    a, b = structures[0]
    for _ in range(8):
        svc.submit(a, b, deadline_s=1e-9)  # infeasible vs the measured EWMA
    for i in range(svc.max_queue + 16):
        svc.submit(a, b)
    svc.drain()
    st = svc.stats()
    emit("serve/overload", 0.0,
         {"shed_rate": st["shed_rate"],
          "shed_queue_full": st["shed_queue_full"],
          "shed_deadline_infeasible": st["shed_deadline_infeasible"],
          "shed_deadline_expired": st["shed_deadline_expired"],
          "failed": st["failed"]})

    # -- chaos: fast kernel faults mid-stream on singleton traffic
    fb0 = telemetry.FALLBACK_COUNTS["fault:pallas->xla"]
    deg0 = svc.counters["degraded_dispatches"]
    with faults.failpoint("kernel:pallas"):
        for i in range(n_chaos):
            svc.submit(*structures[i % n_structs])
            svc.step()  # singleton steps: the breaker-governed path
    br = svc.stats()["breakers"]["pallas"]
    emit("serve/chaos", 0.0,
         {"requests": n_chaos,
          "degraded": svc.counters["degraded_dispatches"] - deg0,
          "fallbacks": telemetry.FALLBACK_COUNTS["fault:pallas->xla"] - fb0,
          "breaker_opens": telemetry.BREAKER_COUNTS["pallas:open"],
          "short_circuits": telemetry.BREAKER_COUNTS["pallas:short_circuit"],
          "breaker_open": int(br["state"] != "closed")})

    # -- recovery: cooldown elapses, the half-open probe closes the breaker
    time.sleep(0.06)
    for i in range(4):
        svc.submit(*structures[i % n_structs])
        svc.step()
    br = svc.stats()["breakers"]["pallas"]
    emit("serve/recovery", 0.0,
         {"breaker_closed": int(br["state"] == "closed"),
          "closes": telemetry.BREAKER_COUNTS["pallas:close"],
          "reopens": telemetry.BREAKER_COUNTS["pallas:reopen"],
          "completed_total": svc.counters["completed"]})


def bench_obs(quick: bool = False):
    """Observability overhead gate (BENCH_obs_*.json).

    The PR-9 contract is "tracing off costs nothing measurable on the pinned
    replay hot path". Rows:

      obs/span_off      — unit cost of one *disabled* span() call (amortized
                          over 10k calls): the only thing tracing-off adds
                          per span site
      obs/replay_off    — the pinned replay with tracing off. Its
                          ``off_overhead`` derived metric is the CI gate:
                          span-site count on the replay path x the measured
                          disabled-span unit cost, as a fraction of the
                          replay latency (must stay <= 0.02). The row also
                          carries ``dispatch_identical`` — a telemetry diff
                          over the timed loop proving zero added traces and
                          zero added hashes
      obs/replay_traced — the same replay with tracing ON (informational:
                          what turning the layer on costs)
      obs/sample_trace  — a traced mini chaos run through ``SparseService``
                          (kernel:pallas armed, then recovery) exported as
                          Chrome trace-event JSON to trace_obs_sample.json;
                          the row counts exported spans and flight-recorder
                          events (both must be nonzero — the artifact CI
                          uploads next to the BENCH json)
    """
    from repro import obs
    from repro.core import telemetry
    from repro.runtime import faults
    from repro.serve import SparseService

    obs.set_tracing("off")
    a = random_csr(256, 256, 4.0, 71)
    b = random_csr(256, 256, 4.0, 72)
    ex = ReuseExecutor.from_matrices(a, b, plan_cache=PlanCache())
    jax.block_until_ready(ex.apply(a.values, b.values))  # warm the dispatch

    # dispatch identity: the timed tracing-off loop must bump zero trace and
    # zero hash counters (the telemetry-asserted half of the contract)
    before = telemetry.snapshot()
    us_off, _ = timeit(lambda: ex.apply(a.values, b.values))
    delta = telemetry.diff(before, telemetry.snapshot())
    identical = int("trace" not in delta and "hash" not in delta)

    n = 10_000
    t0 = time.perf_counter()
    for _ in range(n):
        with obs.span("bench.noop"):
            pass
    span_off_us = (time.perf_counter() - t0) * 1e6 / n
    # the replay path crosses one enabled() check in the executor; price two
    # full disabled span() calls per replay to stay conservative
    spans_per_replay = 2
    off_overhead = spans_per_replay * span_off_us / us_off
    emit("obs/span_off", span_off_us, {"calls": n})
    emit("obs/replay_off", us_off,
         {"off_overhead": off_overhead, "dispatch_identical": identical,
          "spans_per_replay": spans_per_replay})

    obs.set_tracing("on")
    obs.clear()
    us_on, _ = timeit(lambda: ex.apply(a.values, b.values))
    emit("obs/replay_traced", us_on, {"traced_ratio": us_on / us_off})

    # sample artifact: a traced chaos mini-run through the serving tier
    obs.reset_obs()
    obs.set_tracing("on")
    sa = random_csr(48, 48, 3.0, 73)
    sb = random_csr(48, 32, 3.0, 74)
    svc = SparseService(backend="pallas", max_batch=2, breaker_threshold=3,
                        retries=1, sleep=lambda _: None)
    with faults.failpoint("kernel:pallas"):
        svc.submit(sa, sb)
        svc.step()  # faulting fast path: ladder fallback, recorder event
    for _ in range(3):
        svc.submit(sa, sb)
        svc.step()
    path = "trace_obs_sample.json"
    payload = obs.export_chrome_trace(path)
    rec_events = len(obs.default_recorder().events())
    emit("obs/sample_trace", 0.0,
         {"trace_events": len(payload["traceEvents"]),
          "recorder_events": rec_events,
          "fallbacks": telemetry.FALLBACK_COUNTS["fault:pallas->xla"]})
    obs.set_tracing(None)  # back to the $REPRO_TRACE default
    obs.reset_obs()


def bench_train_smoke():
    """End-to-end LM substrate: smoke-model training step throughput."""
    from repro.configs import get_config
    from repro.data import SyntheticLMDataset
    from repro.models import NO_SHARDING, init_params
    from repro.train import AdamWConfig, adamw_init, make_train_step

    for arch in ("llama3.2-1b", "qwen3-moe-30b-a3b", "mamba2-2.7b"):
        cfg = get_config(arch, smoke=True)
        params = init_params(cfg, jax.random.PRNGKey(0))
        opt = adamw_init(params)
        data = SyntheticLMDataset(vocab_size=cfg.vocab_size, seq_len=64,
                                  global_batch=4)
        step = jax.jit(make_train_step(cfg, NO_SHARDING, AdamWConfig()))
        batch = {k: jnp.asarray(v) for k, v in data.get_batch(0).items()}

        def run(p, o):
            p2, o2, m = step(p, o, batch)
            return m["loss"]

        us, _ = timeit(lambda: run(params, opt))
        toks = 4 * 64
        emit(f"train_smoke/{arch}", us,
             {"tokens_per_s": toks / (us * 1e-6)})


# Self-contained benches addressable via --bench (no cross-bench inputs).
# Each callable takes the --quick flag (most ignore it; bench_accumulators
# shrinks its regime list).
BENCHES = {
    "compile": lambda quick: bench_compile(),
    "reuse": lambda quick: bench_reuse(),
    "reuse_batched": lambda quick: bench_reuse_batched(),
    "accumulators": bench_accumulators,
    "autotune": bench_autotune,
    "dist": lambda quick: bench_dist(),
    "guard": bench_guard,
    "serve": bench_serve,
    "obs": bench_obs,
    "distributed": lambda quick: bench_distributed(),
    "train_smoke": lambda quick: bench_train_smoke(),
}


def main(argv: list[str] | None = None) -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "--quick", action="store_true",
        help="CI smoke subset: 2 suite cases; compile, reuse and "
             "batched-reuse benches only",
    )
    parser.add_argument(
        "--bench", action="append", metavar="NAME", default=None,
        choices=sorted(BENCHES),
        help="run only the named self-contained bench(es); repeatable. "
             "Combines with --quick (e.g. the CI accumulator artifact runs "
             "--quick --bench accumulators)",
    )
    parser.add_argument(
        "--json", metavar="PATH", default=None,
        help="also write results as machine-readable JSON to PATH",
    )
    parser.add_argument(
        "--fit-thresholds", metavar="BENCH_JSON", default=None,
        help="subcommand: fit per-backend autotuner thresholds from a "
             "previously archived benchmark payload (needs accumulators/* "
             "rows), write the TunedThresholds table to --json, and exit "
             "without running any benches",
    )
    parser.add_argument(
        "--devices", type=int, default=0, metavar="N",
        help="force an N-device host platform (CPU shard_map benches); "
             "0 keeps the platform's real device count",
    )
    args = parser.parse_args(argv)
    if args.fit_thresholds:
        from repro.core import fit_thresholds

        if not args.json:
            parser.error("--fit-thresholds requires --json OUT (the path "
                         "the fitted TunedThresholds table is written to)")
        with open(args.fit_thresholds) as f:
            payload = json.load(f)
        table = fit_thresholds(payload, source=args.fit_thresholds)
        table.save(args.json)
        for bkey, fit in sorted(table.fits.items()):
            print(f"fit,{bkey},avg_row_flops_cutoff="
                  f"{fit.avg_row_flops_cutoff:.6g},n_points={fit.n_points}")
        if not table.fits:
            print("# no accumulators/* rows with dense_acc+lp_hash arms in "
                  f"{args.fit_thresholds}; wrote an empty table")
        print(f"# wrote {args.json} ({len(table.fits)} backend fits)")
        return
    if args.devices > 1:
        # must land before jax touches its backend (lazy: nothing above
        # builds arrays) — same mechanism the distributed tests use
        flags = os.environ.get("XLA_FLAGS", "")
        if "host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count="
                f"{args.devices}").strip()
    CASES[:] = list(suite())[:2] if args.quick else list(suite())
    print("name,us_per_call,derived")
    if args.bench:
        for name in args.bench:
            BENCHES[name](args.quick)
    elif args.quick:
        bench_compile()
        bench_reuse()
        bench_reuse_batched()
        bench_dist()
    else:
        results = bench_methods()
        bench_profile(results)
        bench_compression()
        bench_reuse()
        bench_reuse_batched()
        bench_compile()
        bench_accumulators()
        bench_fm_groups(results)
        bench_distributed()
        bench_dist()
        bench_guard()
        bench_serve()
        bench_train_smoke()
    print(f"# {len(ROWS)} rows")
    if args.json:
        stamp = _env_stamp()
        payload = {
            "schema": 1,
            "quick": bool(args.quick),
            "jax_version": stamp["jax_version"],
            "backend": stamp["backend"],
            "platform": stamp["platform"],
            "device_count": jax.device_count(),
            "rows": RESULTS,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        print(f"# wrote {args.json} ({len(RESULTS)} rows)")


if __name__ == "__main__":
    main()
