"""Jittable building blocks shared by the SpGEMM phases.

The segmented scan is the TPU-native replacement for the paper's per-thread
sequential accumulation loops: after sorting products by (row, key), each
accumulator "group" is a contiguous segment, and an associative segmented scan
performs the OR/ADD accumulation across all groups at once on the VPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def segmented_scan(values: jax.Array, seg_heads: jax.Array, op) -> jax.Array:
    """Inclusive segmented scan: restart the scan at every ``seg_heads`` True.

    The last element of each segment holds the segment's full reduction.
    ``op`` must be associative. O(n log n) work, fully vectorized.
    """
    flags = seg_heads.astype(jnp.bool_)

    def combine(a, b):
        fa, va = a
        fb, vb = b
        return fa | fb, jnp.where(fb, vb, op(va, vb))

    _, out = jax.lax.associative_scan(combine, (flags, values))
    return out


def segment_ends(seg_heads: jax.Array) -> jax.Array:
    """True at the last element of each segment."""
    return jnp.concatenate(
        [seg_heads[1:], jnp.ones((1,), seg_heads.dtype)]
    ).astype(jnp.bool_)


def popcount(x: jax.Array) -> jax.Array:
    return jax.lax.population_count(x)


def exclusive_cumsum(x: jax.Array) -> jax.Array:
    return jnp.concatenate([jnp.zeros((1,), x.dtype), jnp.cumsum(x)[:-1]])


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return ceil_div(a, b) * b
