"""Assigned-architecture configs: exact shapes from the assignment table."""
import pytest

from repro.configs import ARCH_IDS, SHAPES, all_cells, get_config, skip_reason

EXPECT = {
    # arch: (L, d_model, H, kv, d_ff, vocab)
    "llama3.2-1b": (16, 2048, 32, 8, 8192, 128256),
    "qwen2-7b": (28, 3584, 28, 4, 18944, 152064),
    "codeqwen1.5-7b": (32, 4096, 32, 32, 13440, 92416),
    "gemma2-9b": (42, 3584, 16, 8, 14336, 256000),
    "mamba2-2.7b": (64, 2560, 1, 1, 0, 50280),
    "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
    "phi-3-vision-4.2b": (32, 3072, 32, 32, 8192, 32064),
    "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
    "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 0, 151936),
    "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 0, 151936),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_exact_config(arch):
    cfg = get_config(arch)
    l, d, h, kv, ff, v = EXPECT[arch]
    assert cfg.num_layers == l
    assert cfg.d_model == d
    assert cfg.num_heads == h
    assert cfg.num_kv_heads == kv
    assert cfg.d_ff == ff
    assert cfg.vocab_size == v


def test_moe_configs():
    for arch, ff in [("qwen3-moe-235b-a22b", 1536), ("qwen3-moe-30b-a3b", 768)]:
        cfg = get_config(arch)
        assert cfg.num_experts == 128
        assert cfg.experts_per_token == 8
        assert cfg.moe_d_ff == ff


def test_mamba2_ssm_dims():
    cfg = get_config("mamba2-2.7b")
    assert cfg.ssm_state == 128
    assert cfg.ssm_expand * cfg.d_model // cfg.ssm_head_dim == 80  # heads


def test_gemma2_features():
    cfg = get_config("gemma2-9b")
    assert cfg.pattern == ("local", "global")
    assert cfg.window == 4096
    assert cfg.attn_softcap == 50.0 and cfg.final_softcap == 30.0


def test_recurrentgemma_pattern():
    cfg = get_config("recurrentgemma-9b")
    kinds = list(cfg.pattern) * cfg.pattern_repeats + list(cfg.tail)
    assert len(kinds) == 38
    assert kinds.count("rec") == 26 and kinds.count("local") == 12


def test_shapes_table():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].seq_len == 32768
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["long_500k"].global_batch == 1


def test_cell_skips():
    cells = list(all_cells())
    assert len(cells) == 32  # 40 - 8 documented skips
    # encoder-only: no decode cells
    assert skip_reason("hubert-xlarge", "decode_32k")
    assert skip_reason("hubert-xlarge", "long_500k")
    # pure full-attention archs skip long_500k
    for arch in ("llama3.2-1b", "qwen2-7b", "codeqwen1.5-7b",
                 "phi-3-vision-4.2b", "qwen3-moe-235b-a22b",
                 "qwen3-moe-30b-a3b"):
        assert skip_reason(arch, "long_500k")
    # SSM / hybrid / hybrid-window archs RUN long_500k
    for arch in ("mamba2-2.7b", "recurrentgemma-9b", "gemma2-9b"):
        assert skip_reason(arch, "long_500k") is None


def test_param_count_sanity():
    """Named sizes within tolerance of the computed parameter counts."""
    expected_b = {
        "llama3.2-1b": 1.24, "qwen2-7b": 7.6, "codeqwen1.5-7b": 8.2,
        "gemma2-9b": 9.2, "mamba2-2.7b": 2.7, "recurrentgemma-9b": 8.8,
        "phi-3-vision-4.2b": 3.8, "hubert-xlarge": 0.95,
        "qwen3-moe-235b-a22b": 235.1, "qwen3-moe-30b-a3b": 30.5,
    }
    for arch, want in expected_b.items():
        got = get_config(arch).param_count() / 1e9
        assert abs(got - want) / want < 0.05, (arch, got, want)
