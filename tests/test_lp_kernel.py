"""KKLP Pallas kernel tests (interpret mode) + meta-dispatch routing.

Acceptance contracts of the LP-hash accumulator kernel:
  * spgemm_lp output is BITWISE the core/accumulators.py oracle
    (accumulate_row(kind="lp") -> merged L1+L2 extraction), on randomized
    CSR inputs, including L1 sizes small enough that rows spill to L2
  * lp_reuse (plan replay through the LP accumulator) matches numeric_reuse
  * kernels.ops.numeric_values routes flat_lp-regime inputs to the LP
    kernel — NOT the dense-accumulator kernel — and f64/int dtypes to XLA
  * spgemm(method="lp") and ReuseExecutor(backend="pallas_lp") are wired
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    PlanCache,
    ReuseExecutor,
    numeric_lp,
    numeric_reuse,
    spgemm,
)
from repro.core.accumulators import accumulate_row
from repro.kernels import (
    lp_reuse,
    lp_reuse_arrays,
    ref,
    spgemm_lp,
    spgemm_lp_bucketed,
)
from repro.kernels.ops import (
    KERNEL_COUNTS,
    numeric_values,
    reset_kernel_counts,
    resolve_numeric_kernel,
)
from repro.sparse import (
    CSR,
    dense_spgemm_oracle,
    gustavson_ell_structure,
    gustavson_numpy,
    random_csr,
)
from repro.sparse.formats import csr_to_ell


def _structure(a: CSR, b: CSR):
    """Symbolic structure of C = A*B in ELL layout (numpy Gustavson)."""
    c_idx, c_nnz = gustavson_ell_structure(a, b)
    return jnp.asarray(c_idx), jnp.asarray(c_nnz)


def _row_spills(a: CSR, b: CSR, l1_size: int) -> bool:
    """True if any row's insert stream spills L1 at the 50% cutoff."""
    a_n, b_n = np.asarray(a.indptr), np.asarray(b.indptr)
    ai, bi = np.asarray(a.indices), np.asarray(b.indices)
    for i in range(a.m):
        keys = []
        for s in range(a_n[i], a_n[i + 1]):
            j = ai[s]
            keys.extend(bi[b_n[j]: b_n[j + 1]].tolist())
        if len(set(keys)) > l1_size // 2:
            return True
    return False


@pytest.mark.parametrize("m,n,k,da,db,seed", [
    (12, 16, 20, 3.0, 2.5, 1),
    (24, 20, 16, 2.0, 3.0, 2),
    (8, 32, 48, 4.0, 4.0, 3),
])
@pytest.mark.parametrize("l1_size", [4, 16, None])
def test_spgemm_lp_bitwise_vs_accumulator_oracle(m, n, k, da, db, seed, l1_size):
    """The kernel replays the exact insert stream of the jittable LP port:
    output must be bitwise-equal, spill or no spill (l1_size=4 -> cutoff 2,
    heavy spill; None -> the never-spilling default)."""
    a = random_csr(m, n, da, seed)
    b = random_csr(n, k, db, seed + 100)
    ea, eb = csr_to_ell(a), csr_to_ell(b)
    c_idx, c_nnz = _structure(a, b)
    if l1_size == 4:  # construction precondition: the spill path must run
        assert _row_spills(a, b, l1_size)
    got = spgemm_lp(ea.indices, ea.values, ea.row_nnz, eb.indices, eb.values,
                    eb.row_nnz, c_idx, c_nnz, l1_size=l1_size, interpret=True)
    from repro.kernels.spgemm_lp import default_l1_size

    eff_l1 = default_l1_size(c_idx.shape[1]) if l1_size is None else l1_size
    want = ref.spgemm_lp_ref(ea.indices, ea.values, ea.row_nnz, eb.indices,
                             eb.values, eb.row_nnz, c_idx, c_nnz, eff_l1)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_spgemm_lp_matches_gustavson():
    """Independent of the accumulator oracle: values match the numpy
    Gustavson sweep at the symbolic structure."""
    a = random_csr(16, 20, 3.0, 11)
    b = random_csr(20, 24, 2.5, 12)
    ea, eb = csr_to_ell(a), csr_to_ell(b)
    ip, ind, val, _ = gustavson_numpy(a, b)
    c_idx, c_nnz = _structure(a, b)
    got = np.asarray(
        spgemm_lp(ea.indices, ea.values, ea.row_nnz, eb.indices, eb.values,
                  eb.row_nnz, c_idx, c_nnz, interpret=True)
    )
    for i in range(a.m):
        n_i = int(c_nnz[i])
        np.testing.assert_allclose(got[i, :n_i], val[ip[i]: ip[i + 1]],
                                   rtol=1e-4, atol=1e-5)


def test_spgemm_lp_bucketed_matches_plain():
    """Width bucketing (padded rA/rB/rC, masked by the nnz vectors) must not
    change values; output sliced back to the caller's rC."""
    a = random_csr(14, 18, 3.0, 5)
    b = random_csr(18, 22, 2.5, 6)
    ea, eb = csr_to_ell(a), csr_to_ell(b)
    c_idx, c_nnz = _structure(a, b)
    plain = spgemm_lp(ea.indices, ea.values, ea.row_nnz, eb.indices,
                      eb.values, eb.row_nnz, c_idx, c_nnz, interpret=True)
    bucketed = spgemm_lp_bucketed(ea.indices, ea.values, ea.row_nnz,
                                  eb.indices, eb.values, eb.row_nnz,
                                  c_idx, c_nnz, interpret=True)
    assert bucketed.shape == plain.shape
    np.testing.assert_array_equal(np.asarray(bucketed), np.asarray(plain))


@pytest.mark.parametrize("seed,m,n,k,d", [
    (1, 40, 50, 45, 3.0),
    (2, 9, 7, 5, 1.5),
    (3, 100, 100, 100, 5.0),  # fm_cap > LP_TILE: multi-tile grid path
])
def test_lp_reuse_matches_numeric_reuse(seed, m, n, k, d):
    from repro.kernels.spgemm_lp import LP_TILE

    a = random_csr(m, n, d, seed)
    b = random_csr(n, k, d, seed + 100)
    res = spgemm(a, b, method="sparse", plan_cache=PlanCache())
    if seed == 3:  # construction precondition: cross-tile RMW must exercise
        assert res.plan.seg_ids.shape[0] > LP_TILE
    want = numeric_reuse(res.plan, a.values, b.values)
    got = lp_reuse(res.plan, a.values, b.values, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_lp_reuse_matches_ref_oracle():
    a = random_csr(21, 17, 2.0, 61)
    b = random_csr(17, 19, 2.0, 62)
    res = spgemm(a, b, method="sparse", plan_cache=PlanCache())
    p = res.plan
    want = ref.segsum_reuse_ref(p.a_slot_s, p.b_slot_s, p.seg_ids,
                                a.values, b.values, p.indices.shape[0])
    got = lp_reuse_arrays(p.a_slot_s, p.b_slot_s, p.seg_ids,
                          a.values, b.values,
                          nnz_cap=p.indices.shape[0], interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def _flat_lp_pair():
    """A pair in the KKLP regime (avg row flops >= 256)."""
    a = random_csr(4, 32, 16.0, 3)
    b = random_csr(32, 64, 32.0, 4)
    assert resolve_numeric_kernel(a, b) == "flat_lp"
    return a, b


def test_numeric_values_routes_flat_lp_to_lp_kernel():
    """Acceptance: the flat_lp branch dispatches to the LP kernel, not the
    dense accumulator — and the values still match the dense oracle."""
    a, b = _flat_lp_pair()
    c_idx, c_nnz = _structure(a, b)
    reset_kernel_counts()
    got = numeric_values(a, b, c_idx, c_nnz)
    assert KERNEL_COUNTS["flat_lp"] == 1
    assert KERNEL_COUNTS["dense_acc"] == 0
    dense = np.zeros((a.m, b.k), np.float32)
    got_n, ci, cn = np.asarray(got), np.asarray(c_idx), np.asarray(c_nnz)
    for i in range(a.m):
        dense[i, ci[i, : cn[i]]] = got_n[i, : cn[i]]
    np.testing.assert_allclose(dense, dense_spgemm_oracle(a, b),
                               rtol=1e-4, atol=1e-4)


def test_numeric_values_routes_modest_rows_to_dense_acc():
    a = random_csr(24, 30, 3.0, 7)
    b = random_csr(30, 20, 2.0, 8)
    assert resolve_numeric_kernel(a, b) == "dense_acc"
    c_idx, c_nnz = _structure(a, b)
    reset_kernel_counts()
    numeric_values(a, b, c_idx, c_nnz)
    assert KERNEL_COUNTS["dense_acc"] == 1
    assert KERNEL_COUNTS["flat_lp"] == 0


def test_numeric_values_int_dtype_falls_back_to_xla():
    """f32-accumulating Pallas kernels must not see int operands: "auto"
    resolves to the exact XLA reference even in the flat_lp regime."""
    a, b = _flat_lp_pair()
    ai = CSR(a.indptr, a.indices,
             jnp.ones(a.nnz_cap, jnp.int32), a.shape)
    bi = CSR(b.indptr, b.indices,
             jnp.ones(b.nnz_cap, jnp.int32), b.shape)
    assert resolve_numeric_kernel(ai, bi) == "xla"
    c_idx, c_nnz = _structure(ai, bi)
    reset_kernel_counts()
    out = numeric_values(ai, bi, c_idx, c_nnz)
    assert KERNEL_COUNTS["xla"] == 1
    assert jnp.issubdtype(out.dtype, jnp.integer)
    with pytest.raises(ValueError, match="unknown kernel"):
        numeric_values(a, b, c_idx, c_nnz, kernel="cuda")
    # an EXPLICIT Pallas kernel on f32-incompatible dtypes fails loudly
    # instead of silently truncating integer sums in the f32 accumulator
    for explicit in ("flat_lp", "dense_acc"):
        with pytest.raises(ValueError, match="accumulates in f32"):
            numeric_values(ai, bi, c_idx, c_nnz, kernel=explicit)


def test_spgemm_method_lp():
    """spgemm(method='lp'): same plan/cache pipeline, LP-kernel values."""
    a = random_csr(24, 30, 3.0, 7)
    b = random_csr(30, 20, 2.0, 8)
    res = spgemm(a, b, method="lp", plan_cache=PlanCache())
    assert res.stats["method"] == "lp"
    assert res.stats["lp_backend"] == "pallas"
    assert res.plan is not None  # the Reuse path survives
    np.testing.assert_allclose(np.asarray(res.c.to_dense()),
                               dense_spgemm_oracle(a, b), rtol=1e-4, atol=1e-4)
    # int operands: automatic XLA fallback, exact integer accumulation
    ai = CSR(a.indptr, a.indices, jnp.ones(a.nnz_cap, jnp.int32), a.shape)
    bi = CSR(b.indptr, b.indices, jnp.ones(b.nnz_cap, jnp.int32), b.shape)
    res_i = spgemm(ai, bi, method="lp", plan_cache=PlanCache())
    assert res_i.stats["lp_backend"] == "xla"
    assert jnp.issubdtype(res_i.c.values.dtype, jnp.integer)


def test_spgemm_stats_record_kernel_choice():
    a = random_csr(24, 30, 3.0, 7)
    b = random_csr(30, 20, 2.0, 8)
    res = spgemm(a, b, method="sparse", plan_cache=PlanCache())
    assert res.stats["kernel"] == "dense_acc"
    af, bf = _flat_lp_pair()
    res_f = spgemm(af, bf, method="sparse", plan_cache=PlanCache())
    assert res_f.stats["kernel"] == "flat_lp"


def test_numeric_lp_composite_matches_fresh():
    """numeric_lp (expand -> plan -> LP replay, one jitted composite) agrees
    with the XLA numeric_fresh pipeline on both structure and values."""
    from repro.core import numeric_fresh, round_capacity
    from repro.core.compression import flops_stats

    a = random_csr(20, 24, 2.5, 31)
    b = random_csr(24, 18, 2.0, 32)
    fm = int(flops_stats(a, b.row_nnz())[0])
    fm_cap = round_capacity(fm)
    c_ref, _ = numeric_fresh(a, b, fm_cap, round_capacity(64))
    nnz_cap = round_capacity(int(c_ref.indptr[-1]))
    c_ref, _ = numeric_fresh(a, b, fm_cap, nnz_cap)
    c_lp, plan = numeric_lp(a, b, fm_cap, nnz_cap, interpret=True)
    np.testing.assert_array_equal(np.asarray(c_lp.indptr),
                                  np.asarray(c_ref.indptr))
    np.testing.assert_allclose(np.asarray(c_lp.values),
                               np.asarray(c_ref.values), rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# Meta-algorithm correctness fixes (hypothesis-free home: this module is
# always collected, unlike test_spgemm.py / test_accumulators.py which are
# skipped without hypothesis — these guards must run everywhere)
# --------------------------------------------------------------------------


def test_choose_method_memory_guard_scales_with_dtype():
    """Regression: the dense-bytes guard must use the value itemsize. With
    m*k chosen so f32 values (4+4 bytes/slot) exactly fit the 1 GiB budget,
    f64 values (8+4 bytes/slot) must overflow it and force 'sparse' — the
    old hard-coded 4-byte guard said 'dense' for both. Values are numpy
    arrays so the f64 dtype survives without the x64 flag (choose_method
    only inspects dtypes; nothing is compiled here)."""
    from repro.core import choose_method

    m, k = 4096, 32768  # m*k*8 == 1 GiB == DENSE_BYTES_BUDGET
    base = random_csr(8, 8, 2.0, 3)
    a32 = CSR(base.indptr, base.indices,
              np.zeros(base.nnz_cap, np.float32), (m, 8))
    b32 = CSR(base.indptr, base.indices,
              np.zeros(base.nnz_cap, np.float32), (8, k))
    assert choose_method(a32, b32, {}) == "dense"
    a64 = CSR(a32.indptr, a32.indices,
              np.zeros(base.nnz_cap, np.float64), (m, 8))
    b64 = CSR(b32.indptr, b32.indices,
              np.zeros(base.nnz_cap, np.float64), (8, k))
    assert choose_method(a64, b64, {}) == "sparse"
    # mixed promotes: f32 * f64 accumulates in f64 -> still 'sparse'
    assert choose_method(a64, b32, {}) == "sparse"


def test_choose_kernel_requires_fm():
    """Regression: a missing stats['fm'] must fail loudly, not silently
    select 'dense_acc' via a 0 default."""
    from repro.core import choose_kernel

    a = random_csr(10, 10, 2.0, 2)
    b = random_csr(10, 10, 2.0, 3)
    with pytest.raises(KeyError, match="fm"):
        choose_kernel(a, b, {})
    assert choose_kernel(a, b, {"fm": 1}) == "dense_acc"
    assert choose_kernel(a, b, {"fm": 256 * a.m}) == "flat_lp"


def test_spgemm_rejects_unknown_method():
    a = random_csr(10, 10, 2.0, 2)
    b = random_csr(10, 10, 2.0, 3)
    with pytest.raises(ValueError, match="unknown method"):
        spgemm(a, b, method="hash")


def test_lp_insert_validates_max_occupancy():
    from repro.core.accumulators import lp_init, lp_insert

    st8 = lp_init(8)
    for bad in (0.0, -0.5, 1.5):
        with pytest.raises(ValueError, match="max_occupancy"):
            lp_insert(st8, jnp.int32(1), jnp.float32(1.0), max_occupancy=bad)


def test_lp_insert_full_table_terminates_at_clamped_cutoff():
    """max_occupancy=1.0 used to allow the table to fill with distinct keys,
    leaving the probe loop no -1 sentinel to stop at (infinite spin). The
    clamped cutoff (size - 1) must reject the key that would fill the table
    — and the probe must still terminate for both old and new keys after."""
    from repro.core.accumulators import lp_init, lp_insert

    size = 4
    st4 = lp_init(size)
    accepted = []
    for key in range(size + 2):  # 6 distinct keys into a 4-slot table
        st4, ok = lp_insert(st4, jnp.int32(key), jnp.float32(1.0),
                            max_occupancy=1.0)
        accepted.append(bool(ok))
    assert accepted == [True, True, True, False, False, False]
    assert int(st4.used) == size - 1  # one sentinel always survives
    # existing keys still accumulate at full clamped occupancy
    st4, ok = lp_insert(st4, jnp.int32(0), jnp.float32(2.0),
                        max_occupancy=1.0)
    assert bool(ok) and float(st4.values[0]) == 3.0


def test_executor_pallas_lp_backend():
    a = random_csr(25, 25, 3.0, 71)
    b = random_csr(25, 25, 3.0, 72)
    ex_xla = ReuseExecutor.from_matrices(a, b, plan_cache=PlanCache(),
                                         backend="xla")
    ex_lp = ReuseExecutor(ex_xla.plan, backend="pallas_lp", interpret=True)
    got = ex_lp.apply(a.values, b.values)
    want = ex_xla.apply(a.values, b.values)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    # int values route back to XLA inside the same backend: exact result
    av = jnp.ones(a.nnz_cap, jnp.int32)
    bv = jnp.ones(b.nnz_cap, jnp.int32)
    np.testing.assert_array_equal(np.asarray(ex_lp.apply(av, bv)),
                                  np.asarray(ex_xla.apply(av, bv)))
