"""Pallas TPU kernels for the paper's compute hot-spots + LM substrate.

Each kernel module contains the pl.pallas_call + BlockSpec implementation;
``ops.py`` holds the jit'd public wrappers; ``ref.py`` the pure-jnp oracles
every kernel is validated against (interpret=True) in tests/test_kernels.py.

Kernels:
  spgemm_symbolic  — symbolic phase, bitmask-compressed dense accumulator
  spgemm_numeric   — numeric phase, dense VMEM accumulator + one-hot MXU
  grouped_matmul   — MoE expert dispatch (two-phase SpGEMM specialization)
  flash_attention  — GQA / sliding-window / softcap blocked attention
  bsr_spgemm       — block-sparse (BSR) numeric phase: one MXU matmul per
                     grid step, plan-steered gathers (the MXU flagship)
  segsum_reuse     — Reuse-case numeric replay: flat-parallel
                     gather-multiply-segment-sum over f_m tiles
  spgemm_lp        — KKLP numeric phase: the paper's §3.1.2 two-level
                     linear-probing hash accumulator (50% max-occupancy, L1/L2
                     spill) in VMEM scratch; plus the lp_reuse replay variant
"""
from repro.kernels.spgemm_symbolic import spgemm_symbolic, spgemm_symbolic_bucketed
from repro.kernels.spgemm_numeric import spgemm_numeric, spgemm_numeric_bucketed
from repro.kernels.grouped_matmul import grouped_matmul
from repro.kernels.flash_attention import flash_attention
from repro.kernels.bsr_spgemm import bsr_spgemm_numeric, plan_bsr_numeric
from repro.kernels.segsum_reuse import segsum_reuse, segsum_reuse_arrays
from repro.kernels.spgemm_lp import (
    lp_reuse,
    lp_reuse_arrays,
    spgemm_lp,
    spgemm_lp_bucketed,
)

__all__ = [
    "spgemm_symbolic",
    "spgemm_symbolic_bucketed",
    "spgemm_numeric",
    "spgemm_numeric_bucketed",
    "spgemm_lp",
    "spgemm_lp_bucketed",
    "lp_reuse",
    "lp_reuse_arrays",
    "segsum_reuse",
    "segsum_reuse_arrays",
    "grouped_matmul",
    "flash_attention",
    "bsr_spgemm_numeric",
    "plan_bsr_numeric",
]
