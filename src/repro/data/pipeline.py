"""Deterministic, shard-aware data pipeline with exact skip-ahead.

Counter-based RNG (Philox keyed by (seed, step)) means batch ``s`` is a pure
function of the step number — restart/resume after a failure replays no data
and skips no data (the checkpoint stores only the step). Each host slices
its rows from the global batch by (process_index, num_processes).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SyntheticLMDataset:
    """Zipf-ish synthetic token stream (vocab-shaped like real text)."""

    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    process_index: int = 0
    num_processes: int = 1

    def local_batch_size(self) -> int:
        assert self.global_batch % self.num_processes == 0
        return self.global_batch // self.num_processes

    def get_batch(self, step: int) -> dict:
        rng = np.random.Generator(
            np.random.Philox(key=self.seed, counter=np.uint64(step))
        )
        b = self.local_batch_size()
        # skip rows belonging to other processes deterministically
        full = rng.zipf(1.3, size=(self.global_batch, self.seq_len + 1))
        full = (full - 1) % self.vocab_size
        lo = self.process_index * b
        rows = full[lo : lo + b].astype(np.int32)
        return {"tokens": rows[:, :-1], "labels": rows[:, 1:]}


@dataclasses.dataclass
class TokenFileDataset:
    """Memory-mapped token file (flat int32 stream), strided per process.

    Deterministic addressing: batch ``step`` reads rows
    [step * global_batch, (step+1) * global_batch) of seq_len+1 tokens, so
    resume-at-step is exact.
    """

    path: str
    seq_len: int
    global_batch: int
    process_index: int = 0
    num_processes: int = 1

    def __post_init__(self):
        self._data = np.memmap(self.path, dtype=np.int32, mode="r")
        self._row = self.seq_len + 1
        self.num_rows = len(self._data) // self._row

    def get_batch(self, step: int) -> dict:
        b = self.global_batch // self.num_processes
        start_row = (step * self.global_batch) % max(
            self.num_rows - self.global_batch, 1
        )
        lo = start_row + self.process_index * b
        rows = np.stack(
            [
                self._data[(lo + i) * self._row : (lo + i + 1) * self._row]
                for i in range(b)
            ]
        ).astype(np.int32)
        return {"tokens": rows[:, :-1], "labels": rows[:, 1:]}


def make_labels(batch: dict) -> dict:
    """For modality-stub batches: synthesize frame-level targets."""
    if "labels" in batch:
        return batch
    frames = batch["frames"]
    labels = (np.abs(frames.sum(-1) * 1000).astype(np.int64) % 504).astype(np.int32)
    return dict(batch, labels=labels)
