"""Public jit'd wrappers around the Pallas kernels.

These own the format plumbing (CSR -> ELL / bitmask, lane padding) and the
backend dispatch: on non-TPU backends the kernels run in interpret mode
(Pallas lowers only to TPU), so the same call sites work on the CPU test rig
and on real hardware. ``impl="xla"`` falls back to the pure-jnp references
— the dry-run path, since the CPU dry-run cannot lower TPU kernels.

Numeric-phase kernel selection is the paper's GPU rule
(``core.meta.choose_kernel``): ``kernel="auto"`` routes modest rows to the
dense-tile kernel (``dense_acc``) and flop-heavy rows (avg row flops >= 256)
to the LP-hash kernel (``flat_lp``) — and forces the ``xla`` reference path
for f64/int value dtypes, since the Pallas kernels accumulate in f32.
``KERNEL_COUNTS`` records every resolved dispatch so tests and benchmarks
can assert the routing (e.g. that ``flat_lp`` no longer lands on the dense
accumulator).
"""
from __future__ import annotations

import functools
from collections import Counter

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compression import bitmask_rows, flops_stats
from repro.core.meta import choose_kernel, f32_accumulation_ok
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.grouped_matmul import TM, grouped_matmul
from repro.kernels.spgemm_lp import spgemm_lp_bucketed
from repro.kernels.spgemm_numeric import spgemm_numeric_bucketed
from repro.kernels.spgemm_symbolic import spgemm_symbolic_bucketed
from repro.sparse.formats import CSR, csr_to_ell

NUMERIC_KERNELS = ("auto", "dense_acc", "flat_lp", "xla")

# Dispatch telemetry: resolved kernel name per numeric_values call.
KERNEL_COUNTS: Counter = Counter()


def reset_kernel_counts() -> None:
    KERNEL_COUNTS.clear()


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def resolve_numeric_kernel(a: CSR, b: CSR, kernel: str = "auto",
                           fm: int | None = None) -> str:
    """Resolve ``kernel`` to a concrete numeric-phase implementation.

    "auto" applies ``core.meta.choose_kernel`` (the avg-row-flops rule,
    static or fitted — see ``core.autotune``) after the dtype guard: f64/int
    accumulation cannot run on the f32 Pallas kernels, so those inputs
    resolve to "xla" regardless of regime. When the autotuner holds a
    measured winner for this problem's structure-stats bucket (recorded by
    ``numeric_values(..., tune="measure")``), that winner takes precedence
    over the threshold rule — measured beats fitted beats static.

    fm: the total multiplication count, if the caller already has it (e.g.
    from ``spgemm`` stats). Computing it here costs an O(nnz) ``flops_stats``
    pass plus a device->host sync per call — replay loops over a pinned
    structure should pass their constant ``fm`` instead of re-paying that.
    """
    from repro.core import autotune  # lazy: avoid kernels<->core cycle

    from repro.runtime.validate import SpgemmConfigError  # cycle-free

    if kernel not in NUMERIC_KERNELS:
        raise SpgemmConfigError(
            f"unknown kernel {kernel!r}; expected one of {NUMERIC_KERNELS}")
    f32_ok = f32_accumulation_ok(a.values.dtype, b.values.dtype)
    if kernel != "auto":
        # an explicit Pallas kernel the dtypes cannot run correctly must fail
        # loudly — silently accumulating f64/int in f32 would corrupt results
        if kernel != "xla" and not f32_ok:
            raise SpgemmConfigError(
                f"kernel={kernel!r} accumulates in f32 and cannot take "
                f"{a.values.dtype}/{b.values.dtype} operands exactly; "
                f"use kernel='xla' (what 'auto' resolves to for them)")
        return kernel
    if not f32_ok:
        return "xla"
    if fm is None:
        fm = int(flops_stats(a, b.row_nnz())[0])
    measured = autotune.lookup_measured(autotune.bucket_key(
        a.m, b.k, fm, a.values.dtype, b.values.dtype, table="numeric"))
    if measured is not None:
        return measured
    return choose_kernel(a, b, {"fm": fm})


def symbolic_rowsizes(a: CSR, b: CSR, *, pad_policy: str | None = None) -> jax.Array:
    """Kernel-backed symbolic phase: (m,) row sizes of C = A*B. ELL widths go
    through the same capacity buckets as the host driver, so similarly-sized
    matrices reuse one compiled kernel."""
    ell = csr_to_ell(a)
    bm = bitmask_rows(b)
    pad = (-bm.shape[1]) % 128
    if pad:
        bm = jnp.pad(bm, ((0, 0), (0, pad)))
    return spgemm_symbolic_bucketed(
        ell.indices, ell.row_nnz, bm, pad_policy=pad_policy,
        interpret=_interpret(),
    )


def numeric_values(a: CSR, b: CSR, c_idx: jax.Array, c_nnz: jax.Array, *,
                   pad_policy: str | None = None, kernel: str = "auto",
                   fm: int | None = None,
                   tune: str | None = None,
                   on_kernel_failure: str = "fallback") -> jax.Array:
    """Kernel-backed numeric phase: ELL-layout values of C at the symbolic
    structure ``c_idx``/``c_nnz`` (the Reuse entry point). Widths bucketed.

    kernel: "auto" (meta-algorithm rule + dtype guard — see
    ``resolve_numeric_kernel``), "dense_acc" (dense-tile Pallas kernel),
    "flat_lp" (LP-hash Pallas kernel), or "xla" (pure-jnp reference; the
    f64/int fallback). Replay loops should pass a concrete ``kernel`` or a
    precomputed ``fm`` — "auto" without ``fm`` pays an O(nnz) flops pass and
    a host sync per call to apply the selection rule.

    tune="measure" (with kernel="auto" only) replaces the threshold rule by
    a first-sight micro-bench: the eligible kernels are timed on these real
    operands, the winner runs and is recorded in the autotuner's bucket
    table — later same-bucket calls (through here *or* through
    ``resolve_numeric_kernel``) dispatch it with zero re-tuning.

    on_kernel_failure: "fallback" (default) walks the degradation ladder on
    any kernel exception — measured/resolved pick, then the static
    ``choose_kernel`` pick (auto modes only), then the exact-XLA reference —
    recording each step in ``telemetry.FALLBACK_COUNTS`` as
    ``"fault:<failed>-><next>"``; "raise" converts the first failure into a
    typed ``KernelFallbackError``. The ladder catches *outside* jit, so a
    failed trace is never cached and the fallback compiles cleanly.
    """
    from repro.core import autotune  # lazy: avoid kernels<->core cycle
    from repro.runtime import faults  # lazy: keep kernels import-light
    from repro.runtime.validate import (KernelFallbackError,
                                        SpgemmConfigError, SpgemmError)

    autotune.validate_tune(tune)
    if tune == "measure" and kernel != "auto":
        raise SpgemmConfigError(
            f"tune='measure' requires kernel='auto' (got kernel={kernel!r}):"
            f" measure mode picks the kernel empirically, an explicit pin "
            f"contradicts it")
    if on_kernel_failure not in ("fallback", "raise"):
        raise SpgemmConfigError(
            f"on_kernel_failure must be 'fallback' or 'raise', got "
            f"{on_kernel_failure!r}")
    ea = csr_to_ell(a)
    eb = csr_to_ell(b)

    def run(kname: str) -> jax.Array:
        faults.check(f"kernel:{kname}")
        if kname == "xla":
            return ref.spgemm_numeric_ref(
                ea.indices, ea.values, eb.indices, eb.values, c_idx, c_nnz,
                b.k)
        if kname == "flat_lp":
            return spgemm_lp_bucketed(
                ea.indices, ea.values, ea.row_nnz, eb.indices, eb.values,
                eb.row_nnz, c_idx, c_nnz, pad_policy=pad_policy,
                interpret=_interpret(),
            )
        return spgemm_numeric_bucketed(
            ea.indices, ea.values, ea.row_nnz, eb.indices, eb.values,
            c_idx, c_nnz, k=b.k, pad_policy=pad_policy,
            interpret=_interpret(),
        )

    # the auto paths need fm anyway (selection rule / bucket key); computing
    # it up front also prices the ladder's static rung at zero extra passes
    if kernel == "auto" and fm is None:
        fm = int(flops_stats(a, b.row_nnz())[0])
    if tune == "measure":
        bkey = autotune.bucket_key(a.m, b.k, fm, a.values.dtype,
                                   b.values.dtype, table="numeric")
        resolved = autotune.lookup_measured(bkey)
        if resolved is None:
            # candidate set = the dtype-eligible rows of the selection table
            cands = {"xla": lambda: run("xla")}
            if f32_accumulation_ok(a.values.dtype, b.values.dtype):
                cands["dense_acc"] = lambda: run("dense_acc")
                cands["flat_lp"] = lambda: run("flat_lp")
            resolved, _ = autotune.measure_and_record(bkey, cands)
    else:
        resolved = resolve_numeric_kernel(a, b, kernel, fm=fm)
        if (kernel == "auto" and resolved == "xla"
                and not f32_accumulation_ok(a.values.dtype, b.values.dtype)):
            from repro.core.telemetry import FALLBACK_COUNTS  # lazy: cycle

            FALLBACK_COUNTS["dtype:numeric_auto->xla"] += 1

    # degradation ladder: resolved/measured pick -> static choose_kernel
    # pick (auto modes only) -> exact-XLA reference, deduplicated in order
    ladder = [resolved]
    if kernel == "auto" or tune == "measure":
        static_pick = choose_kernel(a, b, {"fm": fm})
        if static_pick not in ladder:
            ladder.append(static_pick)
    if "xla" not in ladder:
        ladder.append("xla")

    from repro.obs import trace as obs_trace  # stdlib-only module, cheap

    for i, kname in enumerate(ladder):
        try:
            with obs_trace.span("numeric.kernel", kernel=kname, rung=i):
                out = run(kname)
        except SpgemmError:
            raise  # typed validation errors are not kernel failures
        except Exception as e:
            from repro.obs import recorder  # lazy: failure path only

            if on_kernel_failure == "raise":
                err = KernelFallbackError(
                    f"numeric kernel {kname!r} failed and "
                    f"on_kernel_failure='raise'")
                recorder.note_error(err, kernel=kname, site="numeric_values",
                                    trace_id=obs_trace.current_trace_id())
                raise err from e
            if i + 1 >= len(ladder):
                err = KernelFallbackError(
                    "numeric kernel ladder exhausted "
                    f"({' -> '.join(ladder)})")
                recorder.note_error(err, kernel=kname, site="numeric_values",
                                    trace_id=obs_trace.current_trace_id())
                raise err from e
            from repro.core.telemetry import FALLBACK_COUNTS  # lazy: cycle

            FALLBACK_COUNTS[f"fault:{kname}->{ladder[i + 1]}"] += 1
            recorder.record("fallback", kernel=kname,
                            fallback=f"{kname}->{ladder[i + 1]}",
                            verdict="fallback", site="numeric_values",
                            trace_id=obs_trace.current_trace_id())
            continue
        KERNEL_COUNTS[kname] += 1
        return out


def pallas_spgemm(a: CSR, b: CSR, *,
                  kernel: str = "auto") -> tuple[jax.Array, jax.Array, jax.Array]:
    """Full two-phase kernel pipeline. Returns (c_nnz, c_idx, c_val) with C
    in ELL layout; the host decides rC between the phases (two-phase
    contract). Structure extraction uses the core sort path; the numeric
    kernel follows ``kernel`` (default: the meta-algorithm rule)."""
    from repro.core.spgemm import host_fm_cap, numeric_fresh

    sizes = symbolic_rowsizes(a, b)
    r_c = max(int(jnp.max(sizes)), 1)
    # structure via the core path (host-mediated static sizes); one
    # flops_stats pass serves both the expansion cap and kernel selection
    fm = int(flops_stats(a, b.row_nnz())[0])
    fm_cap = host_fm_cap(a, b, fm=fm)
    nnz = int(jnp.sum(sizes))
    nnz_cap = max(-(-nnz // 8) * 8, 8)
    c, _ = numeric_fresh(a, b, fm_cap, nnz_cap)
    # CSR -> ELL structure for the kernel
    c_ell = csr_to_ell(
        CSR(indptr=c.indptr, indices=c.indices, values=c.values, shape=c.shape),
        r_pad=r_c,
    )
    vals = numeric_values(a, b, c_ell.indices, c_ell.row_nnz, kernel=kernel,
                          fm=fm)
    return c_ell.row_nnz, c_ell.indices, vals


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True,
              window: int | None = None, softcap: float | None = None,
              impl: str = "auto", segment_pos=None) -> jax.Array:
    """Multi-head attention over (H, T, D) tensors with GQA broadcast.

    impl: "pallas" (TPU kernel / interpret), "xla" (reference einsum path —
    used by the dry-run), "auto" (pallas on TPU, xla elsewhere).
    """
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl == "xla" or segment_pos is not None:
        return ref.flash_attention_ref(
            q, k, v, causal=causal, window=window, softcap=softcap,
            segment_pos=segment_pos,
        )
    tq = q.shape[1]
    bq = min(128, tq)
    bk = min(128, k.shape[1])
    return flash_attention(
        q, k, v, causal=causal, window=window, softcap=softcap,
        block_q=bq, block_k=bk, interpret=_interpret(),
    )


def expert_matmul(x: jax.Array, w: jax.Array, block_expert: jax.Array, *,
                  impl: str = "auto") -> jax.Array:
    """Grouped (expert) matmul for expert-sorted token blocks of width TM."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl == "xla":
        gid = jnp.repeat(block_expert, TM, total_repeat_length=x.shape[0])
        return ref.grouped_matmul_ref(x, w, gid)
    return grouped_matmul(x, w, block_expert, interpret=_interpret())
