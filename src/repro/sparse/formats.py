"""Sparse matrix containers as static-shape JAX pytrees.

CSR is the framework's interchange format (mirrors the paper's compressed-row
matrices). ELL is the Pallas-kernel feed format: fixed row width, gatherable
with static shapes. BSR carries dense (bm, bn) blocks for MXU-friendly block
SpGEMM.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["indptr", "indices", "values"],
    meta_fields=["shape"],
)
@dataclasses.dataclass(frozen=True)
class CSR:
    """Compressed sparse row matrix with static nnz capacity.

    indptr:  (m+1,) int32 — row pointers; indptr[m] == true nnz <= nnz_cap.
    indices: (nnz_cap,) int32 — column ids; slots >= indptr[m] are padding.
    values:  (nnz_cap,) dtype.
    shape:   (m, k) static python ints.
    """

    indptr: jax.Array
    indices: jax.Array
    values: jax.Array
    shape: tuple

    @property
    def m(self) -> int:
        return self.shape[0]

    @property
    def k(self) -> int:
        return self.shape[1]

    @property
    def nnz_cap(self) -> int:
        return self.indices.shape[0]

    @property
    def dtype(self):
        return self.values.dtype

    def nnz(self) -> jax.Array:
        """True (dynamic) nnz."""
        return self.indptr[-1]

    def row_nnz(self) -> jax.Array:
        return self.indptr[1:] - self.indptr[:-1]

    def valid_mask(self) -> jax.Array:
        """(nnz_cap,) bool — True for live entries."""
        return jnp.arange(self.nnz_cap, dtype=jnp.int32) < self.indptr[-1]

    def to_dense(self) -> jax.Array:
        """Jittable densification (for oracles/tests; O(m*k) memory)."""
        rows = csr_row_ids(self.indptr, self.nnz_cap)
        mask = self.valid_mask()
        cols = jnp.where(mask, self.indices, 0)
        vals = jnp.where(mask, self.values, 0)
        rows = jnp.where(mask, rows, 0)
        out = jnp.zeros(self.shape, self.values.dtype)
        return out.at[rows, cols].add(vals)

    @staticmethod
    def from_dense(x, nnz_cap: int | None = None, index_dtype=jnp.int32) -> "CSR":
        """Host-side construction from a dense array (numpy path, test helper)."""
        x = np.asarray(x)
        m, k = x.shape
        rows, cols = np.nonzero(x)
        vals = x[rows, cols]
        nnz = len(rows)
        cap = nnz_cap if nnz_cap is not None else max(nnz, 1)
        if cap < nnz:
            from repro.runtime.validate import CapacityOverflowError  # cycle-free
            raise CapacityOverflowError(
                f"nnz_cap={cap} < nnz={nnz}: the requested capacity cannot "
                f"hold the dense input's live entries")
        indptr = np.zeros(m + 1, np.int32)
        np.add.at(indptr[1:], rows, 1)
        indptr = np.cumsum(indptr).astype(np.int32)
        indices = np.zeros(cap, np.int32)
        values = np.zeros(cap, x.dtype)
        indices[:nnz] = cols
        values[:nnz] = vals
        return CSR(
            indptr=jnp.asarray(indptr),
            indices=jnp.asarray(indices, index_dtype),
            values=jnp.asarray(values),
            shape=(m, k),
        )

    @staticmethod
    def from_arrays(indptr, indices, values, shape, validate: bool = True) -> "CSR":
        """Wrap pre-built arrays as a CSR.

        ``validate=True`` (the default) runs cheap host-side shape checks —
        array-length agreement and shape sanity only, never an O(nnz)
        content scan — raising ``SpgemmInputError``. Jitted callers and
        deliberate bad-CSR construction (fault injection) pass
        ``validate=False``; content invariants are the job of
        ``runtime.validate.check_csr`` / ``spgemm(validate=...)``.
        """
        mat = CSR(
            indptr=jnp.asarray(indptr, jnp.int32),
            indices=jnp.asarray(indices, jnp.int32),
            values=jnp.asarray(values),
            shape=tuple(shape),
        )
        if validate:
            # lazy import: formats is a leaf module the runtime layer reads
            from repro.runtime.validate import SpgemmInputError

            shape = mat.shape
            if len(shape) != 2 or any(int(s) < 0 for s in shape):
                raise SpgemmInputError(
                    f"shape must be a non-negative (m, k) pair, got {shape}")
            if mat.indptr.shape[0] != shape[0] + 1:
                raise SpgemmInputError(
                    f"len(indptr) == {mat.indptr.shape[0]} but shape[0]+1 "
                    f"== {shape[0] + 1}")
            if mat.indices.shape[0] != mat.values.shape[0]:
                raise SpgemmInputError(
                    f"len(indices) == {mat.indices.shape[0]} != "
                    f"len(values) == {mat.values.shape[0]}")
        return mat


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["indices", "values", "row_nnz"],
    meta_fields=["shape"],
)
@dataclasses.dataclass(frozen=True)
class ELL:
    """ELLPACK: every row padded to a fixed width r_pad.

    indices: (m, r_pad) int32 — padded slots hold 0.
    values:  (m, r_pad) dtype — padded slots hold 0 (so numerics ignore them).
    row_nnz: (m,) int32 — live width per row.
    shape:   (m, k).
    """

    indices: jax.Array
    values: jax.Array
    row_nnz: jax.Array
    shape: tuple

    @property
    def m(self) -> int:
        return self.shape[0]

    @property
    def k(self) -> int:
        return self.shape[1]

    @property
    def r_pad(self) -> int:
        return self.indices.shape[1]

    def valid_mask(self) -> jax.Array:
        return jnp.arange(self.r_pad, dtype=jnp.int32)[None, :] < self.row_nnz[:, None]

    def to_dense(self) -> jax.Array:
        mask = self.valid_mask()
        rows = jnp.broadcast_to(
            jnp.arange(self.m, dtype=jnp.int32)[:, None], self.indices.shape
        )
        out = jnp.zeros(self.shape, self.values.dtype)
        return out.at[
            jnp.where(mask, rows, 0), jnp.where(mask, self.indices, 0)
        ].add(jnp.where(mask, self.values, 0))


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["indptr", "indices", "blocks"],
    meta_fields=["shape", "block_shape"],
)
@dataclasses.dataclass(frozen=True)
class BSR:
    """Block CSR: CSR over a coarse (m/bm, k/bn) block graph with dense blocks.

    indptr:  (mb+1,) int32 over block rows.
    indices: (nnzb_cap,) int32 block-column ids.
    blocks:  (nnzb_cap, bm, bn) dense blocks.
    """

    indptr: jax.Array
    indices: jax.Array
    blocks: jax.Array
    shape: tuple
    block_shape: tuple

    @property
    def mb(self) -> int:
        return self.shape[0] // self.block_shape[0]

    @property
    def kb(self) -> int:
        return self.shape[1] // self.block_shape[1]

    def to_dense(self) -> jax.Array:
        bm, bn = self.block_shape
        nnzb_cap = self.indices.shape[0]
        rows = csr_row_ids(self.indptr, nnzb_cap)
        mask = jnp.arange(nnzb_cap, dtype=jnp.int32) < self.indptr[-1]
        rows = jnp.where(mask, rows, 0)
        cols = jnp.where(mask, self.indices, 0)
        blocks = jnp.where(mask[:, None, None], self.blocks, 0)
        out = jnp.zeros((self.mb, self.kb, bm, bn), self.blocks.dtype)
        out = out.at[rows, cols].add(blocks)
        return out.transpose(0, 2, 1, 3).reshape(self.shape)


def csr_row_ids(indptr: jax.Array, nnz_cap: int) -> jax.Array:
    """(nnz_cap,) row id per CSR slot; padded slots get row m-1+1 clamped.

    Standard trick: scatter 1 at each row start, cumsum. Jittable, O(nnz).
    """
    m = indptr.shape[0] - 1
    marks = jnp.zeros(nnz_cap, jnp.int32).at[indptr[1:]].add(
        1, mode="drop", indices_are_sorted=True
    )
    row = jnp.cumsum(marks)
    return jnp.minimum(row, m - 1).astype(jnp.int32)


def csr_to_ell(a: CSR, r_pad: int | None = None) -> ELL:
    """Jittable CSR→ELL when r_pad given statically; host decides r_pad."""
    if r_pad is None:
        r_pad = int(jnp.max(a.row_nnz()))
        r_pad = max(r_pad, 1)
    row_nnz = a.row_nnz()
    # gather: ell[i, r] = csr[indptr[i] + r] when r < row_nnz[i]
    base = a.indptr[:-1][:, None] + jnp.arange(r_pad, dtype=jnp.int32)[None, :]
    mask = jnp.arange(r_pad, dtype=jnp.int32)[None, :] < row_nnz[:, None]
    flat = jnp.where(mask, base, 0).reshape(-1)
    idx = jnp.where(mask.reshape(-1), a.indices[jnp.minimum(flat, a.nnz_cap - 1)], 0)
    val = jnp.where(mask.reshape(-1), a.values[jnp.minimum(flat, a.nnz_cap - 1)], 0)
    return ELL(
        indices=idx.reshape(a.m, r_pad).astype(jnp.int32),
        values=val.reshape(a.m, r_pad),
        row_nnz=row_nnz.astype(jnp.int32),
        shape=a.shape,
    )


def ell_to_csr(e: ELL, nnz_cap: int | None = None) -> CSR:
    """Host-side ELL→CSR (test helper)."""
    idx = np.asarray(e.indices)
    val = np.asarray(e.values)
    rn = np.asarray(e.row_nnz)
    m = e.m
    cap = int(nnz_cap if nnz_cap is not None else max(int(rn.sum()), 1))
    indptr = np.zeros(m + 1, np.int32)
    indptr[1:] = np.cumsum(rn)
    indices = np.zeros(cap, np.int32)
    values = np.zeros(cap, val.dtype)
    pos = 0
    for i in range(m):
        w = int(rn[i])
        indices[pos : pos + w] = idx[i, :w]
        values[pos : pos + w] = val[i, :w]
        pos += w
    return CSR.from_arrays(indptr, indices, values, e.shape)
