"""repro.dist acceptance tests on 8 fake host devices (subprocess: the
device-count flag must be set before jax initializes, and the main test
process must keep seeing 1 device).

Covers the sharded-executor contract: bitwise equality with the
single-device ReuseExecutor after merge_shards, one structure hash and zero
retraces across >= 8 replays, mesh-aware plan-cache hits, batched replay,
and the degenerate shard layouts (indivisible m, empty shards).
"""
import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(body: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(body)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    return proc.stdout


def test_sharded_executor_bitwise_and_telemetry():
    """Acceptance: merge(apply(...)) == single-device executor BITWISE for
    both placements; one structure_key hash at pin; zero retraces and zero
    hashes across 8 replays."""
    out = run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.compat import make_mesh
        from repro.core import (HASH_COUNTS, PlanCache, ReuseExecutor,
                                reset_hash_counts, reset_trace_counts)
        from repro.core.spgemm import TRACE_COUNTS
        from repro.dist import ShardedReuseExecutor
        from repro.sparse import random_csr

        mesh = make_mesh((8,), ("data",))
        a = random_csr(96, 64, 4.0, 1)
        b = random_csr(64, 80, 3.0, 2)
        ref = ReuseExecutor.from_matrices(a, b, plan_cache=PlanCache())
        want = ref.to_csr(ref.apply(a.values, b.values))
        want_nnz = int(want.indptr[-1])

        for placement in ("replicated", "allgather"):
            reset_hash_counts()
            ex = ShardedReuseExecutor.from_matrices(
                a, b, mesh, b_placement=placement, plan_cache=PlanCache())
            assert sum(HASH_COUNTS.values()) == 1  # the one pin hash
            c = ex.merge(ex.apply(a.values, b.values))
            nnz = int(c.indptr[-1])
            assert nnz == want_nnz
            np.testing.assert_array_equal(np.asarray(c.indptr),
                                          np.asarray(want.indptr))
            np.testing.assert_array_equal(np.asarray(c.indices)[:nnz],
                                          np.asarray(want.indices)[:nnz])
            np.testing.assert_array_equal(np.asarray(c.values)[:nnz],
                                          np.asarray(want.values)[:nnz])

            reset_trace_counts(); reset_hash_counts()
            rng = np.random.default_rng(0)
            for _ in range(8):
                av = jnp.asarray(rng.standard_normal(a.nnz_cap), jnp.float32)
                bv = jnp.asarray(rng.standard_normal(b.nnz_cap), jnp.float32)
                jax.block_until_ready(ex.apply(av, bv))
            assert sum(TRACE_COUNTS.values()) == 0, dict(TRACE_COUNTS)
            assert sum(HASH_COUNTS.values()) == 0, dict(HASH_COUNTS)
        print("OK")
    """)
    assert "OK" in out


def test_sharded_spgemm_mesh_entry_and_cache():
    """spgemm(mesh=...) routes through repro.dist: oracle-correct result,
    mesh stats recorded, and a repeated structure hits the mesh-aware cache
    (no re-shard, no rebuild)."""
    out = run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.compat import make_mesh
        from repro.core import PlanCache, spgemm
        from repro.sparse import CSR, random_csr
        from repro.sparse.oracle import dense_spgemm_oracle

        mesh = make_mesh((8,), ("data",))
        cache = PlanCache()
        a = random_csr(96, 64, 4.0, 1)
        b = random_csr(64, 80, 3.0, 2)
        res = spgemm(a, b, mesh=mesh, plan_cache=cache)
        np.testing.assert_allclose(np.asarray(res.c.to_dense()),
                                   dense_spgemm_oracle(a, b),
                                   rtol=1e-4, atol=1e-4)
        assert res.stats["cache"] == "miss"
        assert res.stats["num_shards"] == 8
        assert res.stats["b_placement"] == "replicated"
        assert res.stats["mesh_shape"] == (8,)

        rng = np.random.default_rng(0)
        a2 = CSR(a.indptr, a.indices,
                 jnp.asarray(rng.standard_normal(a.nnz_cap), jnp.float32),
                 a.shape)
        res2 = spgemm(a2, b, mesh=mesh, plan_cache=cache)
        assert res2.stats["cache"] == "hit"
        np.testing.assert_allclose(np.asarray(res2.c.to_dense()),
                                   dense_spgemm_oracle(a2, b),
                                   rtol=1e-4, atol=1e-4)
        # dense method cannot shard
        try:
            spgemm(a, b, method="dense", mesh=mesh)
        except ValueError:
            pass
        else:
            raise AssertionError("dense + mesh should raise")
        print("OK")
    """)
    assert "OK" in out


def test_sharded_apply_batched_matches_per_call():
    """apply_batched == per-call apply bitwise for stacked/shared operands
    on both placements (one dispatch per batch across the mesh)."""
    out = run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.compat import make_mesh
        from repro.core import PlanCache
        from repro.dist import ShardedReuseExecutor
        from repro.sparse import random_csr

        mesh = make_mesh((8,), ("data",))
        a = random_csr(48, 40, 3.0, 21)
        b = random_csr(40, 36, 2.0, 22)
        rng = np.random.default_rng(1)
        a_stack = jnp.asarray(rng.standard_normal((5, a.nnz_cap)), jnp.float32)
        b_stack = jnp.asarray(rng.standard_normal((5, b.nnz_cap)), jnp.float32)
        for placement in ("replicated", "allgather"):
            ex = ShardedReuseExecutor.from_matrices(
                a, b, mesh, b_placement=placement, plan_cache=PlanCache())
            got = ex.apply_batched(a_stack, b_stack)
            assert got.shape == (5, ex.num_shards, ex.nnz_cap)
            for i in range(5):
                np.testing.assert_array_equal(
                    np.asarray(got[i]),
                    np.asarray(ex.apply(a_stack[i], b_stack[i])))
            # shared unbatched B (the fixed-prolongator serving shape)
            got_b = ex.apply_batched(a_stack, b.values)
            for i in range(5):
                np.testing.assert_array_equal(
                    np.asarray(got_b[i]),
                    np.asarray(ex.apply(a_stack[i], b.values)))
            try:
                ex.apply_batched(a_stack[0], b.values)
            except ValueError:
                pass
            else:
                raise AssertionError("unbatched pair should raise")
            # device-side merge_values == host merge's live value layout
            one = ex.apply(a_stack[0], b_stack[0])
            merged = ex.merge(one)
            nnz = int(merged.indptr[-1])
            mv = ex.merge_values(one)
            assert mv.shape == (nnz,)
            np.testing.assert_array_equal(np.asarray(mv),
                                          np.asarray(merged.values)[:nnz])
            # batched output must be rejected by the merge paths
            for bad in (ex.merge, ex.merge_values):
                try:
                    bad(got)
                except ValueError:
                    pass
                else:
                    raise AssertionError("batched values should raise")
        print("OK")
    """)
    assert "OK" in out


def test_sharded_degenerate_layouts():
    """Indivisible m and S > m (whole shards empty) stay oracle-correct
    across the mesh for both placements."""
    out = run_sub("""
        import numpy as np
        from repro.compat import make_mesh
        from repro.core import PlanCache
        from repro.dist import ShardedReuseExecutor
        from repro.sparse import random_csr
        from repro.sparse.oracle import dense_spgemm_oracle

        mesh = make_mesh((8,), ("data",))
        for m in (91, 5):
            a = random_csr(m, 32, 3.0, m)
            b = random_csr(32, 24, 2.0, m + 1)
            want = dense_spgemm_oracle(a, b)
            for placement in ("replicated", "allgather"):
                ex = ShardedReuseExecutor.from_matrices(
                    a, b, mesh, b_placement=placement, plan_cache=PlanCache())
                c = ex.merge(ex.apply(a.values, b.values))
                np.testing.assert_allclose(np.asarray(c.to_dense()), want,
                                           rtol=1e-4, atol=1e-4)
        print("OK")
    """)
    assert "OK" in out
