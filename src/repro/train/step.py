"""Training step: loss, grads, AdamW update; optional microbatch accumulation.

The step is a single jit-able function suitable for ``.lower()`` in the
dry-run: inputs are (params, opt_state, batch), all shardings provided via
``in_shardings``. Gradient all-reduce over the data axes is inserted by
GSPMD from the batch sharding; overlap with the backward pass is XLA's
latency-hiding scheduler's job (enabled by the dryrun XLA flags).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import forward
from repro.models.sharding import ShardingRules
from repro.train.optim import AdamWConfig, adamw_init, adamw_update


def cross_entropy_loss(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean next-token CE. logits: (B, T, V); labels: (B, T) int32.

    Computed in f32 with the max-subtraction folded in; the (B, T, V)
    f32 cast stays sharded (dp, None, model) per the logits constraint.
    """
    lf = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(lf, axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1)) + m[..., 0]
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def train_step(params, opt_state, batch: dict, cfg: ModelConfig,
               rules: ShardingRules, opt_cfg: AdamWConfig, *, mesh=None,
               num_microbatches: int = 1):
    """One optimizer step. batch: {'tokens'|'frames', 'labels'}."""

    def loss_fn(p, mb):
        logits, _ = forward(p, mb, cfg, rules, mesh=mesh, remat=True)
        return cross_entropy_loss(logits, mb["labels"])

    if num_microbatches <= 1:
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
    else:
        def split(x):
            b = x.shape[0]
            return x.reshape(num_microbatches, b // num_microbatches, *x.shape[1:])

        mbs = jax.tree.map(split, batch)

        def acc_step(carry, mb):
            loss_acc, grad_acc = carry
            l, g = jax.value_and_grad(loss_fn)(params, mb)
            return (
                loss_acc + l / num_microbatches,
                jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32) / num_microbatches,
                    grad_acc, g,
                ),
            ), None

        zero_grads = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        (loss, grads), _ = jax.lax.scan(
            acc_step, (jnp.zeros((), jnp.float32), zero_grads), mbs
        )

    params, opt_state, metrics = adamw_update(grads, opt_state, params, opt_cfg)
    metrics["loss"] = loss
    return params, opt_state, metrics


def make_train_step(cfg: ModelConfig, rules: ShardingRules,
                    opt_cfg: Optional[AdamWConfig] = None, *, mesh=None,
                    num_microbatches: int = 1):
    opt_cfg = opt_cfg or AdamWConfig()

    def fn(params, opt_state, batch):
        return train_step(
            params, opt_state, batch, cfg, rules, opt_cfg, mesh=mesh,
            num_microbatches=num_microbatches,
        )

    return fn
