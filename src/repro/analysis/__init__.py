"""repro.analysis — static contract linter for the SpGEMM stack.

Nine PRs of growth encoded this repo's load-bearing invariants as prose:
"failures are caught *outside* jit so a failed trace is never cached",
"off means off: dispatch-identical", documented counter-key grammars, a
fixed span taxonomy, env-var resolution confined to two call sites. This
package turns that prose into an AST pass that fails CI the moment a new
call site drifts (see ROADMAP "The analysis layer").

Pieces:

  * :mod:`repro.analysis.context`  — parsed-module project model + the
    machine-readable registries (``SPAN_NAMES``, ``KEY_FAMILIES``,
    ``ALL_COUNTERS``, the typed taxonomy) read *statically* from the tree
    under scan, so fixture trees lint exactly like the real package;
  * :mod:`repro.analysis.registry` — the rule registry (``@rule``);
  * ``rules_*`` modules            — one module per shipped rule;
  * :mod:`repro.analysis.runner`   — ``run_analysis``: scan + suppression
    (``# repro: allow[RULE]``) + committed-baseline filtering;
  * :mod:`repro.analysis.cli`      — ``python -m repro.analysis`` (exit 0
    iff no *new* findings; ``--json`` report artifact for CI).
"""
from repro.analysis.findings import Finding, Report
from repro.analysis.registry import RULES, all_rule_ids, rule
from repro.analysis.runner import run_analysis

# rule modules self-register on import; keep after registry import
from repro.analysis import (  # noqa: E402  (registration side effects)
    rules_env,
    rules_jit,
    rules_spans,
    rules_taxonomy,
    rules_telemetry,
)

__all__ = [
    "Finding",
    "Report",
    "RULES",
    "all_rule_ids",
    "rule",
    "run_analysis",
    "rules_env",
    "rules_jit",
    "rules_spans",
    "rules_taxonomy",
    "rules_telemetry",
]
