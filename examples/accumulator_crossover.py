"""Accumulator crossover demo: the paper's KKLP position, end to end.

The meta-algorithm (core/meta.py, the paper's §3.3 GPU rule) keys numeric-
phase kernel selection on average row flops: modest rows go to the dense
accumulator, flop-heavy rows (>= 256) to the linear-probing hash accumulator
(kernels/spgemm_lp.py). This script walks the whole wiring on CPU (Pallas in
interpret mode):

  1. choose_kernel's decision on both sides of the cutoff
  2. spgemm(method="lp"): LP-kernel values on the plan pipeline
  3. a pinned ReuseExecutor replaying through backend="pallas_lp"
  4. the spill path: a deliberately tiny L1 table, bitwise-validated against
     the jittable accumulator oracle (core/accumulators.py)

Run: PYTHONPATH=src python examples/accumulator_crossover.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import PlanCache, ReuseExecutor, choose_kernel, spgemm
from repro.kernels import ref, spgemm_lp
from repro.kernels.ops import resolve_numeric_kernel
from repro.sparse import dense_spgemm_oracle, gustavson_ell_structure, random_csr
from repro.sparse.formats import csr_to_ell


def main():
    # 1. both sides of the avg-row-flops cutoff
    modest_a, modest_b = random_csr(64, 64, 3.0, 1), random_csr(64, 64, 3.0, 2)
    heavy_a, heavy_b = random_csr(4, 32, 16.0, 3), random_csr(32, 64, 32.0, 4)
    for label, (a, b) in (("modest rows", (modest_a, modest_b)),
                          ("flop-heavy rows", (heavy_a, heavy_b))):
        res = spgemm(a, b, method="sparse", plan_cache=PlanCache())
        fm = res.stats["fm"]
        print(f"{label}: avg row flops {fm / a.m:.1f} -> "
              f"choose_kernel={choose_kernel(a, b, {'fm': fm})}, "
              f"numeric kernel={resolve_numeric_kernel(a, b)}")

    # 2. spgemm(method="lp"): the KKLP position on the plan pipeline
    res = spgemm(heavy_a, heavy_b, method="lp", plan_cache=PlanCache())
    err = np.abs(np.asarray(res.c.to_dense())
                 - dense_spgemm_oracle(heavy_a, heavy_b)).max()
    print(f"spgemm(method='lp'): backend={res.stats['lp_backend']}, "
          f"max |err| vs dense oracle = {err:.2e}")
    assert err < 1e-4

    # 3. pinned replay through the LP accumulator
    ex = ReuseExecutor(res.plan, backend="pallas_lp", interpret=True)
    ex_xla = ReuseExecutor(res.plan, backend="xla")
    rng = np.random.default_rng(0)
    for step in range(3):
        av = jnp.asarray(rng.standard_normal(heavy_a.nnz_cap), jnp.float32)
        bv = jnp.asarray(rng.standard_normal(heavy_b.nnz_cap), jnp.float32)
        lp_vals = ex.apply(av, bv)
        xla_vals = ex_xla.apply(av, bv)
        err = np.abs(np.asarray(lp_vals) - np.asarray(xla_vals)).max()
        print(f"replay {step}: pallas_lp vs xla max |err| = {err:.2e}")
        assert err < 1e-5

    # 4. spill: L1 of 8 slots (cutoff 4) against rows with ~32 distinct
    # columns — most keys overflow to L2, and the kernel output is *bitwise*
    # the jittable accumulator oracle's
    ea, eb = csr_to_ell(heavy_a), csr_to_ell(heavy_b)
    c_idx, c_nnz = (jnp.asarray(x)
                    for x in gustavson_ell_structure(heavy_a, heavy_b))
    got = spgemm_lp(ea.indices, ea.values, ea.row_nnz, eb.indices, eb.values,
                    eb.row_nnz, c_idx, c_nnz, l1_size=8, interpret=True)
    want = ref.spgemm_lp_ref(ea.indices, ea.values, ea.row_nnz, eb.indices,
                             eb.values, eb.row_nnz, c_idx, c_nnz, 8)
    bitwise = np.array_equal(np.asarray(got), np.asarray(want))
    print(f"spill path (l1_size=8): bitwise == accumulator oracle: {bitwise}")
    assert bitwise
    print("OK")


if __name__ == "__main__":
    main()
