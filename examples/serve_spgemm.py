"""SparseService quickstart: overload-safe SpGEMM serving in five scenes.

The paper's Reuse case at serving rates: many requests, few structures,
every reply a pinned-plan replay. This script walks the serving tier's
whole contract on CPU:

  1. admission + grouped dispatch — mixed-structure traffic, one device
     dispatch per structure group, every reply bitwise-checked against the
     fresh spgemm() reference
  2. backpressure — a burst past the queue bound sheds with typed
     ``AdmissionRejected``, never an unbounded queue, never a silent drop
  3. deadlines — an infeasible deadline is refused at the door, an expired
     one is shed from the queue as ``DeadlineExceeded``; everything else
     completes
  4. breaker under kernel faults — the fast Pallas path starts failing
     (injected), the degradation ladder keeps every reply bitwise-correct,
     the circuit breaker opens and routes traffic straight to XLA, and a
     half-open probe re-admits the fast path once it heals
  5. warming — the service's own traffic log prefetches the hot plans after
     an eviction, so the next burst never pays a plan build
  6. observability — turn tracing on for a burst: request trace ids ride
     every span into a Chrome trace export, per-phase latency histograms
     land in the metrics registry, and ``stats(debug=True)`` returns the
     flight-recorder ring (tracing off costs nothing — see
     ``benchmarks.run --bench obs``)

Run: PYTHONPATH=src python examples/serve_spgemm.py
"""
import jax.numpy as jnp

from repro import obs
from repro.core import spgemm, telemetry
from repro.runtime import AdmissionRejected, DeadlineExceeded, faults
from repro.serve import SparseService
from repro.sparse import random_csr


class Clock:
    """A hand-cranked clock so the deadline/breaker scenes are exact."""

    def __init__(self):
        self.now = 0.0

    def __call__(self):
        return self.now


def main():
    structures = [
        (random_csr(48, 32, 3.0, 1), random_csr(32, 40, 3.0, 2)),
        (random_csr(24, 32, 2.0, 3), random_csr(32, 16, 2.0, 4)),
    ]
    refs = [spgemm(a, b, method="sparse").c.to_dense() for a, b in structures]
    clock = Clock()
    svc = SparseService(backend="pallas", max_queue=8, max_batch=4,
                        breaker_threshold=2, breaker_cooldown_s=5.0,
                        clock=clock, sleep=lambda _: None)

    # 1. mixed traffic: grouped into one dispatch per structure ------------
    reqs = [svc.submit(*structures[i % 2]) for i in range(6)]
    svc.drain()
    for i, r in enumerate(reqs):
        assert r.ok and bool(jnp.all(r.value.to_dense() == refs[i % 2]))
    print(f"1. served {len(reqs)} requests in "
          f"{svc.counters['group_dispatches']} group dispatches "
          f"(group sizes: {sorted(r.group_size for r in reqs)})")

    # 2. backpressure: the queue bound sheds, typed ------------------------
    burst = [svc.submit(*structures[0]) for _ in range(12)]
    rejected = [r for r in burst if isinstance(r.error, AdmissionRejected)]
    assert len(rejected) == 4  # 8 admitted (max_queue), 4 refused
    svc.drain()
    assert all(r.ok for r in burst if r not in rejected)
    print(f"2. burst of {len(burst)}: {len(rejected)} shed with "
          f"AdmissionRejected, the rest completed")

    # 3. deadlines: refused at the door, shed from the queue ---------------
    svc.metrics.reset()    # forget the measured (fast) steps for this demo
    svc.step_hint_s = 0.5  # pretend a step costs 0.5s (seeds the estimator)
    infeasible = svc.submit(*structures[0], deadline_s=0.1)
    assert isinstance(infeasible.error, AdmissionRejected)
    expired = svc.submit(*structures[0], deadline_s=1.0)
    fine = svc.submit(*structures[1], deadline_s=60.0)
    clock.now += 2.0  # the queue sat longer than the first deadline
    svc.drain()
    assert isinstance(expired.error, DeadlineExceeded) and fine.ok
    print("3. deadlines: 0.1s refused at admission (est wait 0.5s), 1.0s "
          "expired in queue -> DeadlineExceeded, 60s completed")

    # 4. kernel faults: ladder keeps replies correct, breaker stops paying -
    def serve_one():
        r = svc.submit(*structures[0])
        svc.step()
        assert r.ok and bool(jnp.all(r.value.to_dense() == refs[0]))
        return r

    with faults.failpoint("kernel:pallas"):
        degraded = [serve_one().degraded for _ in range(4)]
    opens = telemetry.BREAKER_COUNTS["pallas:open"]
    shorts = telemetry.BREAKER_COUNTS["pallas:short_circuit"]
    print(f"4. fault window: degraded={degraded} (breaker opened after "
          f"{svc._breakers['pallas'].failure_threshold}; opens={opens}, "
          f"short_circuits={shorts} requests skipped the broken kernel; "
          f"every reply still bitwise-correct)")
    clock.now += 5.0  # cooldown elapses, kernel healed
    r = serve_one()
    assert r.backend == "pallas" and not r.degraded
    print(f"4. recovery: half-open probe succeeded, breaker "
          f"{svc._breakers['pallas'].state}, traffic back on pallas")

    # 5. warming from the service's own traffic log ------------------------
    svc.plan_cache.clear()  # an eviction storm
    stats = svc.warm()
    misses0 = svc.plan_cache.stats()["misses"]
    svc.submit(*structures[0])
    svc.submit(*structures[1])
    svc.drain()
    assert svc.plan_cache.stats()["misses"] == misses0
    print(f"5. warmed {stats['built']} plans from the traffic log; the next "
          f"burst ran with zero plan-cache misses")

    # 6. observability: trace a burst, read the histograms, dump the ring --
    obs.set_tracing("on")  # or REPRO_TRACE=1, or spgemm(..., trace=True)
    traced = [svc.submit(*structures[i % 2]) for i in range(4)]
    svc.drain()
    assert all(r.ok for r in traced)
    payload = obs.export_chrome_trace("trace_serve_quickstart.json")
    spans = payload["traceEvents"]
    tids = sorted({e["args"].get("trace_id") for e in spans
                   if e["args"].get("trace_id")})
    hist = obs.default_registry().histogram("numeric.dispatch")
    debug = svc.stats(debug=True)
    print(f"6. traced burst: {len(spans)} spans from requests {tids} -> "
          f"trace_serve_quickstart.json (open in chrome://tracing); "
          f"numeric.dispatch p50={hist.percentile(50)*1e6:.0f}us "
          f"p99={hist.percentile(99)*1e6:.0f}us over {hist.count} dispatches; "
          f"flight recorder holds {debug['flight_recorder']['recorded']} "
          f"events")
    obs.set_tracing(None)  # back to the $REPRO_TRACE default (off)

    print(f"\nfinal stats: completed={svc.counters['completed']} "
          f"shed_rate={svc.stats()['shed_rate']:.3f} "
          f"breaker={svc.stats()['breakers']['pallas']['state']}")
    print("OK")


if __name__ == "__main__":
    main()
