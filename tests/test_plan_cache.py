"""Single-expansion pipeline, capacity bucketing, and plan-cache tests.

Deliberately hypothesis-free: these must run on the bare container (see
tests/conftest.py). Covers the PR 2 contracts:
  * packed single-key sort == lexsort ordering, exactly
  * one expansion + one sort per fresh spgemm() (trace-count fixture)
  * same-bucket structures share compiled executables (zero new traces)
  * Reuse through the cache matches the kernels/ref.py dense reference,
    including cancellation to explicit zeros
  * LRU bound + eviction accounting
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    PlanCache,
    default_plan_cache,
    numeric_reuse,
    plan_nbytes,
    reset_trace_counts,
    round_capacity,
    spgemm,
    structure_key,
)
from repro.core.spgemm import TRACE_COUNTS, _single_sort_order
from repro.kernels import ref
from repro.sparse import CSR, dense_spgemm_oracle, random_csr
from repro.sparse.formats import csr_to_ell


def _with_values(mat: CSR, seed: int) -> CSR:
    """Same structure, fresh random values (the Reuse case's input)."""
    rng = np.random.default_rng(seed)
    vals = jnp.asarray(rng.standard_normal(mat.nnz_cap), jnp.float32)
    return CSR(mat.indptr, mat.indices, vals, mat.shape)


def test_round_capacity_policies():
    assert round_capacity(1, "exact8") == 8
    assert round_capacity(9, "exact8") == 16
    assert round_capacity(16, "exact8") == 16
    assert round_capacity(1, "pow2") == 8
    assert round_capacity(8, "pow2") == 8
    assert round_capacity(9, "pow2") == 16
    assert round_capacity(100, "pow2") == 128
    assert round_capacity(128, "pow2") == 128
    with pytest.raises(ValueError):
        round_capacity(4, "exact")


@pytest.mark.parametrize("m,k", [(16, 8), (37, 53), (1, 1)])
def test_packed_sort_matches_lexsort(m, k):
    rng = np.random.default_rng(m * 100 + k)
    n = 200
    rows = jnp.asarray(rng.integers(0, m + 1, n), jnp.int32)  # m = pad sentinel
    cols = jnp.asarray(rng.integers(0, k, n), jnp.int32)
    got = _single_sort_order(rows, cols, m, k)
    want = jnp.lexsort((cols, rows))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_packed_sort_fallback_wide_keyspace():
    """(m+1)*k overflows int32 -> the fused two-key lax.sort path; ordering
    must still match lexsort exactly."""
    m, k = 1 << 17, 1 << 17
    rng = np.random.default_rng(7)
    n = 500
    rows = jnp.asarray(rng.integers(0, m + 1, n), jnp.int32)
    cols = jnp.asarray(rng.integers(0, k, n), jnp.int32)
    got = _single_sort_order(rows, cols, m, k)
    want = jnp.lexsort((cols, rows))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_fresh_spgemm_single_expansion_single_sort():
    """Acceptance: a fresh spgemm() traces exactly one product expansion and
    one sort stage; a repeat with new values hits the cache with zero new
    traces (== zero recompiles)."""
    jax.clear_caches()
    reset_trace_counts()
    cache = PlanCache()
    a = random_csr(17, 19, 2.0, 3)
    b = random_csr(19, 23, 2.0, 4)
    res = spgemm(a, b, method="sparse", plan_cache=cache)
    assert res.stats["cache"] == "miss"
    assert TRACE_COUNTS["expand_products"] == 1
    assert TRACE_COUNTS["expand_and_sort"] == 1
    assert TRACE_COUNTS["_symbolic_sorted"] == 0  # no separate symbolic sort
    assert TRACE_COUNTS["plan_from_sorted"] == 1
    np.testing.assert_allclose(
        np.asarray(res.c.to_dense()), dense_spgemm_oracle(a, b),
        rtol=1e-4, atol=1e-4,
    )

    baseline = dict(TRACE_COUNTS)
    a2 = _with_values(a, 11)
    res2 = spgemm(a2, b, method="sparse", plan_cache=cache)
    assert res2.stats["cache"] == "hit"
    assert dict(TRACE_COUNTS) == baseline  # zero recompiles on the Reuse path
    np.testing.assert_allclose(
        np.asarray(res2.c.to_dense()), dense_spgemm_oracle(a2, b),
        rtol=1e-4, atol=1e-4,
    )


def test_same_bucket_shares_executable():
    """Two different structures whose sizes land in the same x2 capacity
    buckets must not trigger any new traces on the second call."""
    jax.clear_caches()
    reset_trace_counts()
    cache = PlanCache()
    a1, b1 = random_csr(64, 64, 5.0, 1), random_csr(64, 64, 5.0, 2)
    a2, b2 = random_csr(64, 64, 5.0, 5), random_csr(64, 64, 5.0, 6)
    r1 = spgemm(a1, b1, method="sparse", plan_cache=cache)
    # construction precondition: both multiplies sit in the same buckets
    r2 = spgemm(a2, b2, method="sparse", plan_cache=cache)
    assert r2.stats["cache"] == "miss"  # different structure ...
    assert r2.stats["fm_cap"] == r1.stats["fm_cap"]
    assert r2.stats["nnz_cap"] == r1.stats["nnz_cap"]
    np.testing.assert_allclose(
        np.asarray(r2.c.to_dense()), dense_spgemm_oracle(a2, b2),
        rtol=1e-4, atol=1e-4,
    )
    # ... yet zero new traces: the bucketed executables are shared.
    baseline = dict(TRACE_COUNTS)
    a3, b3 = random_csr(64, 64, 5.0, 8), random_csr(64, 64, 5.0, 9)
    r3 = spgemm(a3, b3, method="sparse", plan_cache=cache)
    assert r3.stats["fm_cap"] == r1.stats["fm_cap"]
    assert dict(TRACE_COUNTS) == baseline


def test_cache_reuse_matches_kernel_ref_after_value_mutation():
    """Reuse path through the plan cache vs kernels/ref.py dense-accumulator
    reference, with mutated values."""
    cache = PlanCache()
    a = random_csr(30, 40, 3.0, 7)
    b = random_csr(40, 35, 2.0, 8)
    r1 = spgemm(a, b, method="sparse", plan_cache=cache)
    assert r1.stats["cache"] == "miss"
    a2, b2 = _with_values(a, 21), _with_values(b, 22)
    r2 = spgemm(a2, b2, method="sparse", plan_cache=cache)
    assert r2.stats["cache"] == "hit"

    ea, eb = csr_to_ell(a2), csr_to_ell(b2)
    r_pad = max(int(jnp.max(r2.c.row_nnz())), 1)
    ec = csr_to_ell(r2.c, r_pad=r_pad)
    want = ref.spgemm_numeric_ref(
        ea.indices, ea.values, eb.indices, eb.values, ec.indices, ec.row_nnz,
        b.k,
    )
    np.testing.assert_allclose(
        np.asarray(ec.values), np.asarray(want), rtol=1e-4, atol=1e-5,
    )


def test_cache_reuse_keeps_explicit_zeros_on_cancellation():
    """Cancellation through the cached plan must keep the symbolic slot as an
    explicit zero (occupancy, not value != 0 — the paper's accumulators)."""
    cache = PlanCache()
    a = CSR.from_dense(np.array([[1.0, 1.0]], np.float32))
    b1 = CSR.from_dense(np.array([[1.0], [1.0]], np.float32))
    r1 = spgemm(a, b1, method="sparse", plan_cache=cache)
    assert r1.stats["cache"] == "miss"
    assert int(r1.c.nnz()) == 1 and float(r1.c.values[0]) == pytest.approx(2.0)
    b2 = CSR(b1.indptr, b1.indices, jnp.asarray([1.0, -1.0], jnp.float32),
             b1.shape)
    r2 = spgemm(a, b2, method="sparse", plan_cache=cache)
    assert r2.stats["cache"] == "hit"
    assert int(r2.c.nnz()) == 1  # structurally present
    assert abs(float(r2.c.values[0])) < 1e-6  # numerically zero


def test_lru_eviction_bound():
    cache = PlanCache(capacity=2)
    mats = [
        (random_csr(12, 12, 2.0, s), random_csr(12, 12, 2.0, s + 50))
        for s in (1, 2, 3)
    ]
    for a, b in mats:
        assert spgemm(a, b, method="sparse", plan_cache=cache).stats["cache"] == "miss"
    assert len(cache) == 2
    assert cache.evictions == 1
    from repro.core.plan_cache import EVICT_COUNTS

    assert EVICT_COUNTS[cache.name] == 1  # telemetry mirrors the instance
    # oldest (mats[0]) was evicted; newest (mats[2]) still resident
    a0, b0 = mats[0]
    assert spgemm(a0, b0, method="sparse", plan_cache=cache).stats["cache"] == "miss"
    a2, b2 = mats[2]
    assert spgemm(a2, b2, method="sparse", plan_cache=cache).stats["cache"] == "hit"


def test_bytes_bound_eviction():
    """max_bytes evicts LRU entries once cached plans exceed the budget —
    the accounting bound for executors pinning plans outside the cache."""
    a = random_csr(24, 24, 3.0, 7)
    b = random_csr(24, 24, 3.0, 8)
    probe = spgemm(a, b, method="sparse", plan_cache=PlanCache()).plan
    one = plan_nbytes(probe)
    assert one > 0
    # room for ~2 same-sized plans, generous entry capacity
    cache = PlanCache(capacity=16, max_bytes=int(one * 2.5))
    mats = [
        (random_csr(24, 24, 3.0, s), random_csr(24, 24, 3.0, s + 90))
        for s in (1, 2, 3)
    ]
    for a_i, b_i in mats:
        spgemm(a_i, b_i, method="sparse", plan_cache=cache)
    assert cache.evictions >= 1
    from repro.core.plan_cache import EVICT_COUNTS

    assert EVICT_COUNTS[cache.name] == cache.evictions
    assert cache.total_bytes <= cache.max_bytes
    assert cache.total_bytes == sum(cache._nbytes.values())
    # newest structure stayed resident
    a2, b2 = mats[2]
    assert spgemm(a2, b2, method="sparse", plan_cache=cache).stats["cache"] == "hit"
    st = cache.stats()
    assert st["bytes"] == cache.total_bytes and st["max_bytes"] == cache.max_bytes


def test_bytes_bound_keeps_newest_oversized_entry():
    """A single plan bigger than max_bytes is still stored (refusing it
    would silently disable reuse); everything older is evicted."""
    a = random_csr(30, 30, 3.0, 17)
    b = random_csr(30, 30, 3.0, 18)
    cache = PlanCache(capacity=8, max_bytes=1)
    res = spgemm(a, b, method="sparse", plan_cache=cache)
    assert len(cache) == 1
    assert spgemm(a, b, method="sparse",
                  plan_cache=cache).stats["cache"] == "hit"
    assert cache.total_bytes == plan_nbytes(res.plan)


def test_bytes_accounting_on_overwrite_and_clear():
    cache = PlanCache(capacity=4, max_bytes=1 << 30)
    a = random_csr(20, 20, 2.0, 27)
    b = random_csr(20, 20, 2.0, 28)
    res = spgemm(a, b, method="sparse", plan_cache=cache)
    key = next(iter(cache._entries))  # the key spgemm stored under
    before = cache.total_bytes
    cache.put(key, res.plan)  # overwrite same key: no double counting
    assert cache.total_bytes == before
    cache.clear()
    assert cache.total_bytes == 0 and len(cache) == 0


def test_plan_cache_rejects_bad_bounds():
    with pytest.raises(ValueError):
        PlanCache(capacity=0)
    with pytest.raises(ValueError):
        PlanCache(max_bytes=0)


def test_default_cache_used_by_public_entry_point():
    """spgemm() with no cache argument reuses the module-level cache."""
    a = random_csr(21, 27, 2.0, 33)
    b = random_csr(27, 31, 2.0, 34)
    default_plan_cache().clear()
    r1 = spgemm(a, b, method="sparse")
    r2 = spgemm(_with_values(a, 1), b, method="sparse")
    assert r1.stats["cache"] == "miss"
    assert r2.stats["cache"] == "hit"
    assert default_plan_cache().stats()["hits"] >= 1
    # disabling the cache bypasses it entirely
    r3 = spgemm(a, b, method="sparse", plan_cache=False)
    assert r3.stats["cache"] == "bypass"


def test_structure_key_sensitivity():
    a = random_csr(10, 10, 2.0, 1)
    b = random_csr(10, 10, 2.0, 2)
    k0 = structure_key(a, b, 64, "pow2")
    assert structure_key(a, b, 64, "pow2") == k0  # deterministic
    assert structure_key(a, b, 128, "pow2") != k0  # fm bucket matters
    assert structure_key(a, b, 64, "exact8") != k0  # policy matters
    assert structure_key(b, a, 64, "pow2") != k0  # operand order matters
    a2 = _with_values(a, 9)
    assert structure_key(a2, b, 64, "pow2") == k0  # values don't matter


def test_plan_survives_for_manual_numeric_reuse():
    """The cached plan is the same object callers can drive by hand — the
    pre-cache API keeps working on top of the cache."""
    cache = PlanCache()
    a = random_csr(18, 22, 2.0, 41)
    b = random_csr(22, 16, 2.0, 42)
    res = spgemm(a, b, method="sparse", plan_cache=cache)
    a2 = _with_values(a, 5)
    vals = numeric_reuse(res.plan, a2.values, b.values)
    want = dense_spgemm_oracle(a2, b)
    c2 = CSR(res.c.indptr, res.c.indices, vals, res.c.shape)
    np.testing.assert_allclose(np.asarray(c2.to_dense()), want,
                               rtol=1e-4, atol=1e-4)
