"""Run selected rules over a tree and split findings by disposition."""
from __future__ import annotations

from pathlib import Path

from repro.analysis.context import Project
from repro.analysis.findings import Finding, Report, load_baseline
from repro.analysis.registry import RULES


def run_analysis(root: Path | str,
                 rules: list[str] | None = None,
                 baseline_path: Path | str | None = None) -> Report:
    """Scan ``root`` with ``rules`` (default: all registered).

    Every finding lands in exactly one bucket: ``new`` (fails the gate),
    ``suppressed`` (inline ``# repro: allow[...]``), or ``baselined``
    (fingerprint present in the committed baseline). Unparseable files are
    themselves findings — a tree the analyzer cannot read must not pass
    the analyzer's gate.
    """
    root = Path(root)
    selected = sorted(RULES) if rules is None else list(rules)
    unknown = [r for r in selected if r not in RULES]
    if unknown:
        raise KeyError(f"unknown analysis rule(s): {unknown}; "
                       f"known: {sorted(RULES)}")

    project = Project(root)
    baseline = (load_baseline(baseline_path)
                if baseline_path is not None else set())

    report = Report(root=str(root), rules=selected)
    for rel, err in project.parse_errors:
        report.new.append(Finding(
            rule="parse", code="parse.syntax-error", path=rel, line=1,
            message=f"file does not parse: {err}",
            hint="fix the syntax error", snippet=""))

    for rule_id in selected:
        for finding in RULES[rule_id].check(project):
            mod = project.module(finding.path)
            if mod is not None and mod.allowed(
                    finding.line, finding.rule, finding.code):
                report.suppressed.append(finding)
            elif finding.fingerprint in baseline:
                report.baselined.append(finding)
            else:
                report.new.append(finding)

    by_rule: dict[str, int] = {}
    for f in report.new + report.suppressed + report.baselined:
        by_rule[f.rule] = by_rule.get(f.rule, 0) + 1
    report.stats = {
        "modules": len(project.modules),
        "parse_errors": len(project.parse_errors),
        "findings_by_rule": by_rule,
    }
    return report
