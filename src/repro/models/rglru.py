"""RG-LRU recurrent block (recurrentgemma / Griffin, arXiv:2402.19427).

h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
a_t = exp(c * log(sigmoid(L)) * r_t),  r/i = input-dependent gates.

Training uses an associative scan over T (log-depth); decode is the O(1)
per-token update that makes the long_500k cell tractable for this arch.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import rms_norm
from repro.models.sharding import ShardingRules

LRU_C = 8.0  # Griffin's fixed exponent scale


class RGLRUCache(NamedTuple):
    state: jax.Array  # (B, W) f32
    conv: jax.Array  # (B, conv_w - 1, W)


def rglru_params_template(cfg: ModelConfig):
    """Gates are block-diagonal over heads (as in the DeepMind Griffin
    implementation) — (H, W/H, W/H) blocks keep the recurrence width fully
    head-sharded: no cross-shard mixing inside the RG-LRU."""
    d = cfg.d_model
    w = cfg.lru_width or d
    nh = cfg.num_heads
    bw = w // nh
    return {
        "proj_x": ((d, w), "ffn_in"),
        "proj_gate": ((d, w), "ffn_in"),
        "conv_w": ((cfg.conv_width, w), "conv_ch"),
        "conv_b": ((w,), "conv_ch1"),
        "gate_a_w": ((nh, bw, bw), "gate_block"),
        "gate_a_b": ((w,), "conv_ch1"),
        "gate_i_w": ((nh, bw, bw), "gate_block"),
        "gate_i_b": ((w,), "conv_ch1"),
        "lam": ((w,), "conv_ch1"),
        "proj_out": ((w, d), "ffn_out"),
        "norm": ((d,), "norm"),
    }


def _causal_conv(x, w, b):
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i : i + x.shape[1]] * w[i][None, None, :] for i in range(k))
    return out + b[None, None, :]


def _gates(p, xs):
    """r, i gates in f32 via block-diagonal (per-head) weights.

    xs: (B, T, W) -> reshaped (B, T, H, W/H)."""
    nh, bw, _ = p["gate_a_w"].shape
    b, t, w = xs.shape
    xf = xs.astype(jnp.float32).reshape(b, t, nh, bw)
    r = jax.nn.sigmoid(
        jnp.einsum("bthw,hwv->bthv", xf, p["gate_a_w"].astype(jnp.float32))
        .reshape(b, t, w) + p["gate_a_b"].astype(jnp.float32)
    )
    i = jax.nn.sigmoid(
        jnp.einsum("bthw,hwv->bthv", xf, p["gate_i_w"].astype(jnp.float32))
        .reshape(b, t, w) + p["gate_i_b"].astype(jnp.float32)
    )
    log_a0 = -jax.nn.softplus(-p["lam"].astype(jnp.float32))  # log sigmoid(L)
    log_a = LRU_C * log_a0[None, None, :] * r  # (B, T, W)
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    return a, mult * i * xs.astype(jnp.float32)


def rglru_layer(p, x, cfg: ModelConfig, rules: ShardingRules, *,
                cache: RGLRUCache | None = None, return_cache: bool = False):
    """Pre-norm recurrent block. x: (B, T, d). Returns (delta, cache|None)."""
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    xs = h @ p["proj_x"].astype(h.dtype)  # (B, T, W)
    gate = h @ p["proj_gate"].astype(h.dtype)
    if rules.enabled and rules.tp_axis and cache is None:
        from jax.sharding import PartitionSpec as P

        w = xs.shape[-1]
        tp_w = rules._tp_if(w)
        xs = rules.constraint(xs, P(rules.dp, None, tp_w))
        gate = rules.constraint(gate, P(rules.dp, None, tp_w))

    new_cache = None
    if cache is None:
        xs_c = _causal_conv(xs, p["conv_w"].astype(xs.dtype),
                            p["conv_b"].astype(xs.dtype))
        a, b_term = _gates(p, xs_c)

        def combine(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, a2 * b1 + b2

        _, hseq = jax.lax.associative_scan(combine, (a, b_term), axis=1)
        y = hseq
        if return_cache:
            new_cache = RGLRUCache(
                state=hseq[:, -1], conv=xs[:, -(p["conv_w"].shape[0] - 1):]
            )
    else:
        window = jnp.concatenate([cache.conv, xs], axis=1)
        xs_c = (
            jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                       p["conv_w"].astype(jnp.float32))
            + p["conv_b"].astype(jnp.float32)
        )[:, None, :].astype(xs.dtype)
        a, b_term = _gates(p, xs_c)  # (B, 1, W)
        s = cache.state * a[:, 0] + b_term[:, 0]
        y = s[:, None, :]
        new_cache = RGLRUCache(state=s, conv=window[:, 1:])

    y = y.astype(x.dtype) * jax.nn.gelu(gate)
    delta = y @ p["proj_out"].astype(y.dtype)
    return delta, new_cache
