"""phi-3-vision-4.2b [vlm] — hf:microsoft/Phi-3-vision-128k-instruct.

Backbone only (per spec): 32L, d_model=3072, 32 heads (kv=32 == MHA),
d_ff=8192, vocab=32064. Vision frontend is a STUB: input_specs() supplies
precomputed CLIP patch embeddings (num_patches x 1024) projected into the
token stream. SpGEMM applicability: none.
long_500k: skipped (pure full-attention backbone).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32_064,
    head_dim=96,
    rope_theta=10_000.0,
    frontend="vision",
    frontend_dim=1024,
    num_patches=576,
)

SMOKE = ModelConfig(
    name="phi-3-vision-4.2b-smoke",
    family="vlm",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    head_dim=16,
    frontend="vision",
    frontend_dim=32,
    num_patches=16,
)

SKIP_SHAPES = {"long_500k": "pure full-attention arch (per-spec skip)"}
