"""qwen3-moe-30b-a3b [moe] — hf:Qwen/Qwen3-30B-A3B.

48L, d_model=2048, 32 heads (GQA kv=4), per-expert d_ff=768, vocab=151936,
MoE 128 experts top-8, QK-norm.

SpGEMM applicability: YES (dispatch = two-phase SpGEMM; DESIGN.md §4).
long_500k: skipped (full attention).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=151_936,
    pattern=("moe",),
    head_dim=128,
    qk_norm=True,
    rope_theta=1_000_000.0,
    num_experts=128,
    experts_per_token=8,
    moe_d_ff=768,
)

SMOKE = ModelConfig(
    name="qwen3-moe-30b-a3b-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=0,
    vocab_size=256,
    pattern=("moe",),
    head_dim=16,
    qk_norm=True,
    num_experts=8,
    experts_per_token=2,
    moe_d_ff=32,
)

SKIP_SHAPES = {"long_500k": "pure full-attention arch (per-spec skip)"}
