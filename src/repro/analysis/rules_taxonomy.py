"""Rule ``taxonomy`` — errors go through the typed taxonomy, loudly.

``runtime/validate.py`` owns the error taxonomy (PR 7): every failure mode
has a typed class that still subclasses its builtin ancestor, so callers
can catch precisely while legacy ``except ValueError`` keeps working.

Sub-checks:

  * ``taxonomy.bare-raise`` — ``raise ValueError(...)`` or
    ``raise RuntimeError(...)`` outside ``runtime/validate.py``. Use (or
    add) a taxonomy class; they subclass the builtin, so no caller breaks.
  * ``taxonomy.broad-except`` — an ``except Exception``/bare ``except``
    handler that swallows: no re-raise, no typed-error construction, no
    telemetry record. Silent failure is the one thing the hardened
    execution story forbids.
"""
from __future__ import annotations

import ast

from repro.analysis.asthelpers import dotted
from repro.analysis.context import TAXONOMY_MODULE, Project
from repro.analysis.findings import Finding
from repro.analysis.registry import rule
from repro.analysis.rules_jit import _broad, _handler_is_loud

RULE = "taxonomy"

BARE = {"ValueError", "RuntimeError"}


@rule(RULE, "no bare ValueError/RuntimeError; no silent broad excepts")
def check(project: Project):
    taxonomy = project.taxonomy_classes()
    for mod in project.modules:
        exempt = mod.rel == TAXONOMY_MODULE
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Raise) and not exempt:
                exc = node.exc
                target = exc.func if isinstance(exc, ast.Call) else exc
                name = dotted(target) if target is not None else ""
                if name in BARE:
                    yield Finding(
                        rule=RULE, code=f"{RULE}.bare-raise",
                        path=mod.rel, line=node.lineno,
                        message=(f"bare raise {name} — use the typed "
                                 f"taxonomy in runtime/validate.py"),
                        hint=("raise SpgemmConfigError / SpgemmInputError / "
                              "PlanMismatchError / ... (they subclass "
                              f"{name}, so no caller breaks)"),
                        snippet=mod.snippet(node.lineno))
            if isinstance(node, ast.Try):
                for handler in node.handlers:
                    if _broad(handler) and not _handler_is_loud(handler, taxonomy):
                        yield Finding(
                            rule=RULE, code=f"{RULE}.broad-except",
                            path=mod.rel, line=handler.lineno,
                            message=("broad except that swallows: no "
                                     "re-raise, no typed error, no "
                                     "telemetry record"),
                            hint=("re-raise typed, bump a counter, or "
                                  "annotate # repro: allow[taxonomy] with "
                                  "a why"),
                            snippet=mod.snippet(handler.lineno))
