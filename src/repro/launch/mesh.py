"""Production mesh construction (spec'd shapes) + sharding-rule factory.

make_production_mesh is a FUNCTION so importing this module never touches
jax device state; the dry-run sets the 512-placeholder-device XLA flag
before any jax initialization (launch/dryrun.py lines 1-2).
"""
from __future__ import annotations

import jax

from repro.compat import make_mesh
from repro.models.sharding import ShardingRules


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_test_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for the 8-device subprocess tests."""
    return make_mesh(shape, axes)


def make_data_mesh(num_devices: int | None = None, axis: str = "data"):
    """1-D mesh over ``num_devices`` (default: all) for sharded SpGEMM —
    the decomposition ``repro.dist`` and ``spgemm(..., mesh=...)`` expect."""
    n = len(jax.devices()) if num_devices is None else num_devices
    return make_mesh((n,), (axis,))


def rules_for_mesh(mesh) -> ShardingRules:
    names = mesh.axis_names
    if "model" in names:
        tp_axis = "model"
        tp_size = mesh.shape["model"]
    else:
        tp_axis, tp_size = None, 1
    dp_axes = tuple(n for n in names if n in ("pod", "data"))
    dp_total = 1
    for n in dp_axes:
        dp_total *= mesh.shape[n]
    return ShardingRules(
        dp_axes=dp_axes or ("data",),
        tp_axis=tp_axis,
        tp_size=tp_size,
        dp_size=dp_total,
        enabled=True,
    )


def dp_size(mesh) -> int:
    out = 1
    for n in mesh.axis_names:
        if n in ("pod", "data"):
            out *= mesh.shape[n]
    return out
