"""mamba2-2.7b [ssm] — arXiv:2405.21060 (SSD / state-space duality).

64L, d_model=2560, attention-free, vocab=50280, ssm_state=128,
expand=2 (d_inner=5120), head_dim=64 (80 SSD heads).

SpGEMM applicability: none (dense scans). long_500k: RUN — SSM decode is
O(1)-state per token (the arch this shape exists for).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=1,  # unused for ssm layers
    num_kv_heads=1,
    d_ff=0,
    vocab_size=50_280,
    pattern=("ssm",),
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    conv_width=4,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="mamba2-2.7b-smoke",
    family="ssm",
    num_layers=2,
    d_model=64,
    num_heads=1,
    num_kv_heads=1,
    d_ff=0,
    vocab_size=256,
    pattern=("ssm",),
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=16,
    ssm_chunk=32,
    conv_width=4,
    tie_embeddings=True,
)

SKIP_SHAPES = {}
