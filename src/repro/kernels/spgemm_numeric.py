"""Pallas TPU kernel: SpGEMM numeric phase with a dense VMEM accumulator.

This is KKDENSE's numeric phase adapted to the MXU (DESIGN.md §2.1): the
per-row dense accumulator is a (1, k_pad) f32 VMEM tile; scatter of a B-row's
products is a one-hot matmul (vals @ onehot(cols)) and the final gather at
C's symbolic structure is the transposed one-hot matmul — both MXU ops,
replacing GPU per-lane atomics with associative matrix products.

Partitioning: Thread-Sequential (grid (m, rA)) — one C row per outer grid
step; lane parallelism covers B-row nonzeros; the B-row gather is steered by
the scalar-prefetched A structure via the BlockSpec index_map.

Two-phase contract: the kernel takes C's structure (from the symbolic
kernel) and writes values in ELL layout — reuse re-invokes only this kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# one-hot scatter tile width along the dense-accumulator (column) axis
K_TILE = 512


def _kernel(a_idx_ref, a_nnz_ref, c_nnz_ref,  # scalar prefetch
            a_val_ref, b_idx_ref, b_val_ref, c_idx_ref,  # VMEM inputs
            out_ref,  # VMEM output (1, rC)
            acc_ref):  # VMEM scratch (1, k_pad) f32
    i = pl.program_id(0)
    r = pl.program_id(1)
    n_r = pl.num_programs(1)
    k_pad = acc_ref.shape[1]
    r_b = b_idx_ref.shape[1]
    r_c = out_ref.shape[1]

    @pl.when(r == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    live = r < a_nnz_ref[i]
    a_val = jnp.where(live, a_val_ref[0, r], 0.0)
    cols = b_idx_ref[0, :]  # (rB,)
    scaled = (a_val * b_val_ref[0, :].astype(jnp.float32))[None, :]  # (1, rB)

    def scatter_tile(t, _):
        base = t * K_TILE
        # one-hot (rB, K_TILE) on the MXU: scatter == matmul
        onehot = (
            cols[:, None] == base + jax.lax.iota(jnp.int32, K_TILE)[None, :]
        ).astype(jnp.float32)
        tile = jnp.dot(scaled, onehot, preferred_element_type=jnp.float32)
        cur = pl.load(acc_ref, (slice(None), pl.dslice(base, K_TILE)))
        pl.store(acc_ref, (slice(None), pl.dslice(base, K_TILE)), cur + tile)
        return 0

    jax.lax.fori_loop(0, k_pad // K_TILE, scatter_tile, 0)

    @pl.when(r == n_r - 1)
    def _emit():
        c_cols = c_idx_ref[0, :]  # (rC,)

        def gather_tile(t, out):
            base = t * K_TILE
            onehot = (
                base + jax.lax.iota(jnp.int32, K_TILE)[:, None] == c_cols[None, :]
            ).astype(jnp.float32)  # (K_TILE, rC)
            seg = pl.load(acc_ref, (slice(None), pl.dslice(base, K_TILE)))
            return out + jnp.dot(seg, onehot, preferred_element_type=jnp.float32)

        vals = jax.lax.fori_loop(
            0, k_pad // K_TILE, gather_tile, jnp.zeros((1, r_c), jnp.float32)
        )
        mask = jax.lax.iota(jnp.int32, r_c)[None, :] < c_nnz_ref[i]
        out_ref[...] = jnp.where(mask, vals, 0.0).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def spgemm_numeric(a_idx, a_val, a_nnz, b_idx, b_val, c_idx, c_nnz, *,
                   k: int, interpret: bool = False) -> jax.Array:
    """Numeric phase: C values (ELL layout, (m, rC)) at the given structure.

    a_idx/a_val: (m, rA) ELL of A; a_nnz: (m,); b_idx/b_val: (n, rB) ELL of B
    (padded B slots must carry value 0); c_idx: (m, rC) symbolic structure of
    C; c_nnz: (m,); k: number of columns of B (static).
    """
    m, r_a = a_idx.shape
    n, r_b = b_idx.shape
    r_c = c_idx.shape[1]
    k_pad = -(-k // K_TILE) * K_TILE

    grid = (m, r_a)
    out = pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, r_a), lambda i, r, ai, an, cn: (i, 0)),
                pl.BlockSpec((1, r_b), lambda i, r, ai, an, cn: (ai[i, r], 0)),
                pl.BlockSpec((1, r_b), lambda i, r, ai, an, cn: (ai[i, r], 0)),
                pl.BlockSpec((1, r_c), lambda i, r, ai, an, cn: (i, 0)),
            ],
            out_specs=pl.BlockSpec((1, r_c), lambda i, r, ai, an, cn: (i, 0)),
            scratch_shapes=[pltpu.VMEM((1, k_pad), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((m, r_c), a_val.dtype),
        interpret=interpret,
    )(a_idx, a_nnz, c_nnz, a_val, b_idx, b_val, c_idx)
    return out


def _pad_width(x: jax.Array, width: int) -> jax.Array:
    cur = x.shape[1]
    return x if cur == width else jnp.pad(x, ((0, 0), (0, width - cur)))


def spgemm_numeric_bucketed(a_idx, a_val, a_nnz, b_idx, b_val, c_idx, c_nnz, *,
                            k: int, pad_policy: str | None = None,
                            interpret: bool = False) -> jax.Array:
    """``spgemm_numeric`` with ELL widths rA/rB/rC padded to capacity buckets.

    Same bucketing contract as the host driver (core.meta.round_capacity):
    each width rounds up to its x2 band so similarly-shaped problems share
    one compiled kernel. Zero-padding preserves semantics — padded A slots
    are masked by ``a_nnz``, padded B slots carry value 0 (the kernel's
    contract), padded C slots are masked by ``c_nnz`` — and the output is
    sliced back to the caller's rC.
    """
    from repro.core.meta import DEFAULT_PAD_POLICY, round_capacity

    policy = DEFAULT_PAD_POLICY if pad_policy is None else pad_policy
    r_c = c_idx.shape[1]
    a_idx = _pad_width(a_idx, round_capacity(a_idx.shape[1], policy))
    a_val = _pad_width(a_val, a_idx.shape[1])
    b_idx = _pad_width(b_idx, round_capacity(b_idx.shape[1], policy))
    b_val = _pad_width(b_val, b_idx.shape[1])
    c_idx_p = _pad_width(c_idx, round_capacity(r_c, policy))
    out = spgemm_numeric(a_idx, a_val, a_nnz, b_idx, b_val, c_idx_p, c_nnz,
                         k=k, interpret=interpret)
    return out[:, :r_c]
