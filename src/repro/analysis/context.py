"""Parsed-project model: module ASTs, allow-comments, static registries.

The analyzer never *imports* the code under scan — everything is read from
the AST. That keeps the pass runnable on broken trees (CI should report the
contract violation, not an ImportError) and makes fixture trees in tests
lint exactly like the real package: a tiny directory with its own
``core/telemetry.py`` / ``obs/trace.py`` / ``runtime/validate.py`` gets its
own registries.

Registry sources (all under the scan root):

  * ``obs/trace.py``        → ``SPAN_NAMES`` (the span taxonomy)
  * ``core/telemetry.py``   → ``KEY_FAMILIES`` (counter-key grammars) and
                              ``ALL_COUNTERS`` (registered counter names)
  * ``runtime/validate.py`` → the typed error taxonomy (class defs)

Suppression: ``# repro: allow[rule-a,rule-b] why`` on the flagged line or
the line directly above it. The rule list matches rule ids ("taxonomy") or
full sub-check codes ("taxonomy.broad-except"); ``allow[*]`` matches every
rule. Suppressions are reported (never silent) — they are the in-code
version of the baseline, for findings that are *intentional*, with the why
next to the code instead of in a JSON file.
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from pathlib import Path

ALLOW_RE = re.compile(r"#\s*repro:\s*allow\[([A-Za-z0-9_.\-*,\s]+)\]")

# Registry file locations, relative to the scan root.
TRACE_MODULE = "obs/trace.py"
TELEMETRY_MODULE = "core/telemetry.py"
TAXONOMY_MODULE = "runtime/validate.py"


@dataclass
class ModuleInfo:
    """One parsed source file."""

    path: Path  # absolute
    rel: str  # posix, relative to scan root
    source: str
    tree: ast.Module
    lines: list[str] = field(default_factory=list)
    # line number -> set of allowed rule ids/codes ("*" allows all)
    allow: dict[int, set[str]] = field(default_factory=dict)

    def snippet(self, line: int) -> str:
        if 1 <= line <= len(self.lines):
            return self.lines[line - 1].strip()
        return ""

    def allowed(self, line: int, rule_id: str, code: str) -> bool:
        """Does an allow-comment on this line (or the one above) cover us?"""
        for ln in (line, line - 1):
            ids = self.allow.get(ln)
            if ids and ("*" in ids or rule_id in ids or code in ids):
                return True
        return False


def _parse_allows(lines: list[str]) -> dict[int, set[str]]:
    out: dict[int, set[str]] = {}
    for i, text in enumerate(lines, start=1):
        m = ALLOW_RE.search(text)
        if m:
            ids = {part.strip() for part in m.group(1).split(",") if part.strip()}
            out[i] = ids
    return out


class Project:
    """The tree under scan + lazily extracted registries."""

    def __init__(self, root: Path):
        self.root = Path(root)
        self.modules: list[ModuleInfo] = []
        self.parse_errors: list[tuple[str, str]] = []
        for path in sorted(self.root.rglob("*.py")):
            rel = path.relative_to(self.root).as_posix()
            if rel.startswith("analysis/"):
                continue  # the linter does not lint itself (fixtures do)
            source = path.read_text()
            try:
                tree = ast.parse(source)
            except SyntaxError as e:  # surfaced as a finding by the runner
                self.parse_errors.append((rel, str(e)))
                continue
            lines = source.splitlines()
            self.modules.append(ModuleInfo(
                path=path, rel=rel, source=source, tree=tree, lines=lines,
                allow=_parse_allows(lines)))
        self._cache: dict[str, object] = {}

    # ------------------------------------------------------------------
    # registry extraction (AST-level, never imports the scanned code)
    # ------------------------------------------------------------------

    def module(self, rel: str) -> ModuleInfo | None:
        for m in self.modules:
            if m.rel == rel:
                return m
        return None

    def _module_assign(self, rel: str, name: str) -> ast.expr | None:
        mod = self.module(rel)
        if mod is None:
            return None
        for node in mod.tree.body:
            targets = []
            if isinstance(node, ast.Assign):
                targets = node.targets
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                targets = [node.target]
            for t in targets:
                if isinstance(t, ast.Name) and t.id == name:
                    return node.value
        return None

    def span_names(self) -> frozenset[str] | None:
        """``SPAN_NAMES`` from obs/trace.py, or None when absent."""
        if "span_names" not in self._cache:
            value = self._module_assign(TRACE_MODULE, "SPAN_NAMES")
            # unwrap frozenset({...}) / set({...}) wrappers around the literal
            if isinstance(value, ast.Call) and len(value.args) == 1 \
                    and ast.unparse(value.func).rsplit(".", 1)[-1] in (
                        "frozenset", "set"):
                value = value.args[0]
            names = None
            if value is not None:
                try:
                    names = frozenset(ast.literal_eval(value))
                except (ValueError, TypeError):
                    names = None
            self._cache["span_names"] = names
        return self._cache["span_names"]  # type: ignore[return-value]

    def key_families(self) -> dict[str, tuple[str, ...]] | None:
        """``KEY_FAMILIES`` grammar templates from core/telemetry.py."""
        if "key_families" not in self._cache:
            value = self._module_assign(TELEMETRY_MODULE, "KEY_FAMILIES")
            fams = None
            if value is not None:
                try:
                    raw = ast.literal_eval(value)
                    fams = {str(k): tuple(str(t) for t in v)
                            for k, v in raw.items()}
                except (ValueError, TypeError, AttributeError):
                    fams = None
            self._cache["key_families"] = fams
        return self._cache["key_families"]  # type: ignore[return-value]

    def registered_counters(self) -> frozenset[str] | None:
        """Counter variable names registered in telemetry.ALL_COUNTERS."""
        if "registered" not in self._cache:
            value = self._module_assign(TELEMETRY_MODULE, "ALL_COUNTERS")
            names = None
            if isinstance(value, ast.Dict):
                names = frozenset(
                    v.id for v in value.values if isinstance(v, ast.Name))
            self._cache["registered"] = names
        return self._cache["registered"]  # type: ignore[return-value]

    def reset_registered(self) -> frozenset[str] | None:
        """Reset-function names wired into telemetry._RESETS."""
        if "resets" not in self._cache:
            value = self._module_assign(TELEMETRY_MODULE, "_RESETS")
            names = None
            if isinstance(value, (ast.Tuple, ast.List)):
                names = frozenset(
                    e.id for e in value.elts if isinstance(e, ast.Name))
            self._cache["resets"] = names
        return self._cache["resets"]  # type: ignore[return-value]

    def taxonomy_classes(self) -> frozenset[str]:
        """Typed-error class names defined in runtime/validate.py (plus the
        retry taxonomy member defined next to its mechanism)."""
        if "taxonomy" not in self._cache:
            names = set()
            mod = self.module(TAXONOMY_MODULE)
            if mod is not None:
                for node in mod.tree.body:
                    if isinstance(node, ast.ClassDef):
                        names.add(node.name)
            # RetryExhaustedError lives in runtime/retry.py by design
            retry = self.module("runtime/retry.py")
            if retry is not None:
                for node in retry.tree.body:
                    if isinstance(node, ast.ClassDef):
                        names.add(node.name)
            self._cache["taxonomy"] = frozenset(names)
        return self._cache["taxonomy"]  # type: ignore[return-value]


def default_root() -> Path:
    """The installed ``repro`` package directory (what CI scans)."""
    return Path(__file__).resolve().parents[1]
