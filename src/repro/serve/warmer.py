"""Traffic-log driven plan-cache warming for the serving tier.

A reuse-oriented serving tier lives or dies on steady-state cache behavior:
the first request of every structure pays the full expand+sort plan build,
so a cold cache turns the head of a traffic burst into a latency cliff. The
warmer moves that cost off the serving path: record the structures a
service actually saw (``TrafficLog``), then replay the log's hottest
structures through ``resolve_plan`` into a plan cache *before* traffic
arrives (``warm_plan_cache``).

Warming is best-effort by design and must tolerate eviction mid-stream:

  * a log bigger than the cache simply churns the LRU — the warmer keeps
    going, and the eviction churn is visible in ``telemetry.EVICT_COUNTS``
    (the returned stats carry the delta, so callers can detect a warm set
    that does not fit instead of wondering why replays are cold);
  * an exemplar whose plan build fails (corrupt structure recorded from a
    hostile trace) is skipped and counted, never fatal;
  * warming an already-resident structure is a cheap cache hit.

The log stores one structure *exemplar* per structure key (operands are
kept with their prepared/bucketed buffers so the warm-time plan is
byte-identical to the serve-time plan) plus a hit count; values ride along
but are irrelevant to the plan. ``TrafficLog.record`` hashes the structure
(one ``structure_key`` per call — the same unavoidable minimum as the
grouped dispatch); the serving tier's internal recording reuses the key it
already computed at admission, adding zero extra hashes.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import NamedTuple

from repro.core.meta import DEFAULT_PAD_POLICY
from repro.core.plan_cache import EVICT_COUNTS, structure_key
from repro.core.spgemm import prepare_sparse_inputs, resolve_plan
from repro.runtime.validate import SpgemmError


class TrafficEntry(NamedTuple):
    """One distinct structure observed in traffic."""

    skey: str  # structure_key of the prepared operands
    a: object  # prepared (bucketed) CSR exemplars
    b: object
    fm_cap: int
    count: int  # how many requests carried this structure


class TrafficLog:
    """Structure-frequency log of a request stream.

    ``record(a, b)`` prepares/buckets the operands exactly like the serving
    path (so the recorded key matches what dispatch will look up) and keeps
    the first-seen exemplar per structure with a running count.
    """

    def __init__(self, pad_policy: str | None = None):
        self.pad_policy = DEFAULT_PAD_POLICY if pad_policy is None else pad_policy
        self._entries: OrderedDict[str, TrafficEntry] = OrderedDict()

    def __len__(self) -> int:
        return len(self._entries)

    def record(self, a, b) -> str:
        """Log one request's structure; returns its structure key."""
        a, b, _, _, fm_cap = prepare_sparse_inputs(a, b, self.pad_policy)
        skey = structure_key(a, b, fm_cap, self.pad_policy)
        return self.record_prepared(skey, a, b, fm_cap)

    def record_prepared(self, skey: str, a, b, fm_cap: int) -> str:
        """Log a structure the caller already prepared and hashed (the
        serving tier's admission path — no second digest)."""
        hit = self._entries.get(skey)
        if hit is None:
            self._entries[skey] = TrafficEntry(skey, a, b, fm_cap, 1)
        else:
            self._entries[skey] = hit._replace(count=hit.count + 1)
        return skey

    def top(self, n: int | None = None) -> list[TrafficEntry]:
        """Entries by descending traffic count (ties: first-seen first)."""
        ranked = sorted(self._entries.values(),
                        key=lambda e: -e.count)
        return ranked if n is None else ranked[:n]


def warm_plan_cache(log: TrafficLog, cache, limit: int | None = None) -> dict:
    """Prefetch plans for the log's hottest structures into ``cache``.

    Returns warm stats: ``built`` (plans constructed), ``hits`` (already
    resident), ``failed`` (exemplars whose plan build raised a typed error
    — skipped, warming continues), and ``evictions`` (LRU churn during the
    warm, from ``EVICT_COUNTS[cache.name]`` — nonzero means the warm set
    exceeds the cache bound and the tail of the warm evicted its head).
    """
    evict0 = EVICT_COUNTS[cache.name]
    built = hits = failed = 0
    for entry in log.top(limit):
        try:
            _, state, _ = resolve_plan(entry.a, entry.b, entry.fm_cap,
                                       log.pad_policy, cache, key=entry.skey)
        except SpgemmError:
            failed += 1
            continue
        if state == "hit":
            hits += 1
        else:
            built += 1
    return {
        "built": built,
        "hits": hits,
        "failed": failed,
        "evictions": EVICT_COUNTS[cache.name] - evict0,
    }
