"""Chaos suite: every registered fault must either raise its typed error
(validation on) or degrade to a bitwise-correct XLA-reference result with
FALLBACK_COUNTS evidence (validation off). No fault may produce silent
wrong values — that is the acceptance bar of the failure model (ROADMAP
"The failure model")."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import telemetry
from repro.core.executor import ReuseExecutor
from repro.core.plan_cache import PlanCache
from repro.core.spgemm import numeric_reuse, spgemm
from repro.kernels.ops import numeric_values
from repro.runtime import faults
from repro.runtime.validate import (CapacityOverflowError, KernelFallbackError,
                                    PlanMismatchError, SpgemmInputError,
                                    check_csr)
from repro.sparse import csr_to_ell, random_csr


@pytest.fixture
def ab():
    return random_csr(32, 24, 4.0, seed=1), random_csr(24, 40, 4.0, seed=2)


# --------------------------------------------------------------------------
# Data faults: validation ON -> the registered typed error, both modes
# --------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["host", "device"])
@pytest.mark.parametrize("name", [s.name for s in faults.data_faults()])
def test_data_fault_raises_typed_error(ab, name, mode):
    a, _ = ab
    bad = faults.inject_csr(name, a)
    spec = faults.FAULTS[name]
    with pytest.raises(spec.expects):
        check_csr(bad, mode, name="A")


@pytest.mark.parametrize("name", ["corrupt_indptr", "capacity_overflow"])
def test_data_fault_caught_at_spgemm_entry(ab, name):
    # spgemm(validate=...) must catch the corruption before any dispatch
    a, b = ab
    bad = faults.inject_csr(name, a)
    with pytest.raises(faults.FAULTS[name].expects):
        spgemm(bad, b, method="sparse", validate="host")


def test_typed_errors_are_valueerrors(ab):
    # back-compat: pre-taxonomy call sites catch ValueError
    a, _ = ab
    bad = faults.inject_csr("capacity_overflow", a)
    with pytest.raises(ValueError):
        check_csr(bad, "host")
    assert issubclass(CapacityOverflowError, ValueError)
    assert issubclass(SpgemmInputError, ValueError)
    assert issubclass(PlanMismatchError, ValueError)


def test_fault_injection_is_deterministic(ab):
    a, _ = ab
    x = faults.inject_csr("oob_col_index", a, seed=7)
    y = faults.inject_csr("oob_col_index", a, seed=7)
    assert np.array_equal(np.asarray(x.indices), np.asarray(y.indices))


# --------------------------------------------------------------------------
# Kernel faults: validation OFF -> degradation ladder, bitwise-correct XLA
# --------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["pallas", "pallas_lp"])
def test_executor_kernel_fault_degrades_bitwise(ab, backend):
    a, b = ab
    ex = ReuseExecutor.from_matrices(a, b, backend=backend)
    oracle = numeric_reuse(ex.plan, a.values, b.values)
    with faults.failpoint(f"kernel:{backend}"):
        out = ex.apply(a.values, b.values)
    assert bool(jnp.all(out == oracle))  # bitwise: same XLA reference
    assert ex.kernel_source == "fallback"
    assert telemetry.FALLBACK_COUNTS[f"fault:{backend}->xla"] == 1


def test_executor_kernel_fault_strict_raises(ab):
    a, b = ab
    ex = ReuseExecutor.from_matrices(a, b, backend="pallas",
                                     on_kernel_failure="raise")
    with faults.failpoint("kernel:pallas"):
        with pytest.raises(KernelFallbackError) as ei:
            ex.apply(a.values, b.values)
    assert isinstance(ei.value.__cause__, faults.InjectedFault)
    assert ex.kernel_source == "static"  # no silent fallback happened


def test_executor_recovers_after_fault_clears(ab):
    a, b = ab
    ex = ReuseExecutor.from_matrices(a, b, backend="pallas")
    with faults.failpoint("kernel:pallas"):
        ex.apply(a.values, b.values)
    oracle = numeric_reuse(ex.plan, a.values, b.values)
    out = ex.apply(a.values, b.values)  # failpoint disarmed: pallas again
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               rtol=1e-6)
    assert telemetry.FALLBACK_COUNTS["fault:pallas->xla"] == 1  # no new bump


@pytest.mark.parametrize("kernel", ["dense_acc", "flat_lp"])
def test_numeric_values_ladder_bitwise(ab, kernel):
    a, b = ab
    res = spgemm(a, b, method="sparse")
    c_ell = csr_to_ell(res.c)
    ref = numeric_values(a, b, c_ell.indices, c_ell.row_nnz, kernel="xla")
    with faults.failpoint(f"kernel:{kernel}"):
        out = numeric_values(a, b, c_ell.indices, c_ell.row_nnz,
                             kernel=kernel)
    assert bool(jnp.all(out == ref))
    assert telemetry.FALLBACK_COUNTS[f"fault:{kernel}->xla"] == 1
    assert telemetry.KERNEL_COUNTS["xla"] >= 1


def test_numeric_values_auto_ladder_exhausts_to_xla(ab):
    # every Pallas rung armed: auto must still land on the exact reference
    a, b = ab
    res = spgemm(a, b, method="sparse")
    c_ell = csr_to_ell(res.c)
    ref = numeric_values(a, b, c_ell.indices, c_ell.row_nnz, kernel="xla")
    with faults.failpoint("kernel:dense_acc"), \
            faults.failpoint("kernel:flat_lp"):
        out = numeric_values(a, b, c_ell.indices, c_ell.row_nnz,
                             kernel="auto")
    assert bool(jnp.all(out == ref))
    assert sum(v for k, v in telemetry.FALLBACK_COUNTS.items()
               if k.startswith("fault:")) >= 1


def test_numeric_values_ladder_exhausted_raises(ab):
    a, b = ab
    res = spgemm(a, b, method="sparse")
    c_ell = csr_to_ell(res.c)
    with faults.failpoint("kernel:dense_acc"), \
            faults.failpoint("kernel:flat_lp"), \
            faults.failpoint("kernel:xla"):
        with pytest.raises(KernelFallbackError, match="exhausted"):
            numeric_values(a, b, c_ell.indices, c_ell.row_nnz, kernel="auto")


# --------------------------------------------------------------------------
# NaN guard
# --------------------------------------------------------------------------


def test_nan_guard_recovers_kernel_side_poison(ab):
    a, b = ab
    ex = ReuseExecutor.from_matrices(a, b, nan_guard=True)
    oracle = numeric_reuse(ex.plan, a.values, b.values)
    with faults.failpoint("executor:poison_output"):
        out = ex.apply(a.values, b.values)
    assert bool(jnp.all(jnp.isfinite(out)))
    assert bool(jnp.all(out == oracle))
    assert ex.nan_events == [("recovered", "xla")]
    assert telemetry.FALLBACK_COUNTS["nan_guard:rerun"] == 1
    assert telemetry.FALLBACK_COUNTS["nan_guard:recovered"] == 1


def test_nan_guard_flags_data_nan(ab):
    a, b = ab
    ex = ReuseExecutor.from_matrices(a, b, nan_guard=True)
    bad_vals = np.asarray(a.values).copy()
    bad_vals[0] = np.nan
    out = ex.apply(jnp.asarray(bad_vals), b.values)
    assert not bool(jnp.all(jnp.isfinite(out)))  # data NaN: flagged, not hidden
    assert ex.nan_events and ex.nan_events[0][0] == "data"
    assert telemetry.FALLBACK_COUNTS["nan_guard:data"] == 1


def test_nan_guard_zero_overhead_path_clean_output(ab):
    a, b = ab
    ex = ReuseExecutor.from_matrices(a, b, nan_guard=True)
    ex.apply(a.values, b.values)
    assert ex.nan_events == []
    assert telemetry.FALLBACK_COUNTS["nan_guard:rerun"] == 0


def test_nan_guard_rejects_donate(ab):
    a, b = ab
    ex = ReuseExecutor.from_matrices(a, b, nan_guard=True)
    with pytest.raises(ValueError, match="donate"):
        ex.apply(a.values, b.values, donate=True)


# --------------------------------------------------------------------------
# Plan mismatch + cache eviction mid-replay
# --------------------------------------------------------------------------


def test_plan_mismatch_at_replay(ab):
    a, b = ab
    ex = ReuseExecutor.from_matrices(a, b, validate="host")
    with pytest.raises(PlanMismatchError, match="slots"):
        ex.apply(a.values[: max(ex._guard.a_req - 1, 1)], b.values)


def test_check_compat_detects_different_structure(ab):
    a, b = ab
    ex = ReuseExecutor.from_matrices(a, b)
    ex.check_compat(a, b)  # same structure: fine
    a2 = random_csr(32, 24, 6.0, seed=9)  # different sparsity pattern
    with pytest.raises(PlanMismatchError):
        ex.check_compat(a2, b)


def test_check_compat_requires_pinned_key(ab):
    a, b = ab
    res = spgemm(a, b, method="sparse")
    ex = ReuseExecutor(res.plan)  # bare plan: no structure key retained
    with pytest.raises(PlanMismatchError, match="no pinned structure key"):
        ex.check_compat(a, b)


def test_plan_cache_eviction_mid_replay(ab):
    # simulated eviction: the cache clears between calls; spgemm must
    # transparently rebuild (a "miss", never wrong values), and a pinned
    # executor must keep replaying its own plan unaffected
    a, b = ab
    cache = PlanCache(capacity=4)
    r1 = spgemm(a, b, method="sparse", plan_cache=cache)
    ex = ReuseExecutor.from_matrices(a, b, plan_cache=cache)
    assert spgemm(a, b, method="sparse", plan_cache=cache).stats["cache"] == "hit"
    cache.clear()  # the registered plan_cache_eviction fault
    r2 = spgemm(a, b, method="sparse", plan_cache=cache)
    assert r2.stats["cache"] == "miss"
    assert bool(jnp.all(r2.c.values == r1.c.values))
    out = ex.apply(a.values, b.values)  # pinned plan: eviction-proof
    assert bool(jnp.all(out == r1.c.values))


# --------------------------------------------------------------------------
# Failpoint hygiene
# --------------------------------------------------------------------------


def test_failpoint_context_disarms_on_error():
    with pytest.raises(RuntimeError):
        with faults.failpoint("kernel:pallas"):
            raise RuntimeError("body blew up")
    assert not faults.armed("kernel:pallas")


def test_registry_covers_both_fault_kinds():
    kinds = {s.kind for s in faults.FAULTS.values()}
    assert kinds == {"data", "kernel", "cache"}
    for s in faults.data_faults():
        assert s.expects is not None  # every data fault names its error
    for s in faults.kernel_faults():
        assert s.site and s.site.startswith("kernel:")


# --------------------------------------------------------------------------
# Chaos under traffic: the serving tier's acceptance bar
# --------------------------------------------------------------------------


def test_service_chaos_under_traffic():
    """Live traffic through SparseService while everything misbehaves at
    once — kernel failpoints flapping, one corrupt request in the stream, a
    forced plan-cache eviction mid-stream. The bar is the failure model's:
    every COMPLETED response is bitwise-equal to the XLA reference and every
    non-completion is a typed SpgemmError; nothing silent, nothing dropped.
    """
    from repro.serve import SparseService
    from repro.runtime.validate import SpgemmError, SpgemmInputError

    structures = [
        (random_csr(32, 24, 4.0, seed=1), random_csr(24, 40, 4.0, seed=2)),
        (random_csr(16, 24, 3.0, seed=7), random_csr(24, 8, 3.0, seed=8)),
        (random_csr(48, 16, 2.0, seed=9), random_csr(16, 48, 3.0, seed=10)),
    ]
    refs = [spgemm(a, b, method="sparse").c.to_dense() for a, b in structures]
    svc = SparseService(backend="pallas", max_batch=2, breaker_threshold=2,
                        retries=1, sleep=lambda _: None)
    ledger = []  # (response, reference | None for the corrupt one)

    def pump(i, corrupt=False):
        a, b = structures[i % len(structures)]
        if corrupt:
            a = faults.inject_csr("nan_values", a)
        ledger.append((svc.submit(a, b), None if corrupt else refs[i % 3]))

    for i in range(4):  # clean warm-up traffic
        pump(i)
    svc.drain()
    with faults.failpoint("kernel:pallas"):  # fast kernel starts flapping
        for i in range(4):
            pump(i)
        svc.drain()
        pump(0, corrupt=True)  # a hostile request inside the fault window
        svc.plan_cache.clear()  # and the cache evicts mid-stream
        for i in range(3):
            pump(i)
        svc.drain()
    for i in range(3):  # recovery traffic, failpoint cleared
        pump(i)
    svc.drain()

    assert len(ledger) == 15
    completed = rejected = 0
    for resp, ref in ledger:
        assert resp.done  # nothing silently dropped
        if ref is None:  # the corrupt request: typed rejection at the door
            assert isinstance(resp.error, SpgemmInputError)
            rejected += 1
        else:
            assert resp.ok, f"unexpected failure: {resp.error!r}"
            assert bool(jnp.all(resp.value.to_dense() == ref))  # bitwise
            completed += 1
    assert completed == 14 and rejected == 1
    # the chaos left evidence, not wreckage: ladder fallbacks were counted,
    # and the flapping kernel tripped the breaker
    assert telemetry.FALLBACK_COUNTS["fault:pallas->xla"] >= 1
    assert telemetry.BREAKER_COUNTS["pallas:open"] >= 1
    stats = svc.stats()
    assert stats["rejected_validation"] == 1
    assert stats["completed"] == 14
    assert stats["failed"] == 0
