"""The paper's primary contribution: performance-portable two-phase SpGEMM.

Public API:
    spgemm            — full meta-algorithm driver (KKSPGEMM)
    symbolic          — phase 1 (row sizes; compression-aware)
    numeric_fresh     — phase 2, first run (structure + values + reuse plan)
    numeric_reuse     — phase 2, Reuse case (new values, same structure)
    ReuseExecutor     — pinned-plan replay engine (single/batched dispatch)
    spgemm_grouped    — mixed-structure batch: one dispatch per structure
    compress_matrix   — §3.2 bit compression
    distributed_spgemm — 1-D row-wise SpGEMM over a device mesh (from
                        scratch; for pinned sharded plans see repro.dist)
    round_capacity    — capacity bucketing policy ("exact8" / "pow2")
    PlanCache         — structure-keyed LRU of reuse plans (auto Reuse case;
                        entry-count + bytes bounds)
    fit_thresholds    — per-backend crossover fit from bench_accumulators
                        rows (static < fitted < measured; see core.autotune)
    TunedThresholds   — the fitted table; activate with set_tuned_thresholds
"""
from repro.core.spgemm import (
    SortedExpansion,
    SpgemmPlan,
    SpgemmResult,
    expand_and_sort,
    expand_products,
    host_fm_cap,
    numeric_dense_acc,
    numeric_fresh,
    numeric_lp,
    numeric_reuse,
    plan_from_sorted,
    reset_trace_counts,
    resolve_plan,
    spgemm,
    symbolic,
    symbolic_compressed,
    symbolic_dense_bitmask,
    symbolic_plain,
)
from repro.core.compression import (
    COMPRESSION_CF_CUTOFF,
    CompressedMatrix,
    bitmask_rows,
    compress_matrix,
    compression_decision,
    flops_stats,
)
from repro.core.meta import (
    AVG_ROW_FLOPS_CUTOFF,
    DEFAULT_PAD_POLICY,
    DENSE_K_CUTOFF,
    PAD_POLICIES,
    choose_kernel,
    choose_method,
    estimate_ars,
    round_capacity,
)
from repro.core.autotune import (
    TUNE_COUNTS,
    BackendFit,
    TunedThresholds,
    fit_thresholds,
    get_tuned_thresholds,
    load_thresholds,
    reset_tune_counts,
    set_tuned_thresholds,
)
from repro.core.plan_cache import (
    HASH_COUNTS,
    PlanCache,
    default_plan_cache,
    plan_nbytes,
    reset_hash_counts,
    structure_key,
)
from repro.core.executor import (
    DISPATCH_COUNTS,
    ReuseExecutor,
    reset_dispatch_counts,
    spgemm_grouped,
)
from repro.core.distributed import (
    ShardedCSR,
    allgather_value_perm,
    concat_csr_shards,
    dist_numeric,
    dist_symbolic,
    distributed_spgemm,
    merge_shards,
    partition_rows,
    partition_value_map,
    row_block_bounds,
    shard_cap,
    shard_fm_cap,
)
from repro.core.memory_pool import PoolConfig, acquire_release_sim, chunk_for_step, size_pool

__all__ = [
    "SortedExpansion",
    "SpgemmPlan",
    "SpgemmResult",
    "expand_and_sort",
    "expand_products",
    "plan_from_sorted",
    "reset_trace_counts",
    "resolve_plan",
    "host_fm_cap",
    "numeric_dense_acc",
    "numeric_fresh",
    "numeric_lp",
    "numeric_reuse",
    "spgemm",
    "symbolic",
    "symbolic_compressed",
    "symbolic_dense_bitmask",
    "symbolic_plain",
    "COMPRESSION_CF_CUTOFF",
    "CompressedMatrix",
    "bitmask_rows",
    "compress_matrix",
    "compression_decision",
    "flops_stats",
    "AVG_ROW_FLOPS_CUTOFF",
    "DEFAULT_PAD_POLICY",
    "DENSE_K_CUTOFF",
    "PAD_POLICIES",
    "choose_kernel",
    "choose_method",
    "estimate_ars",
    "round_capacity",
    "TUNE_COUNTS",
    "BackendFit",
    "TunedThresholds",
    "fit_thresholds",
    "get_tuned_thresholds",
    "load_thresholds",
    "reset_tune_counts",
    "set_tuned_thresholds",
    "PlanCache",
    "HASH_COUNTS",
    "default_plan_cache",
    "plan_nbytes",
    "reset_hash_counts",
    "structure_key",
    "DISPATCH_COUNTS",
    "ReuseExecutor",
    "reset_dispatch_counts",
    "spgemm_grouped",
    "ShardedCSR",
    "allgather_value_perm",
    "concat_csr_shards",
    "dist_numeric",
    "dist_symbolic",
    "distributed_spgemm",
    "merge_shards",
    "partition_rows",
    "partition_value_map",
    "row_block_bounds",
    "shard_cap",
    "shard_fm_cap",
    "PoolConfig",
    "acquire_release_sim",
    "chunk_for_step",
    "size_pool",
]
