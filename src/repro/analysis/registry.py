"""Rule registry: ``@rule`` decorator + lookup.

A rule is a callable ``(project: Project) -> Iterable[Finding]``. Modules
register themselves at import time; :mod:`repro.analysis.__init__` imports
every shipped rule module so ``RULES`` is complete after
``import repro.analysis``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Iterable

from repro.analysis.context import Project
from repro.analysis.findings import Finding


@dataclass(frozen=True)
class Rule:
    id: str
    doc: str  # one-line summary (shown by --list-rules / --help)
    check: Callable[[Project], Iterable[Finding]]


RULES: dict[str, Rule] = {}


def rule(rule_id: str, doc: str):
    """Register ``fn`` as the checker for ``rule_id``."""

    def deco(fn: Callable[[Project], Iterable[Finding]]):
        if rule_id in RULES:
            raise RuntimeError(f"duplicate analysis rule id: {rule_id}")
        RULES[rule_id] = Rule(id=rule_id, doc=doc, check=fn)
        return fn

    return deco


def all_rule_ids() -> list[str]:
    return sorted(RULES)
