"""Tests for repro.analysis — the static contract linter.

Each rule gets three fixtures: a violating tree (true positive), a clean
tree (no false positive), and a suppressed variant (inline allow). Fixture
trees carry their own minimal registries (``core/telemetry.py``,
``obs/trace.py``, ``runtime/validate.py``) so the analyzer resolves them
exactly like the real package. On top of that: a no-new-findings run over
the real ``src/repro``, a baseline round-trip, and the CLI gate driven via
subprocess (what CI actually runs).
"""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.analysis import Finding, all_rule_ids, run_analysis
from repro.analysis.findings import load_baseline, save_baseline

REAL_ROOT = Path(__file__).resolve().parents[1] / "src" / "repro"
REAL_BASELINE = Path(__file__).resolve().parents[1] / "analysis" / "baseline.json"


def make_tree(tmp_path: Path, files: dict) -> Path:
    root = tmp_path / "pkg"
    for rel, content in files.items():
        p = root / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(content))
    return root


# Minimal registries a fixture tree needs so rules resolve against it.
REGISTRIES = {
    "core/telemetry.py": """
        from collections import Counter

        KEY_FAMILIES = {
            "fallback": ("fault:{}->{}", "nan_guard:rerun"),
            "breaker": ("{}:open", "{}:close"),
        }

        FALLBACK_COUNTS = Counter()
        BREAKER_COUNTS = Counter()


        def reset_fallback_counts():
            FALLBACK_COUNTS.clear()


        def reset_breaker_counts():
            BREAKER_COUNTS.clear()


        ALL_COUNTERS = {
            "fallback": FALLBACK_COUNTS,
            "breaker": BREAKER_COUNTS,
        }

        _RESETS = (reset_fallback_counts, reset_breaker_counts)
    """,
    "obs/trace.py": """
        SPAN_NAMES = frozenset({"plan.build", "numeric.dispatch"})


        def span(name, **attrs):
            return None
    """,
    "runtime/validate.py": """
        class SpgemmError(Exception):
            pass


        class SpgemmConfigError(SpgemmError, ValueError):
            pass
    """,
}


def run_on(tmp_path, files, rules=None):
    root = make_tree(tmp_path, {**REGISTRIES, **files})
    return run_analysis(root, rules=rules)


def codes(findings):
    return sorted(f.code for f in findings)


# --------------------------------------------------------------------------
# rule registry / plumbing
# --------------------------------------------------------------------------


def test_all_five_rules_registered():
    assert all_rule_ids() == ["env", "jit-boundary", "span", "taxonomy",
                              "telemetry-key"]


def test_registries_alone_are_clean(tmp_path):
    report = run_on(tmp_path, {})
    assert report.ok, codes(report.new)
    assert not report.suppressed and not report.baselined


def test_unknown_rule_is_a_loud_error(tmp_path):
    with pytest.raises(KeyError, match="nope"):
        run_on(tmp_path, {}, rules=["nope"])


def test_syntax_error_fails_the_gate(tmp_path):
    report = run_on(tmp_path, {"broken.py": "def f(:\n"})
    assert not report.ok
    assert codes(report.new) == ["parse.syntax-error"]


# --------------------------------------------------------------------------
# rule 1: jit-boundary
# --------------------------------------------------------------------------


def test_jit_try_in_traced_violating(tmp_path):
    report = run_on(tmp_path, {"mod.py": """
        import jax


        @jax.jit
        def f(x):
            try:
                return x + 1
            except Exception:
                return x
    """}, rules=["jit-boundary"])
    assert "jit-boundary.try-in-traced" in codes(report.new)


def test_jit_host_sync_in_traced_violating(tmp_path):
    report = run_on(tmp_path, {"mod.py": """
        import jax
        import numpy as np


        def helper(x):
            return np.asarray(x)


        def f(x):
            return helper(x) + float(x[0])


        g = jax.jit(f)
    """}, rules=["jit-boundary"])
    # both the direct float() in f and the np.asarray in its callee helper
    assert codes(report.new).count("jit-boundary.host-sync") == 2


def test_jit_silent_catch_violating(tmp_path):
    report = run_on(tmp_path, {"mod.py": """
        def run_cell(cell):
            return cell.lower().compile()


        def survey(cells):
            out = []
            for c in cells:
                try:
                    out.append(run_cell(c))
                except Exception:
                    pass
            return out
    """}, rules=["jit-boundary"])
    assert "jit-boundary.silent-catch" in codes(report.new)


def test_jit_clean_ladder_passes(tmp_path):
    # catching OUTSIDE jit with a typed re-raise is the sanctioned ladder
    report = run_on(tmp_path, {"mod.py": """
        import jax
        from runtime.validate import SpgemmConfigError


        @jax.jit
        def f(x):
            return x + 1


        def dispatch(x):
            try:
                return f(x)
            except Exception as e:
                raise SpgemmConfigError("kernel failed") from e
    """}, rules=["jit-boundary"])
    assert report.ok, codes(report.new)


def test_jit_suppressed(tmp_path):
    report = run_on(tmp_path, {"mod.py": """
        import jax


        @jax.jit
        def f(x):
            # repro: allow[jit-boundary] fixture-sanctioned
            try:
                return x + 1
            except Exception:
                return x
    """}, rules=["jit-boundary"])
    assert report.ok
    assert codes(report.suppressed) == ["jit-boundary.try-in-traced"]


# --------------------------------------------------------------------------
# rule 2: telemetry-key
# --------------------------------------------------------------------------


def test_key_grammar_violating(tmp_path):
    report = run_on(tmp_path, {"mod.py": """
        from core.telemetry import FALLBACK_COUNTS


        def hop():
            FALLBACK_COUNTS["nan_guard:typo"] += 1
    """}, rules=["telemetry-key"])
    assert codes(report.new) == ["telemetry-key.grammar"]


def test_key_param_expansion_violating(tmp_path):
    # the f-string key itself is fine ({}:open / {}:close), but a call site
    # passes an event outside the grammar — caught through param expansion
    report = run_on(tmp_path, {"mod.py": """
        from core.telemetry import BREAKER_COUNTS


        class Breaker:
            def __init__(self, name):
                self.name = name

            def _count(self, event):
                BREAKER_COUNTS[f"{self.name}:{event}"] += 1

            def trip(self):
                self._count("explode")
    """}, rules=["telemetry-key"])
    assert codes(report.new) == ["telemetry-key.grammar"]
    assert "explode" in report.new[0].message


def test_key_clean_literals_and_fstrings(tmp_path):
    report = run_on(tmp_path, {"mod.py": """
        from core.telemetry import BREAKER_COUNTS, FALLBACK_COUNTS


        def hop(a, b):
            FALLBACK_COUNTS["nan_guard:rerun"] += 1
            FALLBACK_COUNTS[f"fault:{a}->{b}"] += 1


        class Breaker:
            def __init__(self, name):
                self.name = name

            def _count(self, event):
                BREAKER_COUNTS[f"{self.name}:{event}"] += 1

            def trip(self):
                self._count("open")
                self._count("close")
    """}, rules=["telemetry-key"])
    assert report.ok, codes(report.new)


def test_key_unregistered_counter_violating(tmp_path):
    report = run_on(tmp_path, {"mod.py": """
        from collections import Counter

        ROGUE_COUNTS = Counter()
    """}, rules=["telemetry-key"])
    assert codes(report.new) == ["telemetry-key.unregistered"]


def test_key_suppressed(tmp_path):
    report = run_on(tmp_path, {"mod.py": """
        from core.telemetry import FALLBACK_COUNTS


        def hop():
            # repro: allow[telemetry-key] fixture-sanctioned
            FALLBACK_COUNTS["nan_guard:typo"] += 1
    """}, rules=["telemetry-key"])
    assert report.ok
    assert codes(report.suppressed) == ["telemetry-key.grammar"]


# --------------------------------------------------------------------------
# rule 3: taxonomy
# --------------------------------------------------------------------------


def test_taxonomy_bare_raise_violating(tmp_path):
    report = run_on(tmp_path, {"mod.py": """
        def f(x):
            if x < 0:
                raise ValueError("negative")
            if x > 10:
                raise RuntimeError("too big")
    """}, rules=["taxonomy"])
    assert codes(report.new) == ["taxonomy.bare-raise", "taxonomy.bare-raise"]


def test_taxonomy_broad_except_swallow_violating(tmp_path):
    report = run_on(tmp_path, {"mod.py": """
        def f(x):
            try:
                return x.go()
            except Exception:
                return None
    """}, rules=["taxonomy"])
    assert codes(report.new) == ["taxonomy.broad-except"]


def test_taxonomy_clean(tmp_path):
    # typed raises are fine anywhere; validate.py itself may raise bare;
    # a broad except that re-raises typed or records telemetry is loud
    report = run_on(tmp_path, {
        "runtime/validate.py": REGISTRIES["runtime/validate.py"] + """

        def resolve(mode):
            if mode not in ("off", "on"):
                raise ValueError(mode)
    """,
        "mod.py": """
        from core.telemetry import FALLBACK_COUNTS
        from runtime.validate import SpgemmConfigError


        def f(x):
            if x < 0:
                raise SpgemmConfigError("negative")
            try:
                return x.go()
            except Exception as e:
                raise SpgemmConfigError("failed") from e


        def g(x, a, b):
            try:
                return x.go()
            except Exception:
                FALLBACK_COUNTS[f"fault:{a}->{b}"] += 1
                return None
    """}, rules=["taxonomy"])
    assert report.ok, codes(report.new)


def test_taxonomy_suppressed(tmp_path):
    report = run_on(tmp_path, {"mod.py": """
        def f(x):
            # repro: allow[taxonomy] fixture-sanctioned
            raise ValueError("negative")
    """}, rules=["taxonomy"])
    assert report.ok
    assert codes(report.suppressed) == ["taxonomy.bare-raise"]


# --------------------------------------------------------------------------
# rule 4: span
# --------------------------------------------------------------------------


def test_span_unknown_name_violating(tmp_path):
    report = run_on(tmp_path, {"mod.py": """
        from obs.trace import span


        def f():
            with span("plan.bulid"):
                pass
    """}, rules=["span"])
    assert codes(report.new) == ["span.unknown-name"]
    assert "plan.bulid" in report.new[0].message


def test_span_dynamic_name_violating(tmp_path):
    report = run_on(tmp_path, {"mod.py": """
        from obs.trace import span


        def f(name):
            with span(name):
                pass
    """}, rules=["span"])
    assert codes(report.new) == ["span.dynamic-name"]


def test_span_clean_and_missing_registry(tmp_path):
    report = run_on(tmp_path, {"mod.py": """
        from obs.trace import span


        def f():
            with span("plan.build", structure_key="k1"):
                pass
    """}, rules=["span"])
    assert report.ok, codes(report.new)

    # a trace module without SPAN_NAMES is itself a finding
    report = run_on(tmp_path / "nr", {
        "obs/trace.py": "def span(name):\n    return None\n",
        "mod.py": "from obs.trace import span\n\n\ndef f():\n"
                  "    return span('anything')\n",
    }, rules=["span"])
    assert codes(report.new) == ["span.no-registry"]


def test_span_suppressed(tmp_path):
    report = run_on(tmp_path, {"mod.py": """
        from obs.trace import span


        def f():
            # repro: allow[span] fixture-sanctioned
            with span("plan.bulid"):
                pass
    """}, rules=["span"])
    assert report.ok
    assert codes(report.suppressed) == ["span.unknown-name"]


# --------------------------------------------------------------------------
# rule 5: env
# --------------------------------------------------------------------------


def test_env_import_time_mutation_violating(tmp_path):
    report = run_on(tmp_path, {"mod.py": """
        import os

        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    """}, rules=["env"])
    assert codes(report.new) == ["env.import-time-mutation"]


def test_env_unsanctioned_read_violating(tmp_path):
    report = run_on(tmp_path, {"mod.py": """
        import os


        def knob():
            return os.environ.get("REPRO_SECRET_KNOB", "off")
    """}, rules=["env"])
    assert codes(report.new) == ["env.unsanctioned-read"]


def test_env_import_time_device_work_violating(tmp_path):
    report = run_on(tmp_path, {"mod.py": """
        import jax

        N_DEVICES = jax.device_count()
    """}, rules=["env"])
    assert codes(report.new) == ["env.import-time-device-work"]


def test_env_clean(tmp_path):
    # sanctioned read site, function-scoped write, main-guard entrypoint
    report = run_on(tmp_path, {
        "runtime/validate.py": REGISTRIES["runtime/validate.py"] + """

        import os


        def resolve_mode(mode):
            if mode is None:
                return os.environ.get("REPRO_VALIDATE", "off")
            return mode
    """,
        "launch/dryrun.py": """
        import os


        def force_host_devices(n=512):
            os.environ["XLA_FLAGS"] = (
                f"--xla_force_host_platform_device_count={n}")


        def main():
            pass


        if __name__ == "__main__":
            force_host_devices()
            main()
    """}, rules=["env"])
    assert report.ok, codes(report.new)


def test_env_suppressed(tmp_path):
    report = run_on(tmp_path, {"mod.py": """
        import os

        # repro: allow[env] fixture-sanctioned
        os.environ["XLA_FLAGS"] = "--whatever"
    """}, rules=["env"])
    assert report.ok
    assert codes(report.suppressed) == ["env.import-time-mutation"]


# --------------------------------------------------------------------------
# suppression semantics
# --------------------------------------------------------------------------


def test_allow_matches_specific_code_and_star(tmp_path):
    files = {"mod.py": """
        def f(x):
            # repro: allow[taxonomy.bare-raise] code-level allow
            raise ValueError("a")


        def g(x):
            # repro: allow[*] blanket allow
            raise RuntimeError("b")
    """}
    report = run_on(tmp_path, files, rules=["taxonomy"])
    assert report.ok
    assert len(report.suppressed) == 2


def test_allow_for_other_rule_does_not_suppress(tmp_path):
    report = run_on(tmp_path, {"mod.py": """
        def f(x):
            # repro: allow[span] wrong rule
            raise ValueError("a")
    """}, rules=["taxonomy"])
    assert codes(report.new) == ["taxonomy.bare-raise"]


# --------------------------------------------------------------------------
# baseline mechanism
# --------------------------------------------------------------------------


def test_baseline_round_trip(tmp_path):
    files = {"mod.py": "def f():\n    raise ValueError('grandfathered')\n"}
    root = make_tree(tmp_path, {**REGISTRIES, **files})
    first = run_analysis(root, rules=["taxonomy"])
    assert len(first.new) == 1

    baseline_path = tmp_path / "baseline.json"
    save_baseline(baseline_path, first.new)
    assert load_baseline(baseline_path) == {first.new[0].fingerprint}

    second = run_analysis(root, rules=["taxonomy"],
                          baseline_path=baseline_path)
    assert second.ok
    assert codes(second.baselined) == ["taxonomy.bare-raise"]
    assert not second.new  # zero drift: load -> re-scan -> all baselined


def test_baseline_survives_line_drift_not_content_change(tmp_path):
    files = {"mod.py": "def f():\n    raise ValueError('grandfathered')\n"}
    root = make_tree(tmp_path, {**REGISTRIES, **files})
    first = run_analysis(root, rules=["taxonomy"])
    baseline_path = tmp_path / "baseline.json"
    save_baseline(baseline_path, first.new)

    # unrelated lines move the finding down: fingerprint must hold
    (root / "mod.py").write_text(
        "import os\n\n\ndef f():\n    raise ValueError('grandfathered')\n")
    drifted = run_analysis(root, rules=["taxonomy"],
                           baseline_path=baseline_path)
    assert drifted.ok and len(drifted.baselined) == 1

    # but editing the offending line itself resurfaces the finding
    (root / "mod.py").write_text(
        "def f():\n    raise ValueError('edited message')\n")
    edited = run_analysis(root, rules=["taxonomy"],
                          baseline_path=baseline_path)
    assert not edited.ok


def test_malformed_baseline_is_loud(tmp_path):
    bad = tmp_path / "baseline.json"
    bad.write_text('{"not": "a baseline"}')
    with pytest.raises(ValueError, match="not a repro.analysis baseline"):
        load_baseline(bad)


def test_missing_baseline_is_empty(tmp_path):
    assert load_baseline(tmp_path / "nope.json") == set()


def test_fingerprint_normalizes_whitespace():
    a = Finding(rule="r", code="r.c", path="p.py", line=3, message="m",
                snippet="raise  ValueError('x')")
    b = Finding(rule="r", code="r.c", path="p.py", line=99, message="m",
                snippet="raise ValueError('x')")
    assert a.fingerprint == b.fingerprint  # line + inner spacing irrelevant


# --------------------------------------------------------------------------
# the real tree: the acceptance gate itself
# --------------------------------------------------------------------------


def test_real_repo_has_no_new_findings():
    report = run_analysis(REAL_ROOT, baseline_path=REAL_BASELINE)
    assert report.ok, "\n".join(f.render() for f in report.new)
    # rules 1-4 are clean on HEAD *without* grandfathering: empty baseline
    assert load_baseline(REAL_BASELINE) == set()
    # the three intentional suppressions are labeled in-code, not silent
    assert len(report.suppressed) == 3
    assert {f.path for f in report.suppressed} == {"launch/dryrun.py",
                                                   "obs/trace.py"}


def test_real_repo_scans_all_modules():
    report = run_analysis(REAL_ROOT)
    assert report.stats["modules"] > 60
    assert report.stats["parse_errors"] == 0


# --------------------------------------------------------------------------
# CLI (what the CI analysis job runs)
# --------------------------------------------------------------------------


def _run_cli(args, cwd=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REAL_ROOT.parent)
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True, text=True, env=env, cwd=cwd)


def test_cli_fails_on_seeded_violation(tmp_path):
    root = make_tree(tmp_path, {
        **REGISTRIES,
        "mod.py": "def f():\n    raise ValueError('seeded')\n",
    })
    out_json = tmp_path / "report.json"
    proc = _run_cli(["--root", str(root), "--json", str(out_json),
                     "--baseline", str(tmp_path / "empty.json")])
    assert proc.returncode == 1, proc.stdout + proc.stderr
    assert "taxonomy.bare-raise" in proc.stdout
    payload = json.loads(out_json.read_text())
    assert payload["ok"] is False
    assert payload["counts"]["new"] == 1
    assert payload["new"][0]["code"] == "taxonomy.bare-raise"
    assert payload["new"][0]["path"] == "mod.py"


def test_cli_passes_on_real_repo(tmp_path):
    out_json = tmp_path / "report.json"
    proc = _run_cli(["--json", str(out_json)])
    assert proc.returncode == 0, proc.stdout + proc.stderr
    payload = json.loads(out_json.read_text())
    assert payload["ok"] is True and payload["counts"]["new"] == 0


def test_cli_rules_subset_and_list_rules(tmp_path):
    root = make_tree(tmp_path, {
        **REGISTRIES,
        "mod.py": "def f():\n    raise ValueError('seeded')\n",
    })
    # scoping to another rule must not trip on the taxonomy violation
    proc = _run_cli(["--root", str(root), "--rules", "span",
                     "--baseline", str(tmp_path / "empty.json")])
    assert proc.returncode == 0, proc.stdout + proc.stderr

    proc = _run_cli(["--list-rules"])
    assert proc.returncode == 0
    for rule_id in all_rule_ids():
        assert rule_id in proc.stdout


def test_cli_update_baseline_grandfathers(tmp_path):
    root = make_tree(tmp_path, {
        **REGISTRIES,
        "mod.py": "def f():\n    raise ValueError('seeded')\n",
    })
    baseline = tmp_path / "baseline.json"
    proc = _run_cli(["--root", str(root), "--baseline", str(baseline),
                     "--update-baseline"])
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert len(load_baseline(baseline)) == 1

    proc = _run_cli(["--root", str(root), "--baseline", str(baseline)])
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "baselined" in proc.stdout
