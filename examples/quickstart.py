"""Quickstart: the paper's SpGEMM as a library, end to end.

Runs on CPU in seconds:
  1. two-phase SpGEMM (symbolic -> allocate -> numeric) on a multigrid
     triple product R*A*P, validated against the dense oracle;
  2. the Reuse case (new values, cached structure plan) — the use case the
     paper shows native libraries fail to serve;
  3. compression statistics (CF / CMRF and the 15% rule);
  4. the meta-algorithm's method choice;
  5. the Pallas TPU kernels in interpret mode.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import (
    compress_matrix,
    compression_decision,
    numeric_reuse,
    spgemm,
)
from repro.kernels.ops import pallas_spgemm
from repro.sparse import CSR, galerkin_triple, dense_spgemm_oracle


def main():
    # -- 1. two-phase SpGEMM on a Galerkin triple product ------------------
    r, a, p = galerkin_triple(32, 32, agg_size=4)
    print(f"A: {a.shape} nnz={int(a.nnz())}   P: {p.shape} nnz={int(p.nnz())}")

    ap = spgemm(a, p, method="sparse")  # sparse path returns a reuse plan
    print(f"A*P: nnz={ap.stats['nnz_c']}  method={ap.stats['method']}  "
          f"cache={ap.stats['cache']}  fm_cap={ap.stats['fm_cap']} "
          f"(pad_policy={ap.stats['pad_policy']})")
    rap = spgemm(r, ap.c)
    want = (np.asarray(r.to_dense()) @ np.asarray(a.to_dense())
            @ np.asarray(p.to_dense()))
    np.testing.assert_allclose(np.asarray(rap.c.to_dense()), want,
                               rtol=1e-4, atol=1e-4)
    print("R*A*P validated against the dense oracle")

    # -- 2. Reuse: same structure, new values ------------------------------
    new_vals = jnp.asarray(
        np.random.default_rng(0).standard_normal(a.nnz_cap), jnp.float32)
    a2 = CSR(a.indptr, a.indices, new_vals, a.shape)
    reused_vals = numeric_reuse(ap.plan, a2.values, p.values)
    fresh = spgemm(a2, p)
    nnz = int(fresh.c.nnz())
    np.testing.assert_allclose(np.asarray(reused_vals)[:nnz],
                               np.asarray(fresh.c.values)[:nnz],
                               rtol=1e-4, atol=1e-5)
    print("Reuse path == fresh run (numeric phase only, no symbolic)")

    # -- 3. compression ----------------------------------------------------
    bc = compress_matrix(a)
    cf, cmrf, use = compression_decision(a, a, bc)
    print(f"compression on A*A: CF={cf:.2f} CMRF={cmrf:.2f} "
          f"applied={use} (rule: CF <= 0.85)")

    # -- 4. Pallas kernels (interpret mode on CPU) --------------------------
    c_nnz, c_idx, c_val = pallas_spgemm(a, p)
    np.testing.assert_allclose(
        np.asarray(c_val[0, : int(c_nnz[0])]),
        np.asarray(ap.c.values[: int(c_nnz[0])]), rtol=1e-4, atol=1e-5)
    print("Pallas symbolic+numeric kernels agree with the XLA path")
    print("OK")


if __name__ == "__main__":
    main()
