"""Training launcher: end-to-end driver with checkpoint/restart.

CPU-scale example (the real meshes need TPU hardware; everything else —
config, data, checkpointing, resume — is the production path):

    PYTHONPATH=src python -m repro.launch.train \
        --arch llama3.2-1b --smoke --steps 200 --batch 8 --seq 128

Fault tolerance: checkpoints every --ckpt-every steps (atomic writes),
auto-resumes from the latest checkpoint, and the counter-based data
pipeline skips ahead exactly. A step-deadline watchdog (runtime/) flags
stragglers; on a real cluster the runner requeues the job and this script
resumes losslessly.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import latest_step, restore, save
from repro.configs import get_config
from repro.data import SyntheticLMDataset, make_labels
from repro.models import init_params
from repro.models.sharding import NO_SHARDING
from repro.runtime.validate import TrainingDivergedError
from repro.runtime.watchdog import StepWatchdog
from repro.train import AdamWConfig, adamw_init, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--step-deadline-s", type=float, default=300.0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    rules = NO_SHARDING
    opt_cfg = AdamWConfig(lr=args.lr)
    data = SyntheticLMDataset(
        vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch
    )

    params = init_params(cfg, jax.random.PRNGKey(0))
    opt_state = adamw_init(params)
    start = 0
    if args.ckpt_dir:
        last = latest_step(args.ckpt_dir)
        if last is not None:
            (params, opt_state), _ = restore(
                args.ckpt_dir, last, (params, opt_state)
            )
            start = last
            print(f"resumed from step {start}")

    step_fn = jax.jit(
        make_train_step(cfg, rules, opt_cfg, num_microbatches=args.microbatches),
        donate_argnums=(0, 1),
    )
    watchdog = StepWatchdog(deadline_s=args.step_deadline_s)

    t_last = time.time()
    for step in range(start, args.steps):
        batch = make_labels(data.get_batch(step))
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        with watchdog.step(step):
            params, opt_state, metrics = step_fn(params, opt_state, batch)
        if (step + 1) % args.log_every == 0:
            loss = float(metrics["loss"])
            dt = (time.time() - t_last) / args.log_every
            t_last = time.time()
            print(f"step {step + 1}: loss={loss:.4f}  {dt * 1e3:.0f} ms/step")
            if not np.isfinite(loss):
                raise TrainingDivergedError(
                    f"loss diverged at step {step + 1}: {loss!r}")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            save(args.ckpt_dir, step + 1, (params, opt_state),
                 extra={"arch": args.arch})
    print("done")


if __name__ == "__main__":
    main()
