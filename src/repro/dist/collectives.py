"""Compressed collectives for bandwidth-bound mesh exchanges.

Distributed SpGEMM (and the LM substrate's data-parallel training loop) is
communication-bound exactly where the node-level kernel is bandwidth-bound,
so the wire format matters as much as the kernel. Two standard compressions:

* **int8 quantized all-reduce** (``compressed_psum``): operands are scaled
  per last-axis group to int8, all-gathered in the compressed format (4x
  fewer wire bytes than f32), and dequantize-reduced locally into the mean;
* **top-k sparsification** (``topk_compress``/``topk_decompress``): keep the
  k largest-magnitude entries plus a local residual, the error-feedback
  scheme of gradient-sparsification training.

Both are pure jittable functions, usable inside ``shard_map`` bodies.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def quantize_int8(x: jax.Array):
    """Per last-axis-group symmetric int8 quantization -> (q, scale)."""
    s = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0
    s = jnp.maximum(s, jnp.asarray(1e-12, x.dtype))
    q = jnp.clip(jnp.round(x / s), -127, 127).astype(jnp.int8)
    return q, s


def dequantize_int8(q: jax.Array, s: jax.Array, shape) -> jax.Array:
    """Inverse of ``quantize_int8``."""
    return (q.astype(s.dtype) * s).reshape(shape)


def compressed_psum(x: jax.Array, axis: str) -> jax.Array:
    """Mean over the mesh axis with int8 wire format.

    Each shard quantizes locally, all-gathers the int8 payload (+ one f32
    scale per group), and reduces after dequantizing — the collective moves
    ~4x fewer bytes than an f32 psum at ~1e-2 absolute error for unit-scale
    operands. Must run inside a ``shard_map`` over ``axis``.
    """
    q, s = quantize_int8(x)
    qg = jax.lax.all_gather(q, axis)  # (S, ...) int8 on the wire
    sg = jax.lax.all_gather(s, axis)
    return jnp.mean(qg.astype(s.dtype) * sg, axis=0)


def topk_compress(x: jax.Array, k: int):
    """Keep the k largest-|x| entries -> (values, flat_indices, residual).

    The residual is what error-feedback training folds into the next step:
    ``decompress(v, i) + residual == x`` exactly.
    """
    flat = x.reshape(-1)
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    vals = flat[idx]
    dec = jnp.zeros_like(flat).at[idx].set(vals)
    return vals, idx, (flat - dec).reshape(x.shape)


def topk_decompress(vals: jax.Array, idx: jax.Array, shape) -> jax.Array:
    """Scatter compressed entries back into a dense array of ``shape``."""
    n = int(np.prod(shape))
    return jnp.zeros((n,), vals.dtype).at[idx].set(vals).reshape(shape)
