"""gemma2-9b [dense] — arXiv:2408.00118, hf:google/gemma-2-9b.

42L, d_model=3584, 16 heads (GQA kv=8), d_ff=14336, vocab=256000,
alternating local(4096-window)/global attention, attn softcap 50,
final logit softcap 30, head_dim=256.

SpGEMM applicability: none (sliding-window = block-banded mask in the flash
kernel, not a sparse-matrix product).
long_500k: RUN as a hybrid-window cell — half the layers are 4096-window
local (bounded KV); global layers decode against the full 512k cache at
linear per-token cost. Recorded in DESIGN.md §Shape-cell skips.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    num_layers=42,
    d_model=3584,
    num_heads=16,
    num_kv_heads=8,
    d_ff=14_336,
    vocab_size=256_000,
    pattern=("local", "global"),
    head_dim=256,
    window=4_096,
    attn_softcap=50.0,
    final_softcap=30.0,
    tie_embeddings=True,
    act="gelu",  # gemma2 uses GeGLU
)

SMOKE = ModelConfig(
    name="gemma2-9b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    pattern=("local", "global"),
    head_dim=16,
    window=16,
    attn_softcap=50.0,
    final_softcap=30.0,
    tie_embeddings=True,
    act="gelu",
)

SKIP_SHAPES = {}
