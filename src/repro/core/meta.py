"""KKSPGEMM meta-algorithm (paper §3.3, Table 1).

The paper's selection constants are kept verbatim:
  * CPUs/KNLs: KKDENSE when k < 250 000, KKMEM otherwise.
  * GPUs:      KKMEM when average row flops < 256, KKLP otherwise.
  * ARS estimate for symbolic sizing: f_m / 8 ("every 8th multiplication
    reduces to the same nonzero").

TPU mapping (DESIGN.md §2): "dense" = dense-accumulator paths (XLA scatter /
Pallas dense-tile kernel), "sparse" = sorted-segment flat-parallel path,
"hash" = Pallas LP-hash kernel. The k cutoff doubles as a memory guard for
the O(m*k) dense accumulator.
"""
from __future__ import annotations

from repro.sparse.formats import CSR

DENSE_K_CUTOFF = 250_000  # paper §3.3
AVG_ROW_FLOPS_CUTOFF = 256  # paper §3.3 (GPU variant selection)
ARS_REDUCTION_GUESS = 8  # paper §3.3: every 8th multiply collides
DENSE_BYTES_BUDGET = 1 << 30  # 1 GiB guard for the XLA dense accumulator


def choose_method(a: CSR, b: CSR, stats: dict) -> str:
    """Return 'dense' or 'sparse' for the XLA numeric phase."""
    k = b.k
    dense_bytes = a.m * k * 4 * 2  # values + occupancy
    if k < DENSE_K_CUTOFF and dense_bytes <= DENSE_BYTES_BUDGET:
        return "dense"
    return "sparse"


def choose_kernel(a: CSR, b: CSR, stats: dict) -> str:
    """Return 'dense_acc' (KKMEM-position: thread-sequential, modest rows) or
    'flat_lp' (KKLP-position: flat-parallel for flop-heavy rows) for the
    Pallas path — the paper's GPU rule on average row flops."""
    fm = max(stats.get("fm", 0), 1)
    avg_row_flops = fm / max(a.m, 1)
    return "dense_acc" if avg_row_flops < AVG_ROW_FLOPS_CUTOFF else "flat_lp"


def estimate_ars(fm: int) -> int:
    """Average output row size estimate used before symbolic (paper §3.3)."""
    return max(fm // ARS_REDUCTION_GUESS, 1)
