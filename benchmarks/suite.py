"""Benchmark matrix suite: scaled-down structural analogues of the paper's
83 multiplications (UF-collection A*A + multigrid R*A*P triple products).

Sizes are chosen for the 1-core CPU container; the structure classes match
Table 3: power-law (RMAT/wikipedia-like), bounded-degree FEM (banded/
stencil), multigrid triple products, and uniform random.
"""
from __future__ import annotations

from repro.sparse import (
    banded_csr,
    galerkin_triple,
    random_csr,
    rmat_csr,
    stencil2d_csr,
)


def suite():
    """Yield (name, A, B) multiplication cases."""
    cases = []
    # A*A on power-law graphs (graph-analytics side of Table 3)
    for scale, ef in ((9, 6), (10, 8)):
        g = rmat_csr(scale, ef, seed=scale)
        cases.append((f"rmat{scale}_AxA", g, g))
    # A*A on FEM-like bounded-degree matrices
    b = banded_csr(20_000, 6, seed=3)
    cases.append(("banded20k_AxA", b, b))
    s = stencil2d_csr(96, 96)
    cases.append(("stencil96_AxA", s, s))
    # uniform random rectangular
    cases.append(
        ("rand8k_AxB", random_csr(8_192, 8_192, 8.0, 11),
         random_csr(8_192, 8_192, 8.0, 12))
    )
    # multigrid triple products (24/83 of the paper's cases)
    r, a, p = galerkin_triple(64, 64, 4)
    cases.append(("mg64_AxP", a, p))
    cases.append(("mg64_RxA", r, a))
    r2, a2, p2 = galerkin_triple(96, 96, 8)
    cases.append(("mg96_AxP", a2, p2))
    return cases
