"""Unified metrics registry: log-bucketed histograms + gauges + counters.

One registry answers three kinds of question the counters alone could not:

  * **Distributions** — ``Histogram`` buckets latencies on a base-2 log scale
    (1us .. ~18h) and reports p50/p95/p99 by in-bucket interpolation, clamped
    to the exact observed min/max so tails are never invented. This replaces
    the serving tier's lone EWMA: ``SparseService`` keeps real step- and
    request-latency distributions, and ``obs.trace`` spans feed per-phase /
    per-kernel histograms (``plan.build``, ``numeric.dispatch``,
    ``numeric.dispatch[pallas]``, ...).
  * **Gauges** — live values read at export time (a plain number or a
    zero-arg callable), e.g. ``Heartbeat.write_errors`` surfaced mid-run
    instead of only at ``stop()``.
  * **Counters** — the nine existing ``core.telemetry`` counters join the
    same registry view (live references, not copies), so one exporter call
    captures the whole instrumentation surface.

Exporters: ``to_jsonl()`` (one JSON object per line — the archival format)
and ``to_prometheus()`` (text exposition format, names sanitized to
``repro_*``) — both pure renderings, no side effects on the metrics.

Histogram observation is only ever driven from code that already decided to
measure (an enabled span, the serving tier's step loop), so the registry
adds nothing to the tracing-off replay hot path.
"""
from __future__ import annotations

import bisect
import json
import math
import re
from typing import Any, Callable

# Base-2 log bucket upper bounds, in seconds: 1us * 2^i. 36 buckets reach
# ~19h; one underflow bucket below 1us and one overflow bucket above the top.
_BUCKET_BOUNDS: list[float] = [1e-6 * (2.0 ** i) for i in range(37)]


class Histogram:
    """Log-bucketed latency histogram with interpolated percentiles."""

    __slots__ = ("name", "counts", "count", "sum", "min", "max")

    def __init__(self, name: str):
        self.name = name
        self.counts = [0] * (len(_BUCKET_BOUNDS) + 1)
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, value: float) -> None:
        v = float(value)
        self.counts[bisect.bisect_left(_BUCKET_BOUNDS, v)] += 1
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def percentile(self, q: float) -> float:
        """Interpolated q-th percentile (q in [0, 100]); NaN when empty.

        Linear interpolation inside the owning bucket, clamped to the exact
        observed [min, max] — a single observation reports itself exactly,
        and all-zero latencies (injected test clocks) report 0, not 1us.
        """
        if self.count == 0:
            return float("nan")
        rank = (q / 100.0) * self.count
        seen = 0.0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if seen + c >= rank:
                lo = 0.0 if i == 0 else _BUCKET_BOUNDS[i - 1]
                hi = (_BUCKET_BOUNDS[i] if i < len(_BUCKET_BOUNDS)
                      else max(self.max, lo))
                frac = (rank - seen) / c
                est = lo + (hi - lo) * frac
                return float(min(max(est, self.min), self.max))
            seen += c
        return float(self.max)

    def summary(self) -> dict:
        """{count, sum, mean, p50, p95, p99, min, max} — the JSONL row body."""
        empty = self.count == 0
        return {
            "count": self.count,
            "sum": self.sum,
            "mean": (self.sum / self.count) if not empty else float("nan"),
            "p50": self.percentile(50.0),
            "p95": self.percentile(95.0),
            "p99": self.percentile(99.0),
            "min": self.min if not empty else float("nan"),
            "max": self.max if not empty else float("nan"),
        }


class Gauge:
    """A live value: a number set with ``set()`` or a zero-arg callable
    (read at export time — the liveness is the point)."""

    __slots__ = ("name", "_value", "_fn")

    def __init__(self, name: str, fn: Callable[[], float] | None = None):
        self.name = name
        self._value = 0.0
        self._fn = fn

    def set(self, value: float) -> None:
        self._fn = None
        self._value = float(value)

    def read(self) -> float:
        if self._fn is not None:
            return float(self._fn())
        return self._value


def _prom_name(name: str) -> str:
    return "repro_" + re.sub(r"[^a-zA-Z0-9_]", "_", name)


class MetricsRegistry:
    """Histograms + gauges + the telemetry counters, one export surface."""

    def __init__(self, name: str = "default"):
        self.name = name
        self._hists: dict[str, Histogram] = {}
        self._gauges: dict[str, Gauge] = {}

    # -- recording -----------------------------------------------------

    def histogram(self, name: str) -> Histogram:
        h = self._hists.get(name)
        if h is None:
            h = self._hists[name] = Histogram(name)
        return h

    def observe(self, name: str, seconds: float) -> None:
        self.histogram(name).observe(seconds)

    def gauge(self, name: str,
              fn: Callable[[], float] | None = None) -> Gauge:
        """Get-or-create a gauge; ``fn`` (re)binds a live read callback."""
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge(name, fn)
        elif fn is not None:
            g._fn = fn
        return g

    def set_gauge(self, name: str, value: float) -> None:
        self.gauge(name).set(value)

    # -- views ---------------------------------------------------------

    def counters(self) -> dict[str, dict[str, int]]:
        """Plain-dict copy of the nine ``core.telemetry`` counters."""
        from repro.core import telemetry  # lazy: telemetry imports core

        return telemetry.snapshot()

    def snapshot(self) -> dict:
        return {
            "counters": self.counters(),
            "histograms": {n: h.summary()
                           for n, h in sorted(self._hists.items())},
            "gauges": {n: g.read() for n, g in sorted(self._gauges.items())},
        }

    # -- exporters -----------------------------------------------------

    def to_jsonl(self) -> str:
        """One JSON object per line: counters, histogram summaries, gauges."""
        lines = []
        for group, keys in sorted(self.counters().items()):
            for key, value in sorted(keys.items()):
                lines.append(json.dumps(
                    {"type": "counter", "group": group, "key": key,
                     "value": value}))
        for name, h in sorted(self._hists.items()):
            lines.append(json.dumps(
                {"type": "histogram", "name": name, **h.summary()}))
        for name, g in sorted(self._gauges.items()):
            lines.append(json.dumps(
                {"type": "gauge", "name": name, "value": g.read()}))
        return "\n".join(lines)

    def to_prometheus(self) -> str:
        """Prometheus text exposition: counters as ``repro_<group>_total``
        (labelled by key), histograms as summary quantiles + _count/_sum,
        gauges as plain gauges."""
        out = []
        for group, keys in sorted(self.counters().items()):
            pname = _prom_name(group) + "_total"
            out.append(f"# TYPE {pname} counter")
            for key, value in sorted(keys.items()):
                out.append(f'{pname}{{key="{key}"}} {value}')
        for name, h in sorted(self._hists.items()):
            pname = _prom_name(name) + "_seconds"
            s = h.summary()
            out.append(f"# TYPE {pname} summary")
            for q, label in ((50.0, "0.5"), (95.0, "0.95"), (99.0, "0.99")):
                out.append(
                    f'{pname}{{quantile="{label}"}} {h.percentile(q):.9g}')
            out.append(f"{pname}_sum {s['sum']:.9g}")
            out.append(f"{pname}_count {s['count']}")
        for name, g in sorted(self._gauges.items()):
            pname = _prom_name(name)
            out.append(f"# TYPE {pname} gauge")
            out.append(f"{pname} {g.read():.9g}")
        return "\n".join(out) + "\n"

    def reset(self) -> None:
        self._hists.clear()
        self._gauges.clear()


_DEFAULT = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    """The process-wide registry spans and gauges feed by default."""
    return _DEFAULT


def observe(name: str, seconds: float) -> None:
    """Record one latency into the default registry's ``name`` histogram."""
    _DEFAULT.observe(name, seconds)


def reset_metrics() -> None:
    """Clear the default registry (tests)."""
    _DEFAULT.reset()
