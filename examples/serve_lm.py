"""Batched serving example: prefill a prompt batch, then greedy decode with
static-shape KV caches (ring buffers on local-attention layers).

    PYTHONPATH=src python examples/serve_lm.py --arch gemma2-9b --steps 24
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.serve import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-9b")
    ap.add_argument("--steps", type=int, default=24)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)  # CPU-scale weights
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(params, cfg,
                         max_len=args.prompt_len + args.steps)

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)
    out = engine.generate(prompts, steps=args.steps)
    print(f"arch={cfg.name}  batch={args.batch}  "
          f"prompt={args.prompt_len}  generated={out.shape[1]} tokens")
    for row in np.asarray(out)[:2]:
        print("  tokens:", row[:16].tolist(), "...")
    assert out.shape == (args.batch, args.steps)
    print("OK")


if __name__ == "__main__":
    main()
