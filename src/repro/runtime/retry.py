"""Bounded, jittered retry for replay/serving call sites.

The serving-tier failure model (ROADMAP "The failure model") distinguishes
*transient* failures — a straggling device, an injected kernel fault, an
OSError from a liveness write — from *deterministic* ones: a corrupted
operand or a plan/operand mismatch will fail identically on every attempt,
so retrying it only burns the latency budget. ``retry_call`` encodes that
split: typed validation errors (``SpgemmInputError``, ``PlanMismatchError``
by default) re-raise immediately; everything else retries under jittered
exponential backoff until the bound, then gives up with a typed
``RetryExhaustedError`` carrying the attempt count and last error.

Determinism: jitter comes from ``random.Random(seed)``, not global state,
so a chaos run's retry schedule replays exactly. ``sleep=`` is injectable
so tests assert the schedule without real waiting.
"""
from __future__ import annotations

import random
import time
from typing import Callable

from repro.runtime.validate import (PlanMismatchError, SpgemmError,
                                    SpgemmInputError)


class RetryExhaustedError(SpgemmError, RuntimeError):
    """All retry attempts failed; ``last_error`` / ``__cause__`` carry the
    final failure and ``attempts`` how many times the call ran."""

    def __init__(self, msg: str, attempts: int, last_error: BaseException):
        super().__init__(msg)
        self.attempts = attempts
        self.last_error = last_error


def backoff_schedule(retries: int, *, base_delay_s: float = 0.05,
                     max_delay_s: float = 2.0, jitter: float = 0.5,
                     seed: int = 0) -> list[float]:
    """The deterministic delay sequence ``retry_call`` would sleep.

    delay(i) = min(base * 2**i, max) * (1 + U[-jitter, +jitter]); exposed
    separately so tests and capacity planning can inspect it.
    """
    rng = random.Random(seed)
    out = []
    for attempt in range(retries):
        d = min(base_delay_s * (2.0 ** attempt), max_delay_s)
        out.append(d * (1.0 + rng.uniform(-jitter, jitter)))
    return out


def retry_call(fn: Callable, *args,
               retries: int = 3,
               base_delay_s: float = 0.05,
               max_delay_s: float = 2.0,
               jitter: float = 0.5,
               retry_on: tuple = (Exception,),
               no_retry_on: tuple = (SpgemmInputError, PlanMismatchError),
               seed: int = 0,
               sleep: Callable[[float], None] = time.sleep,
               on_retry: Callable | None = None,
               label: str | None = None,
               **kwargs):
    """Call ``fn(*args, **kwargs)``; retry transient failures up to
    ``retries`` extra attempts with jittered exponential backoff.

    ``no_retry_on`` wins over ``retry_on``: deterministic typed input/plan
    errors propagate on the first attempt. ``on_retry(attempt, exc, delay)``
    is invoked before each backoff sleep (telemetry hook). Raises
    ``RetryExhaustedError`` from the last failure once the bound is hit.

    Every attempt, every taken backoff, and every give-up is recorded in
    ``telemetry.RETRY_COUNTS`` keyed by ``label`` (default: the callable's
    ``__name__``), so serving loops can report retry rates without wrapping
    the hook: ``"<label>:attempt"`` / ``"<label>:retry"`` /
    ``"<label>:giveup"``.
    """
    from repro.core.telemetry import RETRY_COUNTS  # lazy: telemetry is core

    if retries < 0:
        from repro.runtime.validate import SpgemmConfigError  # cycle-free
        raise SpgemmConfigError(f"retries must be >= 0, got {retries}")
    if label is None:
        label = getattr(fn, "__name__", "anon")
    delays = backoff_schedule(retries, base_delay_s=base_delay_s,
                              max_delay_s=max_delay_s, jitter=jitter,
                              seed=seed)
    last: BaseException | None = None
    for attempt in range(retries + 1):
        try:
            RETRY_COUNTS[f"{label}:attempt"] += 1
            return fn(*args, **kwargs)
        except no_retry_on:
            raise
        except retry_on as e:
            last = e
            if attempt >= retries:
                break
            RETRY_COUNTS[f"{label}:retry"] += 1
            delay = delays[attempt]
            if on_retry is not None:
                on_retry(attempt, e, delay)
            sleep(delay)
    RETRY_COUNTS[f"{label}:giveup"] += 1
    err = RetryExhaustedError(
        f"gave up after {retries + 1} attempts: {last!r}",
        attempts=retries + 1, last_error=last)
    from repro.obs import recorder, trace  # lazy: give-up path only

    recorder.note_error(err, site="retry", label=label,
                        attempts=retries + 1,
                        trace_id=trace.current_trace_id())
    raise err from last
