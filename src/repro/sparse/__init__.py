"""Static-shape sparse matrix containers and generators for the SpGEMM framework.

All containers are JAX pytrees with *static* capacity: XLA cannot allocate
dynamically, so every sparse matrix carries an ``nnz_cap`` >= nnz and a
padded tail. Validity is derived from ``indptr`` (CSR) or ``row_nnz`` (ELL),
never from sentinel values, so padded slots may hold any index.
"""
from repro.sparse.formats import CSR, ELL, BSR, csr_to_ell, csr_row_ids, ell_to_csr
from repro.sparse.generators import (
    random_csr,
    rmat_csr,
    banded_csr,
    stencil2d_csr,
    aggregation_prolongator,
    galerkin_triple,
)
from repro.sparse.oracle import (
    dense_spgemm_oracle,
    gustavson_ell_structure,
    gustavson_numpy,
)

__all__ = [
    "CSR",
    "ELL",
    "BSR",
    "csr_to_ell",
    "ell_to_csr",
    "csr_row_ids",
    "random_csr",
    "rmat_csr",
    "banded_csr",
    "stencil2d_csr",
    "aggregation_prolongator",
    "galerkin_triple",
    "dense_spgemm_oracle",
    "gustavson_ell_structure",
    "gustavson_numpy",
]
