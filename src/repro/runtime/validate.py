"""Typed validation layer for the SpGEMM execution stack.

The meta-algorithm's dispatch surface (dense_acc / flat_lp / segsum / XLA
fallback, static < fitted < measured precedence) means a corrupted operand or
a plan replayed against the wrong structure can fail far from its cause — as
garbage values or a cryptic XLA shape error deep inside a jitted replay.
This module converts those failure modes into a *typed* taxonomy raised at
the entry point that received the bad input:

  ``SpgemmInputError``     — a CSR operand violates its invariants
                             (non-monotone ``indptr``, out-of-bounds column
                             indices, non-finite values, mismatched array
                             lengths, negative shape).
  ``CapacityOverflowError`` — a static bucketed capacity is exceeded
                             (``indptr[-1] > nnz_cap``, repad truncation).
  ``PlanMismatchError``    — a pinned plan replayed against incompatible
                             operands (wrong value-buffer lengths, or a
                             structure-key recheck that no longer matches).
  ``KernelFallbackError``  — a kernel failed and the degradation ladder was
                             told to raise (or ran out of rungs): the typed
                             give-up of ``kernels/ops.py`` /
                             ``core/executor.py``.

All taxonomy classes subclass ``SpgemmError`` and ``ValueError`` /
``RuntimeError`` as appropriate, so pre-taxonomy ``except ValueError``
call sites keep working.

Validation modes (``spgemm(validate=...)``, ``ReuseExecutor.pin/apply``,
``ShardedReuseExecutor``):

  "off"    — the default: zero added work, dispatch-identical to the
             unvalidated path (no extra retraces, hashes, or host syncs).
  "host"   — pull operand structure to the host and check every invariant
             with exact indices in the error message. O(nnz) host work per
             validated call; the thorough mode for debugging and chaos CI.
  "device" — one jitted reduction computes a violation bitmask on device and
             a single scalar sync brings back the verdict. O(nnz) device
             work, O(1) host transfer; the mode for big operands where a
             host pull would dominate.

``validate=None`` resolves through the ``REPRO_VALIDATE`` environment
variable (chaos CI forces ``REPRO_VALIDATE=host``), else "off".
``benchmarks.run --bench guard`` measures the per-mode overhead so it is
reported, not hidden.
"""
from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

VALIDATE_MODES = ("off", "host", "device")

# Environment override consulted when a caller passes validate=None: chaos CI
# sets REPRO_VALIDATE=host to force validation across a whole test run
# without touching call sites.
VALIDATE_ENV_VAR = "REPRO_VALIDATE"


class SpgemmError(Exception):
    """Base of the typed SpGEMM failure taxonomy."""


class SpgemmInputError(SpgemmError, ValueError):
    """A CSR operand violates its structural or numeric invariants."""


class CapacityOverflowError(SpgemmError, ValueError):
    """A static bucketed capacity (nnz_cap / fm_cap) was exceeded."""


class PlanMismatchError(SpgemmError, ValueError):
    """A pinned plan was replayed against incompatible operands."""


class KernelFallbackError(SpgemmError, RuntimeError):
    """A kernel failed and the degradation ladder gave up (or was told to
    raise instead of degrading). ``__cause__`` carries the original error."""


class AdmissionRejected(SpgemmError, RuntimeError):
    """The serving tier refused a request at the door: the bounded admission
    queue is full (backpressure) or the deadline is already infeasible given
    the current backlog. Raised/attached *before* any device work — an
    overloaded service sheds typed, it never queues unboundedly or drops
    silently (see ``serve.spgemm_service``)."""


class DeadlineExceeded(SpgemmError, TimeoutError):
    """An admitted request's deadline expired before its batch dispatched:
    shed from the queue with this typed verdict instead of burning device
    time on an answer nobody is waiting for. Subclasses ``TimeoutError`` so
    generic timeout handling at call sites composes."""


class SpgemmConfigError(SpgemmError, ValueError):
    """A caller passed an invalid knob, mode, name, or option combination
    (unknown kernel/policy/placement strings, malformed config fields,
    out-of-range parameters). The catch-all member for misuse of an API
    surface, as opposed to bad *data* (``SpgemmInputError``) or bad
    *state* (``PlanMismatchError``)."""


class TrainingDivergedError(SpgemmError, RuntimeError):
    """The training loop's loss went non-finite: the typed, intentional
    abort of ``launch/train.py`` (distinct from ``KernelFallbackError``,
    which is the ladder giving up on a single kernel call)."""


def resolve_mode(mode: str | None) -> str:
    """Normalize a ``validate=`` argument to a concrete mode.

    ``None`` defers to ``$REPRO_VALIDATE`` (else "off"); anything outside
    ``VALIDATE_MODES`` is a loud ``SpgemmConfigError`` — a typo'd mode
    silently validating nothing would defeat the whole layer.
    """
    if mode is None:
        mode = os.environ.get(VALIDATE_ENV_VAR, "off") or "off"
    if mode not in VALIDATE_MODES:
        raise SpgemmConfigError(
            f"unknown validate mode {mode!r}; expected one of "
            f"{VALIDATE_MODES}")
    return mode


# --------------------------------------------------------------------------
# CSR invariant checks
# --------------------------------------------------------------------------

# Violation bits shared by the host and device checkers, so both modes raise
# identical typed errors for identical corruptions.
_BIT_INDPTR = 1  # indptr[0] != 0, negative row size, or negative nnz
_BIT_OVERFLOW = 2  # indptr[-1] > nnz_cap
_BIT_COL_OOB = 4  # live column index outside [0, k)
_BIT_NONFINITE = 8  # live value is NaN or +/-Inf


@partial(jax.jit, static_argnames=("k", "check_finite"))
def _csr_flags_device(indptr, indices, values, k: int, check_finite: bool):
    """Device-side invariant sweep -> int32 violation bitmask (one scalar).

    Shapes are already capacity-bucketed by the callers, so this compiles
    once per bucket like every other jitted stage.
    """
    nnz_cap = indices.shape[0]
    nnz = indptr[-1]
    d = indptr[1:] - indptr[:-1]
    bad_indptr = (indptr[0] != 0) | jnp.any(d < 0) | (nnz < 0)
    overflow = nnz > nnz_cap
    live = jnp.arange(nnz_cap, dtype=jnp.int32) < jnp.clip(nnz, 0, nnz_cap)
    col_oob = jnp.any(live & ((indices < 0) | (indices >= k)))
    flags = (bad_indptr.astype(jnp.int32) * _BIT_INDPTR
             | overflow.astype(jnp.int32) * _BIT_OVERFLOW
             | col_oob.astype(jnp.int32) * _BIT_COL_OOB)
    if check_finite:
        nonfinite = jnp.any(live & ~jnp.isfinite(values))
        flags = flags | nonfinite.astype(jnp.int32) * _BIT_NONFINITE
    return flags


def _raise_for_flags(flags: int, name: str, mat) -> None:
    if flags & _BIT_INDPTR:
        raise SpgemmInputError(
            f"{name}: corrupted indptr (must start at 0 and be "
            f"non-decreasing; m={mat.m}, nnz_cap={mat.nnz_cap})")
    if flags & _BIT_OVERFLOW:
        raise CapacityOverflowError(
            f"{name}: indptr[-1] exceeds the nnz capacity "
            f"{mat.nnz_cap} — the bucketed value buffer would overflow")
    if flags & _BIT_COL_OOB:
        raise SpgemmInputError(
            f"{name}: live column index outside [0, {mat.k})")
    if flags & _BIT_NONFINITE:
        raise SpgemmInputError(f"{name}: live value is NaN or Inf")


def check_csr(mat, mode: str = "host", name: str = "operand"):
    """Validate a CSR operand under ``mode``; returns ``mat`` unchanged.

    Metadata checks (shape sanity, array-length agreement) run on the host
    in both modes — they read static shapes only. Content checks (indptr
    monotonicity, column bounds, value finiteness) run per the mode. Raises
    ``SpgemmInputError`` / ``CapacityOverflowError``; mode "off" is a no-op.
    """
    mode = resolve_mode(mode)
    if mode == "off":
        return mat
    shape = tuple(mat.shape)
    if len(shape) != 2 or any(int(s) < 0 for s in shape):
        raise SpgemmInputError(
            f"{name}: shape must be a non-negative (m, k) pair, got {shape}")
    m, k = (int(s) for s in shape)
    if mat.indptr.shape[0] != m + 1:
        raise SpgemmInputError(
            f"{name}: len(indptr) == {mat.indptr.shape[0]} but shape[0]+1 "
            f"== {m + 1}")
    if mat.indices.shape[0] != mat.values.shape[0]:
        raise SpgemmInputError(
            f"{name}: len(indices) == {mat.indices.shape[0]} != "
            f"len(values) == {mat.values.shape[0]}")
    check_finite = bool(jnp.issubdtype(jnp.asarray(mat.values).dtype,
                                       jnp.floating))
    if mode == "device":
        flags = int(_csr_flags_device(mat.indptr, mat.indices, mat.values,
                                      k=k, check_finite=check_finite))
        _raise_for_flags(flags, name, mat)
        return mat
    # host mode: numpy pulls, exact first-violation indices in the message
    ip = np.asarray(mat.indptr)
    if int(ip[0]) != 0:
        raise SpgemmInputError(f"{name}: indptr[0] == {int(ip[0])}, want 0")
    d = np.diff(ip)
    bad = np.nonzero(d < 0)[0]
    if bad.size:
        i = int(bad[0])
        raise SpgemmInputError(
            f"{name}: indptr not monotone at row {i} "
            f"({int(ip[i])} -> {int(ip[i + 1])})")
    nnz = int(ip[-1])
    if nnz > mat.nnz_cap:
        raise CapacityOverflowError(
            f"{name}: indptr[-1] == {nnz} exceeds nnz_cap == {mat.nnz_cap}")
    idx = np.asarray(mat.indices)[:nnz]
    bad = np.nonzero((idx < 0) | (idx >= k))[0]
    if bad.size:
        i = int(bad[0])
        raise SpgemmInputError(
            f"{name}: column index {int(idx[i])} at slot {i} outside "
            f"[0, {k})")
    if check_finite:
        vals = np.asarray(mat.values)[:nnz]
        bad = np.nonzero(~np.isfinite(vals))[0]
        if bad.size:
            raise SpgemmInputError(
                f"{name}: non-finite value at slot {int(bad[0])} "
                f"({vals[int(bad[0])]!r})")
    return mat


# --------------------------------------------------------------------------
# Plan <-> operand compatibility (replay-time checks)
# --------------------------------------------------------------------------


class PlanGuard:
    """Pin-time digest of a plan's operand requirements.

    Built once when an executor pins a plan with validation on (one
    device->host sync of two scalars), so every subsequent ``apply`` pays
    only O(1) host comparisons — the validated replay path must not add
    per-call device syncs or rehashes.
    """

    def __init__(self, plan):
        self.nnz_cap = int(plan.indices.shape[0])
        # operand requirements come from LIVE products only: padding slots
        # were clamped to the build-time bucketed cap at expansion (their
        # sentinel seg_ids drop them from the scatter), so counting them
        # would reject legitimate replays with unrepadded value buffers
        seg = np.asarray(plan.seg_ids)
        live = seg < self.nnz_cap
        asl = np.asarray(plan.a_slot_s)[live]
        bsl = np.asarray(plan.b_slot_s)[live]
        self.a_req = int(asl.max()) + 1 if asl.size else 0
        self.b_req = int(bsl.max()) + 1 if bsl.size else 0
        ip = np.asarray(plan.indptr)
        if int(ip[0]) != 0 or np.any(np.diff(ip) < 0):
            raise SpgemmInputError(
                "plan: corrupted indptr (must start at 0 and be "
                "non-decreasing) — refusing to pin")
        if int(ip[-1]) > self.nnz_cap:
            raise CapacityOverflowError(
                f"plan: indptr[-1] == {int(ip[-1])} exceeds the plan's "
                f"nnz_cap == {self.nnz_cap}")

    def _check_one(self, values, req: int, side: str, mode: str,
                   batched: bool) -> None:
        want_ndim = values.ndim in (1, 2) if batched else values.ndim == 1
        if not want_ndim:
            raise PlanMismatchError(
                f"{side} values must be "
                f"{'(batch, nnz) or (nnz,)' if batched else '1-D (nnz,)'}, "
                f"got shape {tuple(values.shape)}")
        if values.shape[-1] < req:
            raise PlanMismatchError(
                f"{side} value buffer has {values.shape[-1]} slots but the "
                f"pinned plan gathers up to slot {req - 1} — replaying a "
                f"plan against operands from a different structure?")
        if mode == "device" and jnp.issubdtype(values.dtype, jnp.floating):
            if not bool(jnp.all(jnp.isfinite(values))):
                raise SpgemmInputError(
                    f"{side} values contain NaN/Inf (device validation)")

    def check_values(self, a_values, b_values, mode: str,
                     batched: bool = False) -> None:
        """Replay-time operand check: shapes/lengths against the pinned
        requirements (``PlanMismatchError``), plus a device finiteness sweep
        in "device" mode (``SpgemmInputError``)."""
        self._check_one(a_values, self.a_req, "A", mode, batched)
        self._check_one(b_values, self.b_req, "B", mode, batched)


def check_plan_compat(pinned_key: str | None, a, b, fm_cap: int,
                      pad_policy: str) -> None:
    """Full structure-key recheck: do these operands still hash to the plan?

    Used by ``ReuseExecutor.check_compat`` when the caller holds the CSR
    operands (not just value buffers). Costs one ``structure_key`` digest —
    opt-in, and the HASH_COUNTS bump is the documented price.
    """
    from repro.core.plan_cache import structure_key  # cycle-free late import

    if pinned_key is None:
        raise PlanMismatchError(
            "this executor has no pinned structure key (constructed from a "
            "bare plan); build it with ReuseExecutor.pin/from_matrices to "
            "enable the structure-key recheck")
    key = structure_key(a, b, fm_cap, pad_policy)
    if key != pinned_key:
        raise PlanMismatchError(
            f"operand structure key {key[:12]}... does not match the pinned "
            f"plan's {pinned_key[:12]}... — the plan would replay against a "
            f"different sparsity structure")
