"""The paper's headline application scenario: multigrid setup with
structure reuse (§4, Reuse case).

An AMG-style solver recomputes A_coarse = R*A*P every time matrix VALUES
change (nonlinear solves, time stepping) while the STRUCTURE stays fixed.
Two-phase SpGEMM pays symbolic once, then replays the numeric phase.

    PYTHONPATH=src python examples/multigrid_reuse.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import numeric_reuse, spgemm
from repro.sparse import CSR, galerkin_triple


def main():
    r, a, p = galerkin_triple(96, 96, agg_size=4)
    print(f"fine grid: {a.shape[0]} dofs, nnz={int(a.nnz())}")

    # --- setup (NoReuse): symbolic + numeric, plans cached ---------------
    t0 = time.perf_counter()
    ap = spgemm(a, p, method="sparse")
    rap = spgemm(r, ap.c, method="sparse")
    jax.block_until_ready(rap.c.values)
    setup_s = time.perf_counter() - t0
    print(f"setup (symbolic+numeric): {setup_s * 1e3:.1f} ms  "
          f"A_coarse nnz={rap.stats['nnz_c']}")

    # --- time stepping: values change, structure fixed (Reuse) -----------
    rng = np.random.default_rng(0)
    reuse_times = []
    for step in range(5):
        new_vals = jnp.asarray(rng.standard_normal(a.nnz_cap), jnp.float32)
        a_t = CSR(a.indptr, a.indices, new_vals, a.shape)
        t0 = time.perf_counter()
        ap_vals = numeric_reuse(ap.plan, a_t.values, p.values)
        rap_vals = numeric_reuse(rap.plan, r.values, ap_vals)
        jax.block_until_ready(rap_vals)
        reuse_times.append(time.perf_counter() - t0)
    reuse_ms = float(np.mean(reuse_times[1:])) * 1e3
    print(f"reuse numeric-only per timestep: {reuse_ms:.1f} ms  "
          f"({setup_s * 1e3 / reuse_ms:.1f}x faster than setup)")

    # validate one reuse iteration against a fresh run
    fresh = spgemm(CSR(a.indptr, a.indices, a_t.values, a.shape), p).c
    nnz = int(fresh.nnz())
    np.testing.assert_allclose(np.asarray(ap_vals)[:nnz],
                               np.asarray(fresh.values)[:nnz],
                               rtol=1e-4, atol=1e-5)
    print("reuse result validated. OK")


if __name__ == "__main__":
    main()
