"""Deterministic fault injection for the SpGEMM execution stack.

Two mechanisms, both driven by ``tests/test_faults.py`` (the chaos suite):

**Data faults** — a registry of named, seeded corruptions applied to a CSR
operand (``inject_csr``): scrambled ``indptr``, out-of-bounds or negative
column indices, NaN-poisoned values, a bucketed-capacity overflow, and a
length mismatch. Each ``FaultSpec`` records the typed error class the
validation layer must raise for it, so the chaos suite is table-driven:
every registered fault either raises its typed error (validation on) or the
stack degrades to a bitwise-correct XLA-reference result (validation off) —
never silent wrong values.

**Failpoints** — named sites inside kernel dispatch (``kernel:pallas``,
``kernel:flat_lp``, ...) that raise ``InjectedFault`` when armed, to
exercise the degradation ladder without depending on a real lowering
failure. ``InjectedFault`` deliberately subclasses plain ``RuntimeError``,
*not* the typed taxonomy: the ladder must treat it like any unexpected
kernel explosion. Arm with the ``failpoint(site)`` context manager (or
``arm``/``disarm``); ``reset_failpoints()`` is called by the test autouse
fixture so an armed site can never leak across tests.

Everything here is deterministic: corruptions derive from
``np.random.default_rng(seed)`` and failpoints are explicit host-side
state — a chaos run replays identically every time.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Callable

import jax.numpy as jnp
import numpy as np

# --------------------------------------------------------------------------
# Failpoints
# --------------------------------------------------------------------------


class InjectedFault(RuntimeError):
    """Raised at an armed failpoint. Intentionally OUTSIDE the typed
    SpgemmError taxonomy: dispatch sites must handle it as an unexpected
    kernel failure (degradation ladder), not as a validated input error."""


_FAILPOINTS: set[str] = set()


def arm(site: str) -> None:
    """Arm ``site``: the next ``check(site)`` there raises InjectedFault."""
    _FAILPOINTS.add(site)


def disarm(site: str) -> None:
    _FAILPOINTS.discard(site)


def armed(site: str) -> bool:
    return site in _FAILPOINTS


def check(site: str) -> None:
    """Called by instrumented dispatch sites; raises when the site is armed.

    A no-op set lookup when nothing is armed — cheap enough to live on the
    hot path unconditionally.
    """
    if site in _FAILPOINTS:
        raise InjectedFault(f"injected fault at failpoint {site!r}")


def reset_failpoints() -> None:
    """Disarm every failpoint (test-fixture hygiene)."""
    _FAILPOINTS.clear()


@contextlib.contextmanager
def failpoint(site: str):
    """Arm ``site`` for the duration of the with-block, then disarm."""
    arm(site)
    try:
        yield
    finally:
        disarm(site)


# --------------------------------------------------------------------------
# Data-fault registry
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One registered corruption.

    name:        registry key (and chaos-test parametrize id).
    kind:        "data" (corrupts a CSR) | "kernel" (failpoint site) |
                 "cache" (plan-cache manipulation).
    expects:     the typed error class validation must raise for it, or
                 None when the fault is not a validation concern (kernel/
                 cache faults surface through the ladder / re-resolution).
    description: one line for humans and test output.
    fn:          data faults: (csr, rng) -> corrupted csr.
    site:        kernel faults: the failpoint site string.
    """

    name: str
    kind: str
    expects: type | None
    description: str
    fn: Callable | None = None
    site: str | None = None


def _rebuild(csr, indptr=None, indices=None, values=None):
    """A copy of ``csr`` with selected arrays replaced, skipping
    ``from_arrays`` validation (we are deliberately building bad CSRs)."""
    from repro.sparse.formats import CSR

    return CSR.from_arrays(
        csr.indptr if indptr is None else indptr,
        csr.indices if indices is None else indices,
        csr.values if values is None else values,
        csr.shape,
        validate=False,
    )


def _corrupt_indptr(csr, rng):
    ip = np.asarray(csr.indptr).copy()
    # break monotonicity at a random interior row
    i = int(rng.integers(1, max(len(ip) - 1, 2)))
    ip[i] = ip[min(i + 1, len(ip) - 1)] + 7
    return _rebuild(csr, indptr=ip)


def _oob_col_index(csr, rng):
    idx = np.asarray(csr.indices).copy()
    nnz = int(np.asarray(csr.indptr)[-1])
    slot = int(rng.integers(0, max(nnz, 1)))
    idx[slot] = csr.k + 3  # past the column bound
    return _rebuild(csr, indices=idx)


def _negative_col_index(csr, rng):
    idx = np.asarray(csr.indices).copy()
    nnz = int(np.asarray(csr.indptr)[-1])
    idx[int(rng.integers(0, max(nnz, 1)))] = -1
    return _rebuild(csr, indices=idx)


def _nan_values(csr, rng):
    vals = np.asarray(csr.values).copy()
    nnz = int(np.asarray(csr.indptr)[-1])
    vals[int(rng.integers(0, max(nnz, 1)))] = np.nan
    return _rebuild(csr, values=vals)


def _capacity_overflow(csr, rng):
    # keep indptr monotone but claim more live entries than the buffer holds
    ip = np.asarray(csr.indptr).copy()
    ip[-1] = csr.nnz_cap + 8
    return _rebuild(csr, indptr=ip)


def _length_mismatch(csr, rng):
    # drop the last value slot so len(indices) != len(values)
    vals = jnp.asarray(np.asarray(csr.values)[:-1])
    return _rebuild(csr, values=vals)


def _build_registry() -> dict[str, FaultSpec]:
    from repro.runtime.validate import CapacityOverflowError, SpgemmInputError

    specs = [
        FaultSpec("corrupt_indptr", "data", SpgemmInputError,
                  "non-monotone indptr at a random interior row",
                  fn=_corrupt_indptr),
        FaultSpec("oob_col_index", "data", SpgemmInputError,
                  "live column index >= k", fn=_oob_col_index),
        FaultSpec("negative_col_index", "data", SpgemmInputError,
                  "live column index == -1", fn=_negative_col_index),
        FaultSpec("nan_values", "data", SpgemmInputError,
                  "NaN planted in a live value slot", fn=_nan_values),
        FaultSpec("capacity_overflow", "data", CapacityOverflowError,
                  "indptr[-1] pushed past nnz_cap (monotone otherwise)",
                  fn=_capacity_overflow),
        FaultSpec("length_mismatch", "data", SpgemmInputError,
                  "values buffer one slot shorter than indices",
                  fn=_length_mismatch),
        FaultSpec("kernel_pallas", "kernel", None,
                  "segsum_reuse Pallas replay raises mid-dispatch",
                  site="kernel:pallas"),
        FaultSpec("kernel_pallas_lp", "kernel", None,
                  "LP-hash Pallas replay raises mid-dispatch",
                  site="kernel:pallas_lp"),
        FaultSpec("kernel_flat_lp", "kernel", None,
                  "flat_lp numeric kernel raises", site="kernel:flat_lp"),
        FaultSpec("kernel_dense_acc", "kernel", None,
                  "dense_acc numeric kernel raises",
                  site="kernel:dense_acc"),
        FaultSpec("plan_cache_eviction", "cache", None,
                  "plan cache cleared mid-replay (simulated eviction)"),
    ]
    return {s.name: s for s in specs}


FAULTS: dict[str, FaultSpec] = _build_registry()


def data_faults() -> list[FaultSpec]:
    return [s for s in FAULTS.values() if s.kind == "data"]


def kernel_faults() -> list[FaultSpec]:
    return [s for s in FAULTS.values() if s.kind == "kernel"]


def inject_csr(name: str, csr, seed: int = 0):
    """Apply registered data fault ``name`` to ``csr`` deterministically."""
    spec = FAULTS[name]
    if spec.kind != "data":
        from repro.runtime.validate import SpgemmConfigError  # cycle-free
        raise SpgemmConfigError(
            f"fault {name!r} is kind={spec.kind!r}, not a data "
            "fault — arm its failpoint instead")
    return spec.fn(csr, np.random.default_rng(seed))
