"""Pallas TPU kernel: blocked flash attention with GQA, sliding window and
logit soft-capping (gemma2), causal masking.

Grid = (heads, q_blocks, kv_blocks); online softmax with running (m, l)
statistics in VMEM scratch. KV blocks for query head h come from KV head
h // group via the index_map (GQA without materializing repeated KV).

This kernel is the training/prefill path on real TPU hardware; the CPU-back
dry-run uses the XLA reference (`ref.flash_attention_ref`) since Pallas
lowers only to TPU (see DESIGN.md §7). Numerics are validated in
interpret mode against the reference in tests/test_kernels.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, out_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window, softcap, block_q: int,
            block_k: int):
    qt = pl.program_id(1)
    kt = pl.program_id(2)
    n_k = pl.num_programs(2)

    @pl.when(kt == 0)
    def _zero():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)  # (BQ, D)
    k = k_ref[0].astype(jnp.float32)  # (BK, D)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap

    qpos = qt * block_q + jax.lax.iota(jnp.int32, block_q)[:, None]
    kpos = kt * block_k + jax.lax.iota(jnp.int32, block_k)[None, :]
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= qpos - kpos < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]  # (BQ, 1)
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_cur)
    alpha = jnp.exp(m_prev - m_cur)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v_ref[0].astype(jnp.float32), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_cur

    @pl.when(kt == n_k - 1)
    def _emit():
        # fully-masked rows (can happen with windows) produce l == 0
        l = jnp.where(l_ref[...] == 0.0, 1.0, l_ref[...])
        out_ref[0] = (acc_ref[...] / l).astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "block_q", "block_k",
                     "interpret"),
)
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    softcap: float | None = None, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False) -> jax.Array:
    """q: (Hq, Tq, D); k, v: (Hkv, Tk, D); returns (Hq, Tq, D)."""
    hq, tq, d = q.shape
    hkv, tk, _ = k.shape
    assert hq % hkv == 0 and tq % block_q == 0 and tk % block_k == 0
    group = hq // hkv
    scale = 1.0 / (d ** 0.5)

    grid = (hq, tq // block_q, tk // block_k)
    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda h, qt, kt: (h, qt, 0)),
            pl.BlockSpec((1, block_k, d), lambda h, qt, kt: (h // group, kt, 0)),
            pl.BlockSpec((1, block_k, d), lambda h, qt, kt: (h // group, kt, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda h, qt, kt: (h, qt, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(q, k, v)
