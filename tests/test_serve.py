"""Serving engine: prefill->decode handoff equals pure decode; generation."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models import NO_SHARDING, decode_step, init_cache, init_params
from repro.serve import ServeEngine


@pytest.mark.parametrize("arch", ["llama3.2-1b", "gemma2-9b", "mamba2-2.7b",
                                  "recurrentgemma-9b"])
def test_prefill_decode_equals_pure_decode(arch):
    """Engine path (prefill T tokens, decode 1) must equal feeding all T+1
    tokens through decode_step one at a time."""
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(5)
    b, t, max_len = 2, 12, 24
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, t)), jnp.int32)

    eng = ServeEngine(params, cfg, max_len=max_len)
    last_logits, caches, pos = eng.prefill(toks)

    cache2 = init_cache(cfg, b, max_len=max_len, dtype=jnp.float32)
    for i in range(t):
        lg2, cache2 = decode_step(params, cache2, toks[:, i:i + 1],
                                  jnp.int32(i), cfg, NO_SHARDING,
                                  max_len=max_len)
    err = float(jnp.max(jnp.abs(last_logits.astype(jnp.float32)
                                - lg2[:, 0].astype(jnp.float32))))
    assert err < 0.15, err

    # continue decoding one step from both paths with the same token
    nxt = jnp.zeros((b, 1), jnp.int32)
    lg_a, _ = decode_step(params, caches, nxt, jnp.int32(t), cfg, NO_SHARDING,
                          max_len=max_len)
    lg_b, _ = decode_step(params, cache2, nxt, jnp.int32(t), cfg, NO_SHARDING,
                          max_len=max_len)
    err = float(jnp.max(jnp.abs(lg_a.astype(jnp.float32)
                                - lg_b.astype(jnp.float32))))
    assert err < 0.15, err


def test_generate_shapes_and_determinism():
    cfg = get_config("llama3.2-1b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(params, cfg, max_len=32)
    rng = np.random.default_rng(6)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 8)), jnp.int32)
    out1 = eng.generate(prompts, steps=6)
    out2 = eng.generate(prompts, steps=6)
    assert out1.shape == (2, 6)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
