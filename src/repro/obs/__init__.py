"""repro.obs — phase-level tracing, latency histograms, flight recorder.

Three coordinated pieces (see each module's docstring for the full story):

  * :mod:`repro.obs.trace` — ``span()`` phase tracing with Chrome trace-event
    export, trace-ID propagation and a ``$REPRO_TRACE`` env default;
  * :mod:`repro.obs.metrics` — log-bucketed latency histograms, live gauges
    and the nine telemetry counters behind one registry with JSONL /
    Prometheus exporters;
  * :mod:`repro.obs.recorder` — a bounded flight-recorder ring of the last-N
    dispatch events, dumped automatically on kernel/retry give-up.

The contract that makes this safe to thread through the hot path: with
tracing off (the default), a ``span()`` call is one mode check returning a
shared no-op — the pinned-replay path stays dispatch-identical, which
tests/test_obs.py asserts via telemetry and ``benchmarks.run --bench obs``
prices under a 2% gate.
"""
from repro.obs.metrics import (
    Gauge,
    Histogram,
    MetricsRegistry,
    default_registry,
    observe,
    reset_metrics,
)
from repro.obs.recorder import (
    FlightRecorder,
    default_recorder,
    reset_recorder,
)
from repro.obs.trace import (
    TRACE_ENV_VAR,
    TRACE_MODES,
    clear,
    current_trace_id,
    enabled,
    events,
    export_chrome_trace,
    new_trace_id,
    reset_tracing,
    resolve_trace_mode,
    set_tracing,
    span,
    trace_context,
    trace_scope,
)


def reset_obs() -> None:
    """Reset the whole observability layer (tests): tracing state + event
    buffer, the default metrics registry, and the flight-recorder ring."""
    reset_tracing()
    reset_metrics()
    reset_recorder()


__all__ = [
    "TRACE_ENV_VAR",
    "TRACE_MODES",
    "FlightRecorder",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "clear",
    "current_trace_id",
    "default_recorder",
    "default_registry",
    "enabled",
    "events",
    "export_chrome_trace",
    "new_trace_id",
    "observe",
    "reset_metrics",
    "reset_obs",
    "reset_recorder",
    "reset_tracing",
    "resolve_trace_mode",
    "set_tracing",
    "span",
    "trace_context",
    "trace_scope",
]
