"""Pallas TPU kernel: SpGEMM symbolic phase over compressed (bitmask) B.

The paper's §3.2 compression is the most TPU-native piece of the algorithm:
B's structure packs 32 columns per uint32 lane, the symbolic row-union is a
VPU BITWISE-OR, and `population_count` recovers row sizes. The L1 accumulator
is a (1, k32) uint32 VMEM scratch tile — the dense-accumulator scheme in
compressed column space (32x smaller than an uncompressed dense accumulator,
which is why it stays in VMEM for k up to ~4M columns).

Partitioning (DESIGN.md §2.2 Thread-Sequential): grid = (m, rA); step (i, r)
DMAs B's bitmask row ``a_idx[i, r]`` — the gather is steered by the
scalar-prefetched A structure through the BlockSpec index_map, which is the
TPU idiom replacing the GPU's per-thread pointer chasing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_idx_ref, a_nnz_ref, b_bm_ref, out_ref, acc_ref):
    i = pl.program_id(0)
    r = pl.program_id(1)
    n_r = pl.num_programs(1)

    @pl.when(r == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    live = r < a_nnz_ref[i]
    row = b_bm_ref[...]  # (1, k32) uint32, DMA'd by index_map gather
    acc_ref[...] |= jnp.where(live, row, jnp.uint32(0))

    @pl.when(r == n_r - 1)
    def _emit():
        counts = jax.lax.population_count(acc_ref[...])
        out_ref[0, 0] = jnp.sum(counts.astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=("interpret",))
def spgemm_symbolic(a_idx: jax.Array, a_nnz: jax.Array, b_bitmask: jax.Array,
                    *, interpret: bool = False) -> jax.Array:
    """Row sizes of C = A*B from A's ELL structure and B's bitmask rows.

    a_idx: (m, rA) int32 — ELL column ids of A (padded slots masked via a_nnz)
    a_nnz: (m,) int32 — live width per row
    b_bitmask: (n, k32) uint32 — compressed structure of B (k32 % 128 == 0)
    returns: (m,) int32 row sizes.
    """
    m, r_a = a_idx.shape
    n, k32 = b_bitmask.shape
    if k32 % 128:
        from repro.runtime.validate import SpgemmInputError  # cycle-free
        raise SpgemmInputError(
            f"k32={k32} must be lane-aligned (multiple of 128)")

    grid = (m, r_a)
    out = pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec(
                    (1, k32),
                    lambda i, r, a_idx, a_nnz: (a_idx[i, r], 0),
                ),
            ],
            out_specs=pl.BlockSpec((1, 1), lambda i, r, a_idx, a_nnz: (i, 0)),
            scratch_shapes=[pltpu.VMEM((1, k32), jnp.uint32)],
        ),
        out_shape=jax.ShapeDtypeStruct((m, 1), jnp.int32),
        interpret=interpret,
    )(a_idx, a_nnz, b_bitmask)
    return out[:, 0]


def spgemm_symbolic_bucketed(a_idx: jax.Array, a_nnz: jax.Array,
                             b_bitmask: jax.Array, *,
                             pad_policy: str | None = None,
                             interpret: bool = False) -> jax.Array:
    """``spgemm_symbolic`` with the ELL width rA padded to a capacity bucket.

    Same bucketing contract as the host driver (core.meta.round_capacity):
    widths within a x2 band map to one grid shape, so similarly-sized
    matrices share a single compiled kernel instead of each recompiling.
    Padded slots sit beyond ``a_nnz`` and are masked inside the kernel.
    """
    from repro.core.meta import DEFAULT_PAD_POLICY, round_capacity

    policy = DEFAULT_PAD_POLICY if pad_policy is None else pad_policy
    r_a = a_idx.shape[1]
    r_cap = round_capacity(r_a, policy)
    if r_cap != r_a:
        a_idx = jnp.pad(a_idx, ((0, 0), (0, r_cap - r_a)))
    return spgemm_symbolic(a_idx, a_nnz, b_bitmask, interpret=interpret)
