"""Flight recorder: a bounded ring of the last-N dispatch events.

When a replay fails in production the question is never "did it fail" (the
typed taxonomy answers that) but "what was the stack doing just before":
which kernels dispatched, on which structures, how long they took, and which
ladder hops already happened. The recorder keeps exactly that — a
``deque(maxlen=N)`` of dispatch events — and dumps it at the moments the
failure model defines:

  * automatically when a ``KernelFallbackError`` is raised (executor /
    kernel-ladder give-up) or a ``RetryExhaustedError`` fires (the serving
    tier's retry bound) — ``note_error`` snapshots the ring into
    ``last_dump`` and prints a one-line notice to stderr;
  * on demand via ``SparseService.stats(debug=True)`` or ``dump()``.

Recording policy mirrors the tracing-off contract: *successful* dispatches
are recorded only while tracing is enabled (the hot path stays untouched);
*fallback hops and errors* are always recorded — they are rare, already off
the fast path, and exactly what the ring exists to remember.
"""
from __future__ import annotations

import sys
import time
from collections import deque

DEFAULT_CAPACITY = 256


class FlightRecorder:
    """Bounded ring of dispatch events (plain dicts, host-side only)."""

    def __init__(self, capacity: int = DEFAULT_CAPACITY):
        if capacity < 1:
            from repro.runtime.validate import SpgemmConfigError  # cycle-free
            raise SpgemmConfigError(
                f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._ring: deque[dict] = deque(maxlen=capacity)
        self._seq = 0
        self.last_dump: dict | None = None

    def record(self, event: str, **fields) -> dict:
        """Append one event (oldest entry falls off past ``capacity``).

        Conventional fields: ``kernel``, ``structure_key``, ``shapes``,
        ``duration_s``, ``verdict`` ("ok" | "fallback" | "error"),
        ``fallback`` ("<from>-><to>" hop), ``trace_id``, ``site``.
        """
        self._seq += 1
        entry = {"seq": self._seq, "event": event,
                 "wall_time": time.time(), **fields}
        self._ring.append(entry)
        return entry

    def events(self) -> list[dict]:
        return list(self._ring)

    def __len__(self) -> int:
        return len(self._ring)

    def dump(self, reason: str) -> dict:
        """Snapshot the ring: {reason, recorded (lifetime), events}."""
        return {"reason": reason, "recorded": self._seq,
                "capacity": self.capacity, "events": self.events()}

    def note_error(self, exc: BaseException, **context) -> dict:
        """The automatic-dump hook: record the error event, snapshot the
        ring into ``last_dump``, announce on stderr. Returns the dump."""
        self.record("error", verdict="error",
                    error=f"{type(exc).__name__}: {exc}", **context)
        self.last_dump = self.dump(
            reason=f"{type(exc).__name__}: {exc}")
        print(f"FLIGHT-RECORDER: dumped {len(self._ring)} events after "
              f"{type(exc).__name__}", file=sys.stderr)
        return self.last_dump

    def reset(self) -> None:
        self._ring.clear()
        self._seq = 0
        self.last_dump = None


_DEFAULT = FlightRecorder()


def default_recorder() -> FlightRecorder:
    """The process-wide ring the executor / kernel ladder / retry feed."""
    return _DEFAULT


def record(event: str, **fields) -> dict:
    return _DEFAULT.record(event, **fields)


def note_error(exc: BaseException, **context) -> dict:
    return _DEFAULT.note_error(exc, **context)


def reset_recorder() -> None:
    """Clear the default ring (tests)."""
    _DEFAULT.reset()
