"""codeqwen1.5-7b [dense] — hf:Qwen/CodeQwen1.5-7B (qwen1.5 arch).

32L, d_model=4096, 32 heads (GQA kv=32 == MHA), d_ff=13440, vocab=92416,
QKV bias. SpGEMM applicability: none. long_500k: skipped (full attention).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    d_ff=13_440,
    vocab_size=92_416,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="codeqwen1.5-7b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=112,
    vocab_size=256,
    head_dim=16,
    qkv_bias=True,
)

SKIP_SHAPES = {"long_500k": "pure full-attention arch (per-spec skip)"}
