"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from dryrun JSONL."""
from __future__ import annotations

import argparse
import json
from collections import defaultdict


def load(path: str):
    recs = {}
    for line in open(path):
        r = json.loads(line)
        key = (r["arch"], r["shape"], r["mesh"])
        recs[key] = r  # last write wins (re-runs overwrite)
    return list(recs.values())


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b / 1e9:.2f}"


def roofline_table(recs, mesh: str) -> str:
    rows = [r for r in recs if r["mesh"] == mesh and r.get("status") == "ok"]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    out = [
        "| arch | shape | t_compute (s) | t_memory (s) | t_coll (s) | "
        "dominant | useful FLOPs ratio | HBM peak/chip (GB) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        ma = r.get("memory_analysis", {}) or {}
        peak = (ma.get("temp_bytes", 0) + ma.get("argument_bytes", 0)
                + ma.get("output_bytes", 0))
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.4f} | "
            f"{r['t_memory_s']:.4f} | {r['t_collective_s']:.4f} | "
            f"{r['dominant']} | {r['useful_flops_ratio']:.2f} | "
            f"{peak / 1e9:.1f} |"
        )
    return "\n".join(out)


def dryrun_table(recs) -> str:
    by_cell = defaultdict(dict)
    for r in recs:
        by_cell[(r["arch"], r["shape"])][r["mesh"]] = r
    out = [
        "| arch | shape | 16x16 | 2x16x16 | args/chip (GB) | temp/chip (GB) | "
        "collectives (GB/chip, 16x16) |",
        "|---|---|---|---|---|---|---|",
    ]
    for (arch, shape), meshes in sorted(by_cell.items()):
        sp = meshes.get("16x16", {})
        mp = meshes.get("2x16x16", {})
        ma = sp.get("memory_analysis", {}) or {}
        coll = sp.get("coll_breakdown", {}) or {}
        brk = " ".join(
            f"{k}={v / 1e9:.1f}" for k, v in coll.items()
            if k not in ("total", "count") and v > 0
        )
        out.append(
            f"| {arch} | {shape} | "
            f"{'ok' if sp.get('status') == 'ok' else 'FAIL'} | "
            f"{'ok' if mp.get('status') == 'ok' else 'FAIL'} | "
            f"{fmt_bytes(ma.get('argument_bytes'))} | "
            f"{fmt_bytes(ma.get('temp_bytes'))} | {brk} |"
        )
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--jsonl", default="dryrun_results.jsonl")
    ap.add_argument("--section", choices=["roofline", "dryrun", "pick"],
                    default="roofline")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    recs = load(args.jsonl)
    if args.section == "roofline":
        print(roofline_table(recs, args.mesh))
    elif args.section == "dryrun":
        print(dryrun_table(recs))
    else:  # pick hillclimb candidates
        rows = [r for r in recs if r["mesh"] == "16x16"
                and r.get("status") == "ok"]
        rows.sort(key=lambda r: r["roofline_fraction"])
        print("worst roofline fraction:")
        for r in rows[:5]:
            print(f"  {r['arch']} x {r['shape']}: frac="
                  f"{r['roofline_fraction']:.3f} dominant={r['dominant']} "
                  f"terms=({r['t_compute_s']:.3f},{r['t_memory_s']:.3f},"
                  f"{r['t_collective_s']:.3f})")
        rows.sort(key=lambda r: -r["t_collective_s"])
        print("most collective-bound (absolute):")
        for r in rows[:5]:
            print(f"  {r['arch']} x {r['shape']}: t_coll="
                  f"{r['t_collective_s']:.3f} dominant={r['dominant']}")


if __name__ == "__main__":
    main()
