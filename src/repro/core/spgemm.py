"""Two-phase SpGEMM (paper Alg. 2/3) adapted to XLA's static-shape regime.

Phase contract (identical to the paper's host/device split):
  1. ``symbolic``  — jitted; returns per-row nnz of C (no FLOPs). Uses the
     compressed matrix when the CF <= 0.85 rule fires.
  2. host         — materializes ``indptr`` and the concrete nnz(C).
  3. ``numeric``  — jitted at that size; fills C. The first run also emits a
     ``SpgemmPlan`` (structure + product->slot map). Re-running with new
     values but the same structure (the paper's *Reuse* case) is a pure
     gather/segment-sum — no hashing, no sort, no recompile.

Accumulation strategy per the TPU adaptation (DESIGN.md §2): sorted-segment
accumulation (Thread-Flat-Parallel semantics — associative, atomic-free) and
dense scatter accumulation (KKDENSE). Hash accumulators live in
``core/accumulators.py`` (jittable LL/LP ports) and ``kernels/`` (Pallas).

Pipeline & Reuse
----------------
A fresh ``spgemm()`` runs a *single-expansion* pipeline: one
``expand_products`` call and **one** sort feed both the symbolic row counts
and the numeric ``SpgemmPlan``. The sort packs ``(row, col)`` into a single
integer key and argsorts once (``_single_sort_order``) — replacing the two
stable passes of ``lexsort`` — and its contract is exact equivalence with
``jnp.lexsort((col, row))``: stable, lexicographic by row then column. The
stages are:

  ``expand_and_sort``  (jit, static fm_cap)  -> sorted products + row sizes
  host                                       -> nnz(C), bucketed nnz_cap
  ``plan_from_sorted`` (jit, static nnz_cap) -> SpgemmPlan (v2, precomposed)
  ``numeric_reuse``    (jit)                 -> C values

The plan is *precomposed* (v2): ``plan_from_sorted`` folds the sort
permutation into the slot maps at build time (``a_slot_s = a_slot[order]``,
``b_slot_s = b_slot[order]``) and folds validity into sentinel ``seg_ids``
(padding products point at slot ``nnz_cap`` and are dropped by the scatter).
A numeric replay is therefore two gathers + one ``indices_are_sorted``
segment-sum — no O(f_m) permutation pass, no mask — and accumulates in
``jnp.result_type(a_values, b_values)`` so mixed-precision operands never
silently downcast.

Static capacities (``fm_cap``, ``nnz_cap``, and the CSR buffer caps of A and
B) are rounded up to geometric x2 buckets under ``core.meta.round_capacity``
(knob: ``pad_policy``, default "pow2"), so matrices of similar size share one
compiled executable instead of each minting its own. On top of that,
``spgemm()`` consults a structure-keyed LRU plan cache
(``core/plan_cache.py``): a repeated structure with new values skips the
expansion and sort entirely and replays ``numeric_reuse`` — the paper's Reuse
case with zero caller bookkeeping and zero recompiles. For reuse-dominated
workloads (multigrid setup, graph analytics with changing weights),
``core/executor.py`` goes one step further: a ``ReuseExecutor`` pins a plan
once (one structure hash, ever) and replays it as a single jitted dispatch —
optionally batched over stacked value arrays and optionally through the
Pallas ``kernels/segsum_reuse.py`` flat-parallel kernel.

Note the dense method returns ``plan=None``: the KKDENSE path has no
product->slot map, so it offers no Reuse fast path — use ``method="sparse"``
(or an executor) when structure reuse matters. ``TRACE_COUNTS`` records
retraces of every jitted stage so benchmarks and tests can assert the
one-expansion/one-sort contract and the bucketing's recompile savings.
"""
from __future__ import annotations

from collections import Counter
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compression import (
    CompressedMatrix,
    compress_matrix,
    compression_decision,
    flops_stats,
)
from repro.core.meta import DEFAULT_PAD_POLICY, round_capacity
from repro.core.utils import popcount, segmented_scan, segment_ends
from repro.obs.trace import span, trace_scope
from repro.sparse.formats import CSR, csr_row_ids

# Retrace telemetry: each jitted stage bumps its counter at *trace* time only,
# so the counts measure XLA recompiles, not calls. Benchmarks (bench_compile)
# and tests read these to verify the single-expansion contract and that
# capacity bucketing actually shares executables.
TRACE_COUNTS: Counter = Counter()


def _note_trace(name: str) -> None:
    TRACE_COUNTS[name] += 1


def reset_trace_counts() -> None:
    TRACE_COUNTS.clear()


class ProductExpansion(NamedTuple):
    """Flattened multiplication space: the paper's Thread-Flat-Parallel view.

    Product t multiplies A-slot ``a_slot[t]`` with B-slot ``b_slot[t]`` and
    lands in C row ``row[t]``, column ``col[t]``. ``valid`` masks padding.
    """

    row: jax.Array
    col: jax.Array
    a_slot: jax.Array
    b_slot: jax.Array
    valid: jax.Array


class SortedExpansion(NamedTuple):
    """One expansion + one sort: everything both phases need.

    Produced by ``expand_and_sort``; consumed by the host (``row_sizes`` ->
    nnz(C)) and by ``plan_from_sorted`` (everything else). ``heads`` marks the
    first product of each distinct (row, col) group in sorted order;
    ``seg_ids`` maps each sorted product to its C slot.
    """

    order: jax.Array  # (fm_cap,) int32 — the single sort permutation
    rows_s: jax.Array  # (fm_cap,) int32 — rows in sorted order
    cols_s: jax.Array  # (fm_cap,) int32 — cols in sorted order
    valid_s: jax.Array  # (fm_cap,) bool — validity in sorted order
    heads: jax.Array  # (fm_cap,) bool — group heads (padding mints none)
    seg_ids: jax.Array  # (fm_cap,) int32 — sorted product -> C slot
    a_slot: jax.Array  # (fm_cap,) int32 — unsorted, from the expansion
    b_slot: jax.Array  # (fm_cap,) int32
    valid: jax.Array  # (fm_cap,) bool
    row_sizes: jax.Array  # (m,) int32 — the symbolic output


class SpgemmPlan(NamedTuple):
    """Cached numeric plan enabling the Reuse fast path (v2, precomposed).

    The sort permutation is composed into the slot maps at plan-build time:
    ``a_slot_s``/``b_slot_s`` are already in sorted product order, and
    ``seg_ids`` folds validity in as a sentinel (padding products map to slot
    ``nnz_cap``, which the ``mode="drop"`` scatter discards). A replay is two
    gathers + one sorted segment-sum — no permutation gather, no mask.
    """

    indptr: jax.Array  # (m+1,) int32 — C row pointers
    indices: jax.Array  # (nnz_cap,) int32 — C columns, sorted per row
    seg_ids: jax.Array  # (fm_cap,) int32 — sorted product -> C slot
    #                     (nnz_cap sentinel for padding -> dropped)
    a_slot_s: jax.Array  # (fm_cap,) int32 — A slot per sorted product
    b_slot_s: jax.Array  # (fm_cap,) int32 — B slot per sorted product
    shape: tuple  # (m, k) of C


def _single_sort_order(rows: jax.Array, keys: jax.Array, m: int,
                       key_bound: int | None) -> jax.Array:
    """Stable sort permutation by (rows, keys) in ONE pass.

    Packs the pair into a single integer key and argsorts once — the
    replacement for ``jnp.lexsort((keys, rows))``'s two stable passes. Rows
    may carry the padding sentinel ``m``; keys must lie in [0, key_bound).
    Ordering is exactly lexsort's: stable, by row then key.

    Width selection is static (m, key_bound are trace-time ints): int32
    packing when (m+1)*key_bound fits, int64 when x64 is enabled, otherwise a
    single fused two-key ``lax.sort`` — still one sort pass, never two.
    ``key_bound=None`` means "unknown at trace time": use the fused sort.
    """
    span = None if key_bound is None else (m + 1) * key_bound  # rows pad to m
    if span is not None and span <= np.iinfo(np.int32).max:
        packed = rows.astype(jnp.int32) * jnp.int32(key_bound) + keys.astype(jnp.int32)
        return jnp.argsort(packed, stable=True).astype(jnp.int32)
    if span is not None and jax.config.jax_enable_x64 and span <= np.iinfo(np.int64).max:
        packed = rows.astype(jnp.int64) * jnp.int64(key_bound) + keys.astype(jnp.int64)
        return jnp.argsort(packed, stable=True).astype(jnp.int32)
    iota = jnp.arange(rows.shape[0], dtype=jnp.int32)
    _, _, order = jax.lax.sort(
        (rows.astype(jnp.int32), keys.astype(jnp.int32), iota),
        num_keys=2,
        is_stable=True,
    )
    return order


@partial(jax.jit, static_argnames=("fm_cap",))
def expand_products(a: CSR, b: CSR, fm_cap: int) -> ProductExpansion:
    """Enumerate all f_m multiplications with static capacity ``fm_cap``.

    For product t: binary-search the owning A-slot in the exclusive prefix of
    per-A-slot product counts, then offset into B's row. Fully vectorized.
    """
    _note_trace("expand_products")
    b_row_nnz = b.row_nnz()
    a_valid = a.valid_mask()
    per_slot = jnp.where(
        a_valid, b_row_nnz[jnp.minimum(a.indices, b.m - 1)], 0
    ).astype(jnp.int32)
    offsets = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(per_slot).astype(jnp.int32)]
    )  # (nnzA+1,)
    t = jnp.arange(fm_cap, dtype=jnp.int32)
    a_slot = (
        jnp.searchsorted(offsets, t, side="right").astype(jnp.int32) - 1
    ).clip(0, a.nnz_cap - 1)
    within = t - offsets[a_slot]
    valid = t < offsets[-1]
    j = a.indices[a_slot]
    b_slot = (b.indptr[jnp.minimum(j, b.m - 1)] + within).clip(0, b.nnz_cap - 1)
    rows = csr_row_ids(a.indptr, a.nnz_cap)[a_slot]
    col = b.indices[b_slot]
    return ProductExpansion(
        row=jnp.where(valid, rows, a.m),  # pad rows to m -> sorts to the end
        col=jnp.where(valid, col, 0),
        a_slot=a_slot,
        b_slot=b_slot,
        valid=valid,
    )


@partial(jax.jit, static_argnames=("fm_cap",))
def expand_and_sort(a: CSR, b: CSR, fm_cap: int) -> SortedExpansion:
    """The fused front half of a fresh multiply: ONE expansion, ONE sort.

    Returns sorted products plus per-row distinct-column counts — the
    symbolic phase's answer — so the driver never expands or sorts again for
    the numeric plan.
    """
    _note_trace("expand_and_sort")
    ex = expand_products(a, b, fm_cap)
    order = _single_sort_order(ex.row, ex.col, a.m, b.k)
    rows_s = ex.row[order]
    cols_s = ex.col[order]
    valid_s = ex.valid[order]
    heads = jnp.concatenate(
        [
            jnp.ones((1,), jnp.bool_),
            (rows_s[1:] != rows_s[:-1]) | (cols_s[1:] != cols_s[:-1]),
        ]
    )
    heads = heads & valid_s  # padding (row==m) groups don't mint slots
    seg_ids = (jnp.cumsum(heads.astype(jnp.int32)) - 1).clip(0).astype(jnp.int32)
    row_sizes = jnp.zeros((a.m,), jnp.int32).at[jnp.minimum(rows_s, a.m - 1)].add(
        heads.astype(jnp.int32), mode="drop"
    )
    return SortedExpansion(
        order=order,
        rows_s=rows_s,
        cols_s=cols_s,
        valid_s=valid_s,
        heads=heads,
        seg_ids=seg_ids,
        a_slot=ex.a_slot,
        b_slot=ex.b_slot,
        valid=ex.valid,
        row_sizes=row_sizes,
    )


@partial(jax.jit, static_argnames=("k", "nnz_cap"))
def plan_from_sorted(sx: SortedExpansion, k: int, nnz_cap: int) -> SpgemmPlan:
    """Back half of a fresh multiply: C structure + reuse plan, no re-sort.

    Precomposes the sort permutation into the slot maps (plan v2): the one
    extra gather pair here is paid once per *structure*, saving one O(f_m)
    permutation gather on every numeric replay.
    """
    _note_trace("plan_from_sorted")
    m = sx.row_sizes.shape[0]
    c_indices = jnp.zeros((nnz_cap,), jnp.int32).at[sx.seg_ids].max(
        jnp.where(sx.heads, sx.cols_s, 0), mode="drop"
    )
    indptr = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(sx.row_sizes).astype(jnp.int32)]
    )
    return SpgemmPlan(
        indptr=indptr,
        indices=c_indices,
        seg_ids=jnp.where(sx.valid_s, sx.seg_ids, nnz_cap),  # padded -> dropped
        a_slot_s=sx.a_slot[sx.order],
        b_slot_s=sx.b_slot[sx.order],
        shape=(m, k),
    )


def host_fm_cap(a: CSR, b: CSR, pad_to: int = 8, fm: int | None = None) -> int:
    """Host-side f_m (total products) rounded up — the static expansion size.

    fm: precomputed product count, if the caller already paid the
    ``flops_stats`` pass (saves its device->host sync)."""
    if fm is None:
        fm = int(flops_stats(a, b.row_nnz())[0])
    return max(-(-fm // pad_to) * pad_to, pad_to)


# --------------------------------------------------------------------------
# Symbolic phase
# --------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("fm_cap", "m", "key_bound"))
def _symbolic_sorted(rows, keys, payload, valid, m: int, fm_cap: int, key_bound: int):
    """Shared core: sort (row, key) pairs, OR payloads per group, count groups
    per row (plain symbolic: payload == popcount 1 per distinct column)."""
    _note_trace("_symbolic_sorted")
    order = _single_sort_order(rows, keys, m, key_bound)
    rows_s, keys_s, valid_s = rows[order], keys[order], valid[order]
    pay_s = payload[order]
    heads = jnp.concatenate(
        [
            jnp.ones((1,), jnp.bool_),
            (rows_s[1:] != rows_s[:-1]) | (keys_s[1:] != keys_s[:-1]),
        ]
    )
    or_scan = segmented_scan(pay_s, heads, jnp.bitwise_or)
    ends = segment_ends(heads) & valid_s
    contrib = jnp.where(ends, popcount(or_scan), 0).astype(jnp.int32)
    sizes = jnp.zeros((m,), jnp.int32).at[jnp.minimum(rows_s, m - 1)].add(
        jnp.where(valid_s, contrib, 0), mode="drop"
    )
    return sizes


@partial(jax.jit, static_argnames=("fm_cap", "m", "key_bound"))
def symbolic_compressed(a: CSR, bc: CompressedMatrix, m: int, fm_cap: int,
                        key_bound: int | None = None) -> jax.Array:
    """Symbolic phase on the compressed B (paper §3.2): expand (row, CSI, CS)
    products, OR the CS masks per (row, CSI), sum popcounts per row.

    key_bound: static bound on CSI values (ceil(k/32)) enabling the packed
    single-key sort; None falls back to the fused two-key sort."""
    _note_trace("symbolic_compressed")
    bc_row_nnz = bc.row_nnz()
    a_valid = a.valid_mask()
    nb = bc.indptr.shape[0] - 1
    per_slot = jnp.where(
        a_valid, bc_row_nnz[jnp.minimum(a.indices, nb - 1)], 0
    ).astype(jnp.int32)
    offsets = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(per_slot).astype(jnp.int32)]
    )
    t = jnp.arange(fm_cap, dtype=jnp.int32)
    a_slot = (
        jnp.searchsorted(offsets, t, side="right").astype(jnp.int32) - 1
    ).clip(0, a.nnz_cap - 1)
    within = t - offsets[a_slot]
    valid = t < offsets[-1]
    j = jnp.minimum(a.indices[a_slot], nb - 1)
    cap = bc.csi.shape[0]
    b_slot = (bc.indptr[j] + within).clip(0, cap - 1)
    rows = jnp.where(valid, csr_row_ids(a.indptr, a.nnz_cap)[a_slot], m)
    keys = jnp.where(valid, bc.csi[b_slot], 0)
    cs = jnp.where(valid, bc.cs[b_slot], jnp.uint32(0))
    return _symbolic_sorted(rows, keys, cs, valid, m, fm_cap, key_bound=key_bound)


@partial(jax.jit, static_argnames=("fm_cap",))
def symbolic_plain(a: CSR, b: CSR, fm_cap: int) -> jax.Array:
    """Uncompressed symbolic: distinct-column count per row via sort."""
    _note_trace("symbolic_plain")
    ex = expand_products(a, b, fm_cap)
    ones = jnp.where(ex.valid, jnp.uint32(1), jnp.uint32(0))
    return _symbolic_sorted(
        ex.row, ex.col, ones, ex.valid, a.m, fm_cap, key_bound=max(b.k, 1)
    )


@partial(jax.jit, static_argnames=("block_rows",))
def symbolic_dense_bitmask(a_ell, b_bitmask: jax.Array, block_rows: int = 64) -> jax.Array:
    """KKDENSE symbolic: per row-block, gather B's bitmask rows and OR-reduce
    into a dense (block_rows, ceil(k/32)) accumulator — the dense-accumulator
    symbolic with 32x compression. Memory-bounded via lax.map over blocks."""
    m = a_ell.m
    k32 = b_bitmask.shape[1]
    r_pad = a_ell.r_pad
    n_blocks = -(-m // block_rows)
    pad_m = n_blocks * block_rows
    idx = jnp.pad(a_ell.indices, ((0, pad_m - m), (0, 0)))
    rnnz = jnp.pad(a_ell.row_nnz, (0, pad_m - m))
    idx = idx.reshape(n_blocks, block_rows, r_pad)
    rnnz = rnnz.reshape(n_blocks, block_rows)

    def block(args):
        bi, brn = args  # (block_rows, r_pad), (block_rows,)
        masks = b_bitmask[bi.clip(0, b_bitmask.shape[0] - 1)]  # (BR, r_pad, k32)
        live = (
            jnp.arange(r_pad, dtype=jnp.int32)[None, :, None] < brn[:, None, None]
        )
        masks = jnp.where(live, masks, jnp.uint32(0))
        acc = jax.lax.reduce(
            masks, jnp.uint32(0), jnp.bitwise_or, dimensions=(1,)
        )  # (BR, k32)
        return jnp.sum(popcount(acc), axis=-1).astype(jnp.int32)

    sizes = jax.lax.map(block, (idx, rnnz))
    return sizes.reshape(pad_m)[:m]


# --------------------------------------------------------------------------
# Numeric phase
# --------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("fm_cap", "nnz_cap"))
def numeric_fresh(a: CSR, b: CSR, fm_cap: int, nnz_cap: int):
    """First numeric run: discovers C's structure and the product->slot map,
    computes values. Returns (CSR C, SpgemmPlan). Jittable end-to-end (used
    inside shard_map); composes the single-expansion stages inline."""
    _note_trace("numeric_fresh")
    sx = expand_and_sort(a, b, fm_cap)
    plan = plan_from_sorted(sx, b.k, nnz_cap)
    values = numeric_reuse(plan, a.values, b.values)
    c = CSR(indptr=plan.indptr, indices=plan.indices, values=values, shape=(a.m, b.k))
    return c, plan


@jax.jit
def numeric_reuse(plan: SpgemmPlan, a_values: jax.Array, b_values: jax.Array) -> jax.Array:
    """The Reuse case: same structure, new values. Two gathers + one sorted
    segment-sum. No sort, no hash, no permutation pass, no recompile.

    The precomposed plan already orders the slot maps, so padding products
    need no mask: their sentinel ``seg_ids == nnz_cap`` fall off the scatter
    (``mode="drop"``). Accumulates in ``jnp.result_type(a_values, b_values)``
    so mixed-precision operands keep full product precision.
    """
    _note_trace("numeric_reuse")
    acc_dtype = jnp.result_type(a_values, b_values)
    prod = (a_values[plan.a_slot_s].astype(acc_dtype)
            * b_values[plan.b_slot_s].astype(acc_dtype))
    nnz_cap = plan.indices.shape[0]
    return jnp.zeros((nnz_cap,), acc_dtype).at[plan.seg_ids].add(
        prod, mode="drop", indices_are_sorted=True
    )


def lp_replay_values(plan: SpgemmPlan, a_values: jax.Array,
                     b_values: jax.Array, interpret: bool | None = None):
    """The one LP-position replay dispatch: Pallas LP-hash kernel when the
    operand dtypes can accumulate in f32, the exact XLA ``numeric_reuse``
    otherwise (f64/int). Every LP entry point — ``spgemm(method="lp")``,
    ``numeric_lp``, ``ReuseExecutor(backend="pallas_lp")`` — routes through
    here so the fallback rule can never drift between them.

    interpret: None = interpret off-TPU (Pallas lowers only to TPU).
    Returns (values, backend) with backend in {"pallas", "xla"}.
    """
    from repro.core.meta import f32_accumulation_ok  # cycle-free late import

    if f32_accumulation_ok(a_values.dtype, b_values.dtype):
        from repro.kernels.spgemm_lp import lp_reuse  # cycle-free late import

        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        return lp_reuse(plan, a_values, b_values, interpret=interpret), "pallas"
    return numeric_reuse(plan, a_values, b_values), "xla"


@partial(jax.jit, static_argnames=("fm_cap", "nnz_cap", "interpret"))
def numeric_lp(a: CSR, b: CSR, fm_cap: int, nnz_cap: int,
               interpret: bool = False):
    """KKLP-position numeric phase: structure via the single-expansion
    pipeline, values through the Pallas LP-hash accumulator replay
    (``kernels.spgemm_lp.lp_reuse``; automatic XLA fallback for f64/int).
    Returns (CSR C, SpgemmPlan) — the same contract as ``numeric_fresh``,
    selected by ``choose_kernel``'s ``flat_lp`` branch for flop-heavy
    rows."""
    _note_trace("numeric_lp")
    sx = expand_and_sort(a, b, fm_cap)
    plan = plan_from_sorted(sx, b.k, nnz_cap)
    values, _ = lp_replay_values(plan, a.values, b.values, interpret=interpret)
    c = CSR(indptr=plan.indptr, indices=plan.indices, values=values,
            shape=(a.m, b.k))
    return c, plan


@partial(jax.jit, static_argnames=("fm_cap", "nnz_cap"))
def numeric_dense_acc(a: CSR, b: CSR, fm_cap: int, nnz_cap: int) -> CSR:
    """KKDENSE numeric: scatter all products into a dense (m, k) accumulator,
    then extract the CSR structure with a fixed-size nonzero scan. Chosen by
    the meta-algorithm when k is small (paper: k < 250k). O(m*k) memory —
    exactly the paper's dense-accumulator trade-off."""
    _note_trace("numeric_dense_acc")
    ex = expand_products(a, b, fm_cap)
    vals = jnp.where(ex.valid, a.values[ex.a_slot] * b.values[ex.b_slot], 0)
    dense = jnp.zeros((a.m, b.k), a.dtype)
    dense = dense.at[jnp.minimum(ex.row, a.m - 1), ex.col].add(
        jnp.where(ex.valid, vals, 0), mode="drop"
    )
    # structure mask must come from the *symbolic* structure, not value!=0
    # (cancellation must keep explicit zeros, like the paper's accumulators):
    occupied = jnp.zeros((a.m, b.k), jnp.int32)
    occupied = occupied.at[jnp.minimum(ex.row, a.m - 1), ex.col].max(
        ex.valid.astype(jnp.int32), mode="drop"
    )
    rr, cc = jnp.nonzero(occupied, size=nnz_cap, fill_value=0)
    got = jnp.arange(nnz_cap) < jnp.sum(occupied.astype(jnp.int32))
    values = jnp.where(got, dense[rr, cc], 0)
    indices = jnp.where(got, cc, 0).astype(jnp.int32)
    row_sizes = jnp.sum(occupied.astype(jnp.int32), axis=1)
    indptr = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(row_sizes).astype(jnp.int32)]
    )
    return CSR(indptr=indptr, indices=indices, values=values, shape=(a.m, b.k))


# --------------------------------------------------------------------------
# Host-level driver (the paper's Algorithm 2)
# --------------------------------------------------------------------------


class SpgemmResult(NamedTuple):
    c: CSR
    plan: SpgemmPlan | None
    stats: dict


def symbolic(a: CSR, b: CSR, compress: str = "auto",
             pad_policy: str = DEFAULT_PAD_POLICY):
    """Paper Alg. 2 lines 1-3. Returns (row_sizes, stats). Host-mediated:
    decides compression by the CF<=0.85 rule and sizes the expansion."""
    stats: dict = {}
    fm, maxrf = (int(x) for x in _fm_scalars(a, b))
    stats["fm"] = fm
    stats["maxrf"] = maxrf
    use_c = False
    cf = cmrf = 1.0
    bc = None
    if compress in ("auto", "always"):
        bc = compress_matrix(b)
        cf, cmrf, use_c = compression_decision(a, b, bc)
        if compress == "always":
            use_c = True
    stats["cf"], stats["cmrf"], stats["compressed"] = cf, cmrf, use_c
    if use_c and bc is not None:
        fm_c = max(int(jnp.sum(_per_slot(a, bc.row_nnz(), bc.indptr.shape[0] - 1))), 1)
        cap = round_capacity(fm_c, pad_policy)
        sizes = symbolic_compressed(a, bc, a.m, cap, key_bound=-(-b.k // 32))
    else:
        cap = round_capacity(fm, pad_policy)
        sizes = symbolic_plain(a, b, cap)
    return sizes, stats


def _repad_csr(a: CSR, nnz_cap: int) -> CSR:
    """Re-pad a CSR's buffer capacity to a bucketed cap (live prefix kept).

    Requires nnz(a) <= nnz_cap — only padding slots are ever dropped. Runs in
    numpy on purpose: eager jnp slicing here would compile per *exact* input
    capacity, defeating the bucketing (the host driver syncs for the
    structure hash anyway, so the device->host copy is already paid).
    """
    from repro.runtime.validate import CapacityOverflowError  # cycle-free

    if nnz_cap == a.nnz_cap:
        return a
    nnz = int(a.indptr[-1])
    if nnz > nnz_cap:
        raise CapacityOverflowError(
            f"cannot repad CSR to nnz_cap={nnz_cap}: {nnz} live entries would "
            f"be truncated (buffer cap {a.nnz_cap})"
        )
    keep = min(nnz_cap, a.nnz_cap)
    indices = np.zeros(nnz_cap, np.int32)
    values = np.zeros(nnz_cap, np.asarray(a.values).dtype)
    indices[:keep] = np.asarray(a.indices)[:keep]
    values[:keep] = np.asarray(a.values)[:keep]
    return CSR(indptr=a.indptr, indices=jnp.asarray(indices),
               values=jnp.asarray(values), shape=a.shape)


def prepare_sparse_inputs(a: CSR, b: CSR, policy: str):
    """Bucket the operand buffer caps and size the expansion: the shared
    preamble of every sparse-path entry point (``spgemm()`` and
    ``executor.spgemm_grouped``), so the inputs feeding ``structure_key``
    can never drift between them. Returns (a, b, fm, maxrf, fm_cap)."""
    a = _repad_csr(a, round_capacity(max(int(a.indptr[-1]), 1), policy))
    b = _repad_csr(b, round_capacity(max(int(b.indptr[-1]), 1), policy))
    fm, maxrf = (int(x) for x in _fm_scalars(a, b))
    return a, b, fm, maxrf, round_capacity(fm, policy)


def resolve_plan(a: CSR, b: CSR, fm_cap: int, policy: str, cache, key=None):
    """Get-or-build the numeric plan for (repadded) A, B.

    The single source of truth for plan resolution — both ``spgemm()`` and
    ``executor.spgemm_grouped`` go through here, so the structure key, the
    nnz_cap bucketing, and the cache put/get can never drift apart (a drift
    would silently replay a plan with the wrong capacities). ``key`` lets a
    caller that already hashed the structure (the grouping loop) skip the
    second O(nnz) digest.

    Returns (plan, cache_state, key) with cache_state in {"hit", "miss",
    "bypass"} — the key is returned so callers can attach per-entry
    metadata (e.g. the autotuner's measured winner) without re-hashing.
    """
    from repro.core.plan_cache import structure_key  # cycle-free late import

    if key is None:
        key = structure_key(a, b, fm_cap, policy)
    if cache is not None:
        plan = cache.get(key)
        if plan is not None:
            return plan, "hit", key
    with span("plan.build", structure_key=key, fm_cap=fm_cap) as sp:
        sx = expand_and_sort(a, b, fm_cap)
        nnz_cap = round_capacity(int(jnp.sum(sx.row_sizes)), policy)
        sp.set("nnz_cap", nnz_cap)
        plan = plan_from_sorted(sx, b.k, nnz_cap)
    if cache is None:
        return plan, "bypass", key
    cache.put(key, plan)
    return plan, "miss", key


def _measured_replay(plan, a: CSR, b: CSR, cache, cache_key: str):
    """tune="measure" replay: dispatch the measured-fastest replay backend.

    Winner resolution order (each layer avoids re-tuning the next):
      1. the plan-cache entry's sidecar meta (dtype-qualified — the
         structure key excludes value dtypes on purpose),
      2. the autotuner's structure-stats bucket table,
      3. a first-sight micro-bench of the eligible replay backends on the
         real operands (recorded in the bucket table).
    The winner is written back to the plan-cache entry so later replays and
    ``spgemm_grouped`` re-dispatch it with zero re-tuning.
    """
    from repro.core import autotune
    from repro.core.executor import _apply, replay_candidates

    interp = jax.default_backend() != "tpu"
    meta_key = ("tuned_backend", str(a.values.dtype), str(b.values.dtype))
    winner = cache.get_meta(cache_key, meta_key) if cache is not None else None
    if winner is not None:
        autotune.TUNE_COUNTS["plan_meta_hit"] += 1
    else:
        bkey = autotune.bucket_key(
            a.m, b.k, plan.seg_ids.shape[0], a.values.dtype, b.values.dtype,
            table="replay")
        winner = autotune.lookup_measured(bkey)
        if winner is None:
            winner, _ = autotune.measure_and_record(
                bkey, replay_candidates(plan, a.values, b.values, interp))
        if cache is not None:
            cache.set_meta(cache_key, meta_key, winner)
    values = _apply(plan, a.values, b.values, backend=winner,
                    interpret=interp)
    return values, winner


def spgemm(a: CSR, b: CSR, method: str = "auto", compress: str = "auto",
           pad_policy: str | None = None, plan_cache=None,
           tune: str | None = None,
           mesh=None, mesh_axis: str = "data",
           b_placement: str = "replicated",
           validate: str | None = None,
           trace: str | bool | None = None) -> SpgemmResult:
    """Full two-phase SpGEMM with the KKSPGEMM meta-algorithm's method choice
    (see core/meta.py for the heuristics).

    pad_policy: capacity bucketing for every static cap ("pow2" default;
        "exact8" restores tight per-size caps — see core.meta.round_capacity).
    plan_cache: None (default) uses the module-level LRU from
        core/plan_cache.py; pass a PlanCache for an isolated cache, or False
        to disable caching for this call. On a structure hit, the sparse path
        skips the expansion and sort entirely (stats["cache"] == "hit").
    compress: only affects the "dense" method's symbolic phase. The sparse
        path needs the plain expansion for its numeric plan anyway, so
        compression would add work, not save it — its stats (cf/cmrf/
        compressed) are therefore only present on the dense path; use
        ``symbolic()`` directly to inspect compression on any matrix.
    mesh: a JAX mesh routes the multiply through ``repro.dist``: C's rows
        are 1-D partitioned over ``mesh_axis``, the sharded plan comes from
        (and lands in) the mesh-aware plan cache, and the numeric phase runs
        under shard_map in one dispatch. ``b_placement`` picks "replicated"
        (B everywhere, zero communication) or "allgather" (B row-sharded,
        one values-only all-gather per call). Implies the sparse method.

    The dense method returns ``plan=None``: KKDENSE has no product->slot map
    and therefore no Reuse fast path. Callers that need structure reuse (or a
    ``ReuseExecutor``) must use ``method="sparse"``.

    method="lp" is the KKLP position made explicit: the same single-expansion
    sparse pipeline (plan, cache, Reuse path all intact) but the numeric
    values come from the Pallas LP-hash accumulator kernel
    (``kernels/spgemm_lp.py``; interpret mode off-TPU) — with an automatic
    XLA fallback for f64/int operand dtypes, which the f32-accumulating
    kernel must not touch. ``stats["kernel"]`` always records what
    ``choose_kernel`` would pick ('dense_acc' below the avg-row-flops
    cutoff, 'flat_lp' at or above); ``stats["lp_backend"]`` records which
    backend the lp method actually used ("pallas" or "xla").

    validate: "off" (default via None) | "host" | "device" — typed operand
        validation before any dispatch (``runtime/validate.py``): CSR
        invariant violations raise ``SpgemmInputError``, a claimed nnz past
        the buffer cap raises ``CapacityOverflowError``. "host" pulls the
        structure to numpy and reports exact violation indices; "device"
        runs one jitted bitmask sweep with a single scalar sync. ``None``
        defers to ``$REPRO_VALIDATE``. "off" is bit-for-bit the pre-existing
        dispatch path (no extra traces/hashes — telemetry-asserted in
        tests/test_validate.py).

    tune="measure" (sparse/auto-sparse only) switches the replay dispatch to
    the autotuner: on first sight of a structure-stats bucket the eligible
    replay backends are micro-benchmarked on the real operands and the
    winner is cached — in the autotuner's bucket table and in the plan-cache
    entry — so replays re-dispatch it with zero re-tuning
    (``stats["kernel_source"] == "measured"``, ``stats["replay_backend"]``
    records the winner). The dense method ignores tune (its choosers are
    advisory there, and KKDENSE has no replay to re-dispatch); method="lp"
    rejects it (lp *is* an explicit backend pin); mesh= rejects it (the
    sharded replay is XLA-only, see ROADMAP).

    trace: None (default) | bool | "off" | "on" | "xprof" — phase tracing for
        this call (``repro.obs``): "on" records nesting spans
        (``spgemm.prepare``, ``plan.build``, ``numeric.dispatch``, ...) for
        Chrome trace-event export and feeds the per-phase latency histograms;
        "xprof" additionally wraps each span in
        ``jax.profiler.TraceAnnotation``. ``None`` defers to the ambient mode
        (ultimately ``$REPRO_TRACE``, mirroring how ``validate=None`` defers
        to ``$REPRO_VALIDATE``). "off" pins tracing off for this call; the
        untraced path is dispatch-identical (telemetry-asserted in
        tests/test_obs.py).
    """
    from repro.core import autotune  # cycle-free
    from repro.core.meta import choose_kernel, choose_method  # cycle-free
    from repro.core.plan_cache import default_plan_cache

    from repro.runtime.validate import (SpgemmConfigError, check_csr,  # cycle-free
                                        resolve_mode)

    if trace is not None:
        # Pin the trace mode for this call's full extent, then re-enter with
        # trace=None so the body below runs unchanged under the pinned scope.
        with trace_scope(trace):
            return spgemm(a, b, method=method, compress=compress,
                          pad_policy=pad_policy, plan_cache=plan_cache,
                          tune=tune, mesh=mesh, mesh_axis=mesh_axis,
                          b_placement=b_placement, validate=validate,
                          trace=None)
    policy = DEFAULT_PAD_POLICY if pad_policy is None else pad_policy
    if method not in ("auto", "dense", "sparse", "lp"):
        raise SpgemmConfigError(
            f"unknown method {method!r}; expected 'auto', 'dense', 'sparse' "
            f"or 'lp'")
    autotune.validate_tune(tune)
    vmode = resolve_mode(validate)
    if vmode != "off":
        check_csr(a, vmode, name="A")
        check_csr(b, vmode, name="B")
    if tune == "measure" and method == "lp":
        raise SpgemmConfigError(
            "tune='measure' does not compose with method='lp': 'lp' pins "
            "the LP-hash kernel explicitly, while measure mode exists to "
            "pick the replay backend empirically — use method='sparse' (or "
            "'auto') with tune='measure'")
    if mesh is not None:
        if tune is not None:
            raise SpgemmConfigError(
                "tune= does not support mesh= yet: the sharded replay runs "
                "the XLA segment-sum only, so there are no per-shard "
                "candidates to measure (see ROADMAP)")
        if method == "dense":
            raise SpgemmConfigError(
                "mesh= requires the sparse method: KKDENSE has no "
                "product->slot map, so it cannot pin a sharded plan")
        if method == "lp":
            raise SpgemmConfigError(
                "mesh= does not support method='lp' yet: the sharded replay "
                "runs the XLA segment-sum only (see ROADMAP: Pallas path "
                "under shard_map); use method='sparse' on a mesh")
        from repro.dist import sharded_spgemm  # cycle-free late import

        return sharded_spgemm(a, b, mesh, axis=mesh_axis,
                              b_placement=b_placement, pad_policy=policy,
                              plan_cache=plan_cache)
    stats: dict = {"pad_policy": policy, "validate": vmode}
    if method == "auto":
        method = choose_method(a, b, stats)  # shape-only heuristics
    stats["method"] = method

    if method == "dense":
        with span("spgemm.symbolic", method="dense"):
            sizes, sym_stats = symbolic(a, b, compress=compress,
                                        pad_policy=policy)
        stats.update(sym_stats)
        stats["kernel"] = choose_kernel(a, b, stats)  # advisory telemetry
        fm_cap = round_capacity(sym_stats["fm"], policy)
        stats["fm_cap"] = fm_cap
        nnz = int(jnp.sum(sizes))
        nnz_cap = round_capacity(nnz, policy)
        stats["nnz_c"] = nnz
        stats["nnz_cap"] = nnz_cap
        stats["cache"] = "bypass"
        with span("numeric.dispatch", kernel="dense_acc", method="dense"):
            c = numeric_dense_acc(a, b, fm_cap, nnz_cap)
        return SpgemmResult(c=c, plan=None, stats=stats)

    # "sparse"/"lp": single-expansion pipeline through the plan cache. Bucket
    # the input buffer caps *before* any jitted work, so every array shape
    # the jitted stages (including the f_m scalars) see is a bucket size —
    # that's what lets same-bucket matrices share executables.
    if plan_cache is None:
        cache = default_plan_cache()
    elif plan_cache is False:
        cache = None
    else:
        cache = plan_cache
    with span("spgemm.prepare", pad_policy=policy):
        a, b, fm, maxrf, fm_cap = prepare_sparse_inputs(a, b, policy)
    stats["fm"] = fm
    stats["maxrf"] = maxrf
    stats["fm_cap"] = fm_cap
    stats["kernel"] = choose_kernel(a, b, stats)  # the paper's GPU rule

    plan, cache_state, skey = resolve_plan(a, b, fm_cap, policy, cache)
    stats["structure_key"] = skey
    if method == "lp":
        with span("numeric.dispatch", method="lp") as sp:
            values, stats["lp_backend"] = lp_replay_values(
                plan, a.values, b.values)
            sp.set("kernel", stats["lp_backend"])
        stats["replay_backend"] = stats["lp_backend"]
        if stats["lp_backend"] == "xla":
            # host-side bump (trace-time bumps are unreliable): the f32-
            # accumulation dtype guard rerouted the LP pin to exact XLA
            from repro.core.telemetry import FALLBACK_COUNTS

            FALLBACK_COUNTS["dtype:lp->xla"] += 1
    elif tune == "measure":
        with span("numeric.dispatch", method="measure") as sp:
            values, winner = _measured_replay(plan, a, b, cache, skey)
            sp.set("kernel", winner)
        stats["replay_backend"] = winner
        stats["kernel_source"] = "measured"  # overrides choose_kernel's
    else:
        with span("numeric.dispatch", kernel="xla", method=method):
            values = numeric_reuse(plan, a.values, b.values)
        stats["replay_backend"] = "xla"
    c = CSR(indptr=plan.indptr, indices=plan.indices, values=values,
            shape=(a.m, b.k))
    stats["cache"] = cache_state
    stats["nnz_c"] = int(plan.indptr[-1])
    stats["nnz_cap"] = plan.indices.shape[0]
    return SpgemmResult(c=c, plan=plan, stats=stats)


@jax.jit
def _fm_scalars(a: CSR, b: CSR):
    fm, _, maxrf = flops_stats(a, b.row_nnz())
    return fm, maxrf


@jax.jit
def _per_slot(a: CSR, row_nnz: jax.Array, nb: int):
    valid = a.valid_mask()
    return jnp.where(valid, row_nnz[jnp.minimum(a.indices, nb - 1)], 0)
