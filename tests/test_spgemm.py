"""Core SpGEMM tests: two-phase vs Gustavson oracle, compression rules,
reuse semantics, meta-algorithm — including hypothesis property tests."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    COMPRESSION_CF_CUTOFF,
    compress_matrix,
    compression_decision,
    flops_stats,
    numeric_dense_acc,
    numeric_fresh,
    numeric_reuse,
    spgemm,
    symbolic,
    symbolic_dense_bitmask,
    bitmask_rows,
    choose_method,
)
from repro.core.meta import DENSE_K_CUTOFF
from repro.sparse import (
    CSR,
    banded_csr,
    dense_spgemm_oracle,
    galerkin_triple,
    gustavson_numpy,
    random_csr,
    rmat_csr,
    stencil2d_csr,
)
from repro.sparse.formats import csr_to_ell


CASES = [
    (random_csr(40, 50, 3.0, 1), random_csr(50, 45, 2.5, 2)),
    (rmat_csr(5, 5, 3), rmat_csr(5, 5, 4)),
    (banded_csr(48, 2, 5), banded_csr(48, 3, 6)),
    (stencil2d_csr(7, 7), stencil2d_csr(7, 7)),
]


@pytest.mark.parametrize("a,b", CASES)
@pytest.mark.parametrize("method", ["sparse", "dense"])
def test_spgemm_matches_oracle(a, b, method):
    res = spgemm(a, b, method=method)
    np.testing.assert_allclose(
        np.asarray(res.c.to_dense()), dense_spgemm_oracle(a, b),
        rtol=1e-4, atol=1e-4,
    )
    # structure: sorted per row, identical to Gustavson's
    ip, ind, _, _ = gustavson_numpy(a, b)
    np.testing.assert_array_equal(np.asarray(res.c.indptr), ip)
    np.testing.assert_array_equal(np.asarray(res.c.indices)[: ip[-1]], ind)


@pytest.mark.parametrize("a,b", CASES)
def test_symbolic_row_sizes(a, b):
    ip, _, _, _ = gustavson_numpy(a, b)
    for compress in ("auto", "always", "never"):
        sizes, stats = symbolic(a, b, compress=compress)
        np.testing.assert_array_equal(np.asarray(sizes), np.diff(ip))


def test_two_phase_reuse_equals_fresh():
    """The paper's Reuse case: same structure, new values, no recompute of
    the symbolic phase — results must equal a fresh run."""
    a = random_csr(30, 40, 3.0, 11)
    b = random_csr(40, 35, 2.0, 12)
    res = spgemm(a, b, method="sparse")
    rng = np.random.default_rng(0)
    new_avals = jnp.asarray(rng.standard_normal(a.nnz_cap), jnp.float32)
    new_bvals = jnp.asarray(rng.standard_normal(b.nnz_cap), jnp.float32)
    a2 = CSR(a.indptr, a.indices, new_avals, a.shape)
    b2 = CSR(b.indptr, b.indices, new_bvals, b.shape)
    reused = numeric_reuse(res.plan, a2.values, b2.values)
    fresh = spgemm(a2, b2, method="sparse")
    nnz = int(fresh.c.nnz())
    np.testing.assert_allclose(
        np.asarray(reused)[:nnz], np.asarray(fresh.c.values)[:nnz],
        rtol=1e-4, atol=1e-5,
    )


def test_explicit_zeros_kept():
    """Numerical cancellation must keep the symbolic structure (the paper's
    accumulators track occupancy, not value != 0)."""
    a = CSR.from_dense(np.array([[1.0, 1.0]], np.float32))
    b = CSR.from_dense(np.array([[1.0], [-1.0]], np.float32))
    res = spgemm(a, b, method="sparse")
    assert int(res.c.nnz()) == 1  # structurally present
    assert abs(float(res.c.values[0])) < 1e-6  # numerically zero


def test_compression_rules():
    # banded matrices compress well (packed columns)
    a = banded_csr(64, 4, 1)
    bc = compress_matrix(a)
    cf, cmrf, use = compression_decision(a, a, bc)
    assert cf < COMPRESSION_CF_CUTOFF and use
    # 1-nnz-per-row matrices cannot compress
    p = CSR.from_dense(np.eye(32, 8, dtype=np.float32).repeat(1, axis=0))
    r, A, p = galerkin_triple(6, 6, 4)
    bcp = compress_matrix(p)
    cf_p, _, use_p = compression_decision(A, p, bcp)
    assert cf_p == 1.0 and not use_p


def test_compressed_sizes_match_bitmask_rows():
    b = random_csr(30, 100, 4.0, 3)
    bc = compress_matrix(b)
    bm = np.asarray(bitmask_rows(b))
    popc = np.unpackbits(bm.view(np.uint8), axis=1).sum(1)
    rn = np.asarray(bc.row_nnz())
    # compressed row sizes == #distinct CSI per row
    ip = np.asarray(b.indptr)
    ix = np.asarray(b.indices)
    for i in range(b.m):
        csis = set(int(c) >> 5 for c in ix[ip[i]: ip[i + 1]])
        assert rn[i] == len(csis)


def test_dense_bitmask_symbolic():
    a = stencil2d_csr(8, 8)
    b = stencil2d_csr(8, 8)
    ell = csr_to_ell(a)
    bm = bitmask_rows(b)
    sizes = symbolic_dense_bitmask(ell, bm, block_rows=16)
    ip, _, _, _ = gustavson_numpy(a, b)
    np.testing.assert_array_equal(np.asarray(sizes), np.diff(ip))


def test_meta_algorithm_cutoffs():
    small_b = random_csr(10, 100, 2.0, 1)
    big_b = CSR(
        indptr=small_b.indptr, indices=small_b.indices,
        values=small_b.values, shape=(10, DENSE_K_CUTOFF + 1),
    )
    a = random_csr(10, 10, 2.0, 2)
    assert choose_method(a, small_b, {}) == "dense"
    assert choose_method(a, big_b, {}) == "sparse"


# NOTE: the meta-algorithm regression tests (choose_method memory guard,
# choose_kernel KeyError, unknown-method validation, lp_insert clamp) live in
# tests/test_lp_kernel.py — this module is collection-skipped when hypothesis
# is absent (conftest.py), and those guards must run everywhere.


def test_triple_product_galerkin():
    """R*A*P multigrid product (24 of the paper's 83 cases are R*A*P)."""
    r, a, p = galerkin_triple(8, 8, 4)
    ap = spgemm(a, p).c
    rap = spgemm(r, ap).c
    want = (np.asarray(r.to_dense()) @ np.asarray(a.to_dense())
            @ np.asarray(p.to_dense()))
    np.testing.assert_allclose(np.asarray(rap.to_dense()), want, rtol=1e-4,
                               atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(2, 24), n=st.integers(2, 24), k=st.integers(2, 24),
    da=st.floats(0.5, 4.0), db=st.floats(0.5, 4.0),
    seed=st.integers(0, 99999),
)
def test_spgemm_property(m, n, k, da, db, seed):
    """For arbitrary random CSR pairs: dense(spgemm(A,B)) == dense(A)@dense(B)
    and symbolic sizes == structural product row sizes."""
    a = random_csr(m, n, da, seed)
    b = random_csr(n, k, db, seed + 1)
    res = spgemm(a, b)
    np.testing.assert_allclose(
        np.asarray(res.c.to_dense()), dense_spgemm_oracle(a, b),
        rtol=1e-3, atol=1e-3,
    )
    sizes, _ = symbolic(a, b)
    mask = (np.asarray(a.to_dense()) != 0) @ (np.asarray(b.to_dense()) != 0)
    np.testing.assert_array_equal(np.asarray(sizes), (mask > 0).sum(1))


def test_flops_stats():
    a = random_csr(20, 30, 2.0, 4)
    b = random_csr(30, 25, 3.0, 5)
    fm, row_flops, maxrf = flops_stats(a, b.row_nnz())
    _, _, _, rf = gustavson_numpy(a, b)
    np.testing.assert_array_equal(np.asarray(row_flops), rf)
    assert int(fm) == rf.sum() and int(maxrf) == rf.max()
