"""Pallas TPU kernel: expert-grouped matmul — the MoE numeric phase.

MoE dispatch is the one place modern LMs contain a true sparse-matrix
product (DESIGN.md §4): the token->expert dispatch matrix is a top-k-sparse
CSR whose "row pointers" are the per-expert group offsets. Routing is the
symbolic phase (counts only, no FLOPs); this kernel is the numeric phase —
Gustavson's row-wise accumulation at block granularity, with the B-block
gather (here: the expert weight tile) steered by the scalar-prefetched group
structure exactly like spgemm_numeric steers its B-row gather.

Tokens arrive sorted by expert and padded so no block spans two experts.
grid = (token_blocks, f_tiles, d_tiles); weights for block tb come from
``block_expert[tb]`` via the index_map.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

TM = 128  # token-block rows (MXU-aligned)


def _kernel(block_expert_ref, x_ref, w_ref, out_ref, acc_ref):
    dt = pl.program_id(2)
    n_d = pl.num_programs(2)

    @pl.when(dt == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[0].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(dt == n_d - 1)
    def _emit():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile_f", "tile_d", "interpret"))
def grouped_matmul(x: jax.Array, w: jax.Array, block_expert: jax.Array, *,
                   tile_f: int = 128, tile_d: int = 128,
                   interpret: bool = False) -> jax.Array:
    """y[t] = x[t] @ w[expert(t)] for expert-sorted, block-aligned tokens.

    x: (T, d) with T % TM == 0; w: (E, d, f); block_expert: (T // TM,) int32.
    """
    t, d = x.shape
    e, dw, f = w.shape
    assert d == dw and t % TM == 0 and d % tile_d == 0 and f % tile_f == 0

    grid = (t // TM, f // tile_f, d // tile_d)
    return pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((TM, tile_d), lambda tb, ft, dt, be: (tb, dt)),
                pl.BlockSpec(
                    (1, tile_d, tile_f), lambda tb, ft, dt, be: (be[tb], dt, ft)
                ),
            ],
            out_specs=pl.BlockSpec((TM, tile_f), lambda tb, ft, dt, be: (tb, ft)),
            scratch_shapes=[pltpu.VMEM((TM, tile_f), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((t, f), x.dtype),
        interpret=interpret,
    )(block_expert, x, w)
