"""Accumulator data-structure tests (paper §3.1.2): LL / LP semantics,
two-level L1/L2 spill, max-occupancy rule, memory pool modes."""
import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core.accumulators import (
    MAX_OCCUPANCY,
    accumulate_row,
    extract_sorted,
    ll_init,
    ll_insert,
    lp_init,
    lp_insert,
)
from repro.core.memory_pool import acquire_release_sim, chunk_for_step, size_pool


def _as_dict(ids, vals, live):
    return {int(k): float(v) for k, v, ok in zip(ids, vals, live) if ok}


def _merged(l1, l2, l1_live, l2_live):
    d1 = _as_dict(*extract_sorted(l1.ids, l1.values, l1_live))
    d2 = _as_dict(*extract_sorted(l2.ids, l2.values, l2_live))
    out = dict(d1)
    for k, v in d2.items():
        out[k] = out.get(k, 0.0) + v
    return out


def _oracle(keys, vals, valid):
    d = {}
    for k, v, ok in zip(keys, vals, valid):
        if ok:
            d[int(k)] = d.get(int(k), 0.0) + float(v)
    return d


def test_ll_insert_accumulate():
    keys = jnp.array([5, 3, 5, 9, 3, 3, 17, 5], jnp.int32)
    vals = jnp.array([1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0])
    valid = jnp.ones(8, bool)
    l1, l2, spilled = accumulate_row(keys, vals, valid, 8, 16, 16, "ll")
    assert not bool(spilled)
    got = _merged(l1, l2, jnp.arange(16) < l1.used, jnp.arange(16) < l2.used)
    assert got == _oracle(keys, vals, valid)


def test_ll_full_spills_to_l2():
    """L1 capacity 2: first two distinct keys stay, rest spill — and keys
    already in L1 keep accumulating there (paper Alg. 3 lines 7-10)."""
    keys = jnp.array([5, 3, 9, 17, 5, 9], jnp.int32)
    vals = jnp.array([1.0, 2.0, 3.0, 4.0, 5.0, 6.0])
    valid = jnp.ones(6, bool)
    l1, l2, spilled = accumulate_row(keys, vals, valid, 4, 2, 8, "ll")
    assert bool(spilled)
    d1 = _as_dict(*extract_sorted(l1.ids, l1.values, jnp.arange(2) < l1.used))
    assert d1 == {5: 6.0, 3: 2.0}  # key 5 accumulated in L1 even after full
    got = _merged(l1, l2, jnp.arange(2) < l1.used, jnp.arange(8) < l2.used)
    assert got == _oracle(keys, vals, valid)


def test_lp_max_occupancy_rule():
    """LP rejects NEW keys past 50% occupancy but still accumulates into
    existing ones (paper: max-occupancy cutoff)."""
    size = 8  # cutoff = 4
    keys = jnp.array([0, 1, 2, 3, 4, 0], jnp.int32)
    vals = jnp.array([1.0, 1.0, 1.0, 1.0, 1.0, 9.0])
    l1, l2, spilled = accumulate_row(
        keys, vals, jnp.ones(6, bool), size, size, 8, "lp"
    )
    assert bool(spilled)
    d1 = _as_dict(*extract_sorted(l1.ids, l1.values, l1.ids >= 0))
    assert 4 not in d1 and d1[0] == 10.0
    got = _merged(l1, l2, l1.ids >= 0, jnp.arange(8) < l2.used)
    assert got == _oracle(keys, vals, jnp.ones(6, bool))


def test_lp_collision_probing():
    """Keys hashing to the same slot linear-probe (paper Fig. 4c)."""
    st8 = lp_init(8)
    st8, ok1 = lp_insert(st8, jnp.int32(4), jnp.float32(1.0))
    st8, ok2 = lp_insert(st8, jnp.int32(12), jnp.float32(2.0))  # 12 & 7 == 4
    assert bool(ok1) and bool(ok2)
    assert int(st8.ids[4]) == 4 and int(st8.ids[5]) == 12


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 30), keyspace=st.integers(1, 40),
    l1_cap=st.sampled_from([2, 4, 8]), seed=st.integers(0, 9999),
    kind=st.sampled_from(["ll", "lp"]),
)
def test_two_level_property(n, keyspace, l1_cap, seed, kind):
    """Any insert stream: merged L1+L2 contents == dict oracle, provided L2
    is sized at MAXRF (the memory pool guarantee)."""
    rng = np.random.default_rng(seed)
    keys = jnp.asarray(rng.integers(0, keyspace, n), jnp.int32)
    vals = jnp.asarray(rng.standard_normal(n), jnp.float32)
    valid = jnp.asarray(rng.random(n) < 0.9)
    l1, l2, _ = accumulate_row(keys, vals, valid, l1_cap, l1_cap, n + 1, kind)
    l1_live = (jnp.arange(l1_cap) < l1.used) if kind == "ll" else (l1.ids >= 0)
    got = _merged(l1, l2, l1_live, jnp.arange(n + 1) < l2.used)
    want = _oracle(keys, vals, valid)
    assert set(got) == set(want)
    for k in want:
        np.testing.assert_allclose(got[k], want[k], rtol=1e-5, atol=1e-6)


# NOTE: the hypothesis-free lp_insert regression tests (max_occupancy
# validation, clamped-cutoff termination) live in tests/test_lp_kernel.py —
# this module is collection-skipped when hypothesis is absent (conftest.py).


def _sorted_segment_oracle(keys, vals, valid):
    """Per-key f32 sums via an explicit sorted-segment pass: stable sort by
    key (stream order preserved within a segment), then a sequential f32
    running sum that resets at segment heads — the accumulation-order ground
    truth the LP/LL maps must match bitwise."""
    keys = np.asarray(keys)
    vals = np.asarray(vals, np.float32)
    valid = np.asarray(valid)
    live = np.where(valid)[0]
    order = live[np.argsort(keys[live], kind="stable")]
    out: dict[int, np.float32] = {}
    acc = np.float32(0.0)
    for pos, t in enumerate(order):
        k = int(keys[t])
        if pos == 0 or int(keys[order[pos - 1]]) != k:
            acc = np.float32(0.0)
        acc = np.float32(acc + vals[t])
        out[k] = acc
    return out


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(8, 48), keyspace=st.integers(3, 16),
    l1_cap=st.sampled_from([4, 8]), seed=st.integers(0, 9999),
)
def test_lp_spill_extraction_bitwise_vs_sorted_segment_oracle(
        n, keyspace, l1_cap, seed):
    """Streams that exceed the 50% cutoff: merged L1 + L2-spill extraction
    must match the sorted-segment oracle BITWISE (same f32 adds in the same
    stream order, whether a key accumulated in L1 or spilled to L2)."""
    rng = np.random.default_rng(seed)
    keys = jnp.asarray(rng.integers(0, keyspace, n), jnp.int32)
    vals = jnp.asarray(rng.standard_normal(n), jnp.float32)
    valid = jnp.asarray(rng.random(n) < 0.9)
    l1, l2, spilled = accumulate_row(keys, vals, valid, l1_cap, l1_cap,
                                     n + 1, "lp")
    distinct = len({int(k) for k, ok in zip(keys, valid) if ok})
    cutoff = min(int(l1_cap * MAX_OCCUPANCY), l1_cap - 1)
    assert bool(spilled) == (distinct > cutoff)  # the spill path really ran
    want = _sorted_segment_oracle(keys, vals, valid)
    got: dict[int, np.float32] = {}
    for k, v, ok in zip(np.asarray(l1.ids), np.asarray(l1.values),
                        np.asarray(l1.ids) >= 0):
        if ok:
            got[int(k)] = v
    l2_live = np.arange(l2.values.shape[0]) < int(l2.used)
    for k, v, ok in zip(np.asarray(l2.ids), np.asarray(l2.values), l2_live):
        if ok:
            assert int(k) not in got  # a key lives in exactly one level
            got[int(k)] = v
    assert set(got) == set(want)
    for k in want:
        # bitwise: same f32 accumulation order, no tolerance
        assert np.float32(got[k]).tobytes() == np.float32(want[k]).tobytes()


def test_pool_sizing():
    cfg = size_pool(maxrf=1000, concurrency=16, mode="one2one")
    assert cfg.chunk_size == 1000 and cfg.num_chunks == 16
    # budget shrinks NUMCHUNKS (paper's GPU fallback)
    cfg = size_pool(maxrf=1000, concurrency=16, mode="many2many",
                    bytes_budget=2 * 1000 * 8)
    assert cfg.num_chunks == 2
    assert chunk_for_step(cfg, 5) == 1


def test_pool_many2many_scan():
    """Concurrent threads with overlapping holds scan to distinct chunks."""
    got = acquire_release_sim(
        jnp.array([0, 0, 0, 0], jnp.int32),  # all want chunk 0
        jnp.array([10, 10, 10, 10], jnp.int32),  # held past the horizon
        num_chunks=4,
    )
    assert sorted(np.asarray(got).tolist()) == [0, 1, 2, 3]
