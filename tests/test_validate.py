"""Validation layer + retry/watchdog wiring + fallback-provenance tests.

Covers: typed CSR construction checks, resolve_mode/$REPRO_VALIDATE, the
validate="off" dispatch-identity guarantee (telemetry-asserted), the
f64/int XLA-fallback provenance agreement across all three entry points,
retry_call determinism, and the watchdog-guarded replay path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import telemetry
from repro.core.executor import ReuseExecutor
from repro.core.spgemm import spgemm
from repro.kernels.ops import numeric_values
from repro.runtime.retry import RetryExhaustedError, backoff_schedule, retry_call
from repro.runtime.validate import (VALIDATE_MODES, PlanMismatchError,
                                    SpgemmInputError, resolve_mode)
from repro.runtime.watchdog import StepWatchdog, StragglerDetected
from repro.sparse import csr_to_ell, random_csr
from repro.sparse.formats import CSR


@pytest.fixture
def ab():
    return random_csr(32, 24, 4.0, seed=1), random_csr(24, 40, 4.0, seed=2)


# --------------------------------------------------------------------------
# CSR.from_arrays host-side checks (satellite c)
# --------------------------------------------------------------------------


def test_from_arrays_rejects_short_indptr():
    with pytest.raises(SpgemmInputError, match="indptr"):
        CSR.from_arrays([0, 1], [0], [1.0], (4, 4))


def test_from_arrays_rejects_length_mismatch():
    with pytest.raises(SpgemmInputError, match="len\\(indices\\)"):
        CSR.from_arrays([0, 1, 2], [0, 1], [1.0], (2, 4))


def test_from_arrays_rejects_bad_shape():
    with pytest.raises(SpgemmInputError, match="shape"):
        CSR.from_arrays([0, 1], [0], [1.0], (1, -4))
    with pytest.raises(SpgemmInputError, match="shape"):
        CSR.from_arrays([0, 1], [0], [1.0], (1, 2, 3))


def test_from_arrays_escape_hatch():
    # fault injection and jitted callers build bad CSRs on purpose
    bad = CSR.from_arrays([0, 1], [0], [1.0, 2.0], (1, 4), validate=False)
    assert bad.indices.shape[0] != bad.values.shape[0]


def test_from_arrays_accepts_valid():
    m = CSR.from_arrays([0, 2, 3], [1, 3, 0], [1.0, 2.0, 3.0], (2, 4))
    assert m.nnz_cap == 3 and m.shape == (2, 4)


# --------------------------------------------------------------------------
# resolve_mode / $REPRO_VALIDATE
# --------------------------------------------------------------------------


def test_resolve_mode_default_off(monkeypatch):
    monkeypatch.delenv("REPRO_VALIDATE", raising=False)
    assert resolve_mode(None) == "off"


def test_resolve_mode_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_VALIDATE", "host")
    assert resolve_mode(None) == "host"
    assert resolve_mode("off") == "off"  # explicit beats the env


def test_resolve_mode_rejects_typo():
    with pytest.raises(ValueError, match="unknown validate mode"):
        resolve_mode("host ")
    assert VALIDATE_MODES == ("off", "host", "device")


def test_spgemm_stats_record_mode(ab, monkeypatch):
    monkeypatch.delenv("REPRO_VALIDATE", raising=False)
    a, b = ab
    assert spgemm(a, b, method="sparse").stats["validate"] == "off"
    assert spgemm(a, b, method="sparse",
                  validate="host").stats["validate"] == "host"


# --------------------------------------------------------------------------
# validate="off" is dispatch-identical (acceptance criterion)
# --------------------------------------------------------------------------


def test_validate_off_replay_dispatch_identical(ab, monkeypatch):
    monkeypatch.delenv("REPRO_VALIDATE", raising=False)
    a, b = ab
    ex = ReuseExecutor.from_matrices(a, b)
    ex.apply(a.values, b.values)  # warm the jit cache
    before = telemetry.snapshot()
    for _ in range(5):
        ex.apply(a.values, b.values)
    after = telemetry.snapshot()
    # zero added retraces and zero added structure hashes across 5 replays
    assert after["trace"] == before["trace"]
    assert after["hash"] == before["hash"]
    assert after["fallback"] == before["fallback"]
    assert after["dispatch"]["apply"] == before["dispatch"]["apply"] + 5
    assert ex._guard is None  # off mode builds no guard at all


def test_validate_host_replay_adds_no_traces_or_hashes(ab):
    # host-mode per-replay checks are O(1) python — still no device work
    a, b = ab
    ex = ReuseExecutor.from_matrices(a, b, validate="host")
    ex.apply(a.values, b.values)
    before = telemetry.snapshot()
    for _ in range(5):
        ex.apply(a.values, b.values)
    after = telemetry.snapshot()
    assert after["trace"] == before["trace"]
    assert after["hash"] == before["hash"]


def test_validated_result_matches_unvalidated(ab):
    a, b = ab
    base = spgemm(a, b, method="sparse")
    for mode in ("host", "device"):
        res = spgemm(a, b, method="sparse", validate=mode)
        assert bool(jnp.all(res.c.values == base.c.values))


# --------------------------------------------------------------------------
# f64/int XLA-fallback provenance agrees across entry points (satellite d)
# --------------------------------------------------------------------------


def _int_operands():
    a = random_csr(24, 16, 3.0, seed=5)
    b = random_csr(16, 20, 3.0, seed=6)
    to_int = lambda m: CSR(indptr=m.indptr, indices=m.indices,
                           values=jnp.ones_like(m.values, jnp.int32),
                           shape=m.shape)
    return to_int(a), to_int(b)


def test_fallback_provenance_spgemm_lp():
    a, b = _int_operands()
    res = spgemm(a, b, method="lp")
    assert res.stats["lp_backend"] == "xla"
    assert telemetry.FALLBACK_COUNTS["dtype:lp->xla"] == 1


def test_fallback_provenance_executor_pallas_lp():
    a, b = _int_operands()
    ex = ReuseExecutor.from_matrices(a, b, backend="pallas_lp")
    ex.apply(a.values, b.values)
    assert telemetry.FALLBACK_COUNTS["dtype:executor->xla"] == 1


def test_fallback_provenance_numeric_values_auto():
    a, b = _int_operands()
    res = spgemm(a, b, method="sparse")
    c_ell = csr_to_ell(res.c)
    numeric_values(a, b, c_ell.indices, c_ell.row_nnz, kernel="auto")
    assert telemetry.FALLBACK_COUNTS["dtype:numeric_auto->xla"] == 1
    assert telemetry.KERNEL_COUNTS["xla"] == 1  # stats["kernel"] agreement


def test_fallback_rule_cannot_drift_between_entry_points():
    # the same int operands must fall back at EVERY entry point: if any one
    # of the three dtype counters stays 0 the rule has drifted
    a, b = _int_operands()
    spgemm(a, b, method="lp")
    ReuseExecutor.from_matrices(a, b, backend="pallas_lp").apply(
        a.values, b.values)
    res = spgemm(a, b, method="sparse")
    c_ell = csr_to_ell(res.c)
    numeric_values(a, b, c_ell.indices, c_ell.row_nnz, kernel="auto")
    for key in ("dtype:lp->xla", "dtype:executor->xla",
                "dtype:numeric_auto->xla"):
        assert telemetry.FALLBACK_COUNTS[key] >= 1, key


def test_f32_operands_do_not_bump_dtype_counters(ab):
    a, b = ab
    spgemm(a, b, method="lp")
    assert telemetry.FALLBACK_COUNTS["dtype:lp->xla"] == 0


# --------------------------------------------------------------------------
# retry_call
# --------------------------------------------------------------------------


def test_retry_succeeds_after_transient_failures():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("transient")
        return "ok"

    slept = []
    assert retry_call(flaky, retries=3, sleep=slept.append) == "ok"
    assert calls["n"] == 3 and len(slept) == 2


def test_retry_schedule_is_deterministic_and_bounded():
    s1 = backoff_schedule(4, base_delay_s=0.05, max_delay_s=0.2, seed=7)
    s2 = backoff_schedule(4, base_delay_s=0.05, max_delay_s=0.2, seed=7)
    assert s1 == s2
    assert all(d <= 0.2 * 1.5 for d in s1)  # max delay * (1 + jitter)
    assert s1 != backoff_schedule(4, base_delay_s=0.05, max_delay_s=0.2,
                                  seed=8)


def test_retry_typed_give_up():
    def always_fails():
        raise RuntimeError("down")

    slept = []
    with pytest.raises(RetryExhaustedError) as ei:
        retry_call(always_fails, retries=2, sleep=slept.append)
    assert ei.value.attempts == 3
    assert isinstance(ei.value.last_error, RuntimeError)
    assert len(slept) == 2


def test_retry_does_not_retry_deterministic_errors():
    calls = {"n": 0}

    def bad_input():
        calls["n"] += 1
        raise SpgemmInputError("corrupt operand")

    with pytest.raises(SpgemmInputError):
        retry_call(bad_input, retries=5, sleep=lambda d: None)
    assert calls["n"] == 1  # no retry: the input won't get less corrupt

    def mismatched():
        calls["n"] += 1
        raise PlanMismatchError("wrong plan")

    with pytest.raises(PlanMismatchError):
        retry_call(mismatched, retries=5, sleep=lambda d: None)
    assert calls["n"] == 2


def test_retry_on_retry_hook():
    events = []

    def flaky():
        if len(events) < 1:
            raise RuntimeError("once")
        return 1

    retry_call(flaky, retries=2, sleep=lambda d: None,
               on_retry=lambda att, e, d: events.append((att, str(e))))
    assert events == [(0, "once")]


# --------------------------------------------------------------------------
# Watchdog-guarded replay
# --------------------------------------------------------------------------


def test_executor_watchdog_records_slow_replay(ab):
    a, b = ab
    wd = StepWatchdog(deadline_s=0.0, policy="warn")  # everything is slow
    ex = ReuseExecutor.from_matrices(a, b, watchdog=wd)
    ex.apply(a.values, b.values)
    ex.apply_batched(jnp.stack([a.values, a.values]), b.values)
    assert len(wd.slow_steps) == 2
    assert all(dt > 0 for _, dt in wd.slow_steps)


def test_executor_watchdog_raise_policy(ab):
    a, b = ab
    wd = StepWatchdog(deadline_s=0.0, policy="raise")
    ex = ReuseExecutor.from_matrices(a, b, watchdog=wd)
    with pytest.raises(StragglerDetected):
        ex.apply(a.values, b.values)


def test_executor_no_watchdog_stays_async(ab):
    a, b = ab
    ex = ReuseExecutor.from_matrices(a, b)
    out = ex.apply(a.values, b.values)
    assert isinstance(out, jax.Array)  # unblocked dispatch, plain array out
