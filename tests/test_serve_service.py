"""Serving-tier contract tests: bounded admission with typed backpressure,
deadline shedding at both ends, grouped single-dispatch batching, the
circuit breaker's full state walk, traffic-log warming, and the retry /
eviction / breaker telemetry satellites."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import telemetry
from repro.core.executor import DISPATCH_COUNTS
from repro.core.plan_cache import EVICT_COUNTS, PlanCache
from repro.core.spgemm import spgemm
from repro.runtime import faults
from repro.runtime.retry import retry_call
from repro.runtime.validate import (AdmissionRejected, DeadlineExceeded,
                                    SpgemmError, SpgemmInputError)
from repro.serve import (CircuitBreaker, SparseService, TrafficLog,
                         warm_plan_cache)
from repro.serve.breaker import CLOSED, HALF_OPEN, OPEN
from repro.sparse import random_csr


class FakeClock:
    def __init__(self, now=0.0):
        self.now = now

    def __call__(self):
        return self.now

    def advance(self, dt):
        self.now += dt


@pytest.fixture
def ab():
    return random_csr(32, 24, 4.0, seed=1), random_csr(24, 40, 4.0, seed=2)


def oracle_dense(a, b):
    return spgemm(a, b, method="sparse").c.to_dense()


# --------------------------------------------------------------------------
# Admission: backpressure, validation at the door, deadline feasibility
# --------------------------------------------------------------------------


def test_queue_full_rejects_typed(ab):
    a, b = ab
    svc = SparseService(max_queue=2)
    r1, r2 = svc.submit(a, b), svc.submit(a, b)
    r3 = svc.submit(a, b)
    assert not r1.done and not r2.done
    assert r3.done and isinstance(r3.error, AdmissionRejected)
    assert isinstance(r3.error, SpgemmError)  # taxonomy, catchable as such
    assert svc.counters["shed_queue_full"] == 1
    assert svc.queue_depth == 2  # the rejected request never queued


def test_corrupt_operand_rejected_at_door(ab):
    a, b = ab
    bad = faults.inject_csr("nan_values", a)
    svc = SparseService()  # validate="host" is the serving default
    r = svc.submit(bad, b)
    assert r.done and isinstance(r.error, SpgemmInputError)
    assert svc.counters["rejected_validation"] == 1
    assert svc.queue_depth == 0
    # a healthy request right after is unaffected
    assert not svc.submit(a, b).done


def test_validate_off_admits_anything(ab):
    a, b = ab
    bad = faults.inject_csr("nan_values", a)
    svc = SparseService(validate="off")
    assert not svc.submit(bad, b).done  # caller's risk, admitted


def test_infeasible_deadline_shed_at_admission(ab):
    a, b = ab
    clk = FakeClock()
    svc = SparseService(clock=clk)
    svc.step_hint_s = 1.0  # as if measured: one tick costs 1s
    r = svc.submit(a, b, deadline_s=0.5)
    assert r.done and isinstance(r.error, AdmissionRejected)
    assert "infeasible" in str(r.error)
    assert svc.counters["shed_deadline_infeasible"] == 1
    # a feasible deadline is admitted under the same estimate
    assert not svc.submit(a, b, deadline_s=5.0).done


def test_idle_service_admits_any_deadline(ab):
    a, b = ab
    svc = SparseService(clock=FakeClock())
    # no step has run -> no latency estimate -> optimistic admission
    assert not svc.submit(a, b, deadline_s=1e-9).done


def test_expired_deadline_shed_in_queue(ab):
    a, b = ab
    clk = FakeClock()
    svc = SparseService(clock=clk)
    r_dead = svc.submit(a, b, deadline_s=1.0)
    r_live = svc.submit(a, b)  # no deadline
    clk.advance(2.0)
    resolved = svc.step()
    assert resolved == 2
    assert isinstance(r_dead.error, DeadlineExceeded)
    assert isinstance(r_dead.error, TimeoutError)  # stdlib-catchable
    assert r_live.ok
    assert svc.counters["shed_deadline_expired"] == 1
    assert svc.counters["completed"] == 1
    assert svc.counters["failed"] == 0  # a shed is not a failure
    assert svc.stats()["shed_rate"] == 0.5


# --------------------------------------------------------------------------
# Batch loop: grouping, dispatch counts, priorities, the empty tick
# --------------------------------------------------------------------------


def test_grouped_batch_one_dispatch_per_group(ab):
    a, b = ab
    a2, b2 = random_csr(16, 24, 3.0, seed=7), random_csr(24, 8, 3.0, seed=8)
    svc = SparseService(max_batch=8)
    same = [svc.submit(a, b) for _ in range(3)]
    other = svc.submit(a2, b2)
    DISPATCH_COUNTS.clear()
    svc.step()
    # 3 same-structure requests -> ONE batched dispatch; the odd one out
    # dispatches alone
    assert DISPATCH_COUNTS["apply_batched"] == 1
    assert DISPATCH_COUNTS["apply"] == 1
    ref, ref2 = oracle_dense(a, b), oracle_dense(a2, b2)
    for r in same:
        assert r.ok and r.group_size == 3
        assert bool(jnp.all(r.value.to_dense() == ref))  # bitwise
    assert other.ok and other.group_size == 1
    assert bool(jnp.all(other.value.to_dense() == ref2))


def test_max_batch_spills_to_next_step(ab):
    a, b = ab
    svc = SparseService(max_batch=2)
    rs = [svc.submit(a, b) for _ in range(5)]
    assert svc.step() == 2 and svc.queue_depth == 3
    assert svc.drain() == 3
    assert all(r.ok for r in rs)
    assert svc.counters["steps"] == 3


def test_priority_order_under_scarce_batch(ab):
    a, b = ab
    svc = SparseService(max_batch=1)
    r_low = svc.submit(a, b, priority=0)
    r_high = svc.submit(a, b, priority=5)
    svc.step()
    assert r_high.done and not r_low.done  # higher priority jumped the line
    svc.step()
    assert r_low.done


def test_empty_step_is_a_noop():
    svc = SparseService()
    DISPATCH_COUNTS.clear()
    assert svc.step() == 0
    assert DISPATCH_COUNTS["apply"] == 0
    assert DISPATCH_COUNTS["apply_batched"] == 0


def test_plan_cache_eviction_mid_stream_is_invisible(ab):
    a, b = ab
    svc = SparseService()
    r1 = svc.submit(a, b)
    svc.step()
    svc.plan_cache.clear()  # forced eviction between steps
    r2 = svc.submit(a, b)
    svc.step()
    ref = oracle_dense(a, b)
    assert r1.ok and r2.ok
    assert bool(jnp.all(r2.value.to_dense() == ref))


# --------------------------------------------------------------------------
# Circuit breaker: unit walk + integrated routing
# --------------------------------------------------------------------------


def test_breaker_state_walk_with_fake_clock():
    clk = FakeClock()
    br = CircuitBreaker("k", failure_threshold=2, window_s=10.0,
                        cooldown_s=5.0, clock=clk)
    assert br.allow() and br.state == CLOSED
    br.record_failure()
    assert br.state == CLOSED  # below threshold
    br.record_failure()
    assert br.state == OPEN
    assert telemetry.BREAKER_COUNTS["k:open"] == 1
    assert not br.allow()  # short-circuit during cooldown
    assert telemetry.BREAKER_COUNTS["k:short_circuit"] == 1
    clk.advance(5.0)
    assert br.allow() and br.state == HALF_OPEN  # the probe
    assert telemetry.BREAKER_COUNTS["k:half_open"] == 1
    assert not br.allow()  # only ONE probe at a time
    br.record_failure()  # probe verdict: still broken
    assert br.state == OPEN
    assert telemetry.BREAKER_COUNTS["k:reopen"] == 1
    clk.advance(5.0)
    assert br.allow()  # second probe
    br.record_success()
    assert br.state == CLOSED
    assert telemetry.BREAKER_COUNTS["k:close"] == 1
    assert br.snapshot()["recent_failures"] == 0


def test_breaker_window_forgets_stale_failures():
    clk = FakeClock()
    br = CircuitBreaker("k", failure_threshold=2, window_s=1.0, clock=clk)
    br.record_failure()
    clk.advance(2.0)  # first failure ages out of the window
    br.record_failure()
    assert br.state == CLOSED


def test_service_breaker_routes_around_broken_kernel(ab):
    a, b = ab
    clk = FakeClock()
    svc = SparseService(backend="pallas", max_batch=1, clock=clk,
                        breaker_threshold=2, breaker_cooldown_s=5.0)
    ref = oracle_dense(a, b)

    def serve_one():
        r = svc.submit(a, b)
        svc.step()
        assert r.ok and bool(jnp.all(r.value.to_dense() == ref))
        return r

    with faults.failpoint("kernel:pallas"):
        # two degraded dispatches trip the breaker (correct via the ladder)
        for _ in range(2):
            assert serve_one().degraded
        assert svc._breakers["pallas"].state == OPEN
        # open: traffic short-circuits straight to XLA — no ladder cost
        fallbacks0 = telemetry.FALLBACK_COUNTS["fault:pallas->xla"]
        r = serve_one()
        assert r.backend == "xla" and not r.degraded
        assert telemetry.FALLBACK_COUNTS["fault:pallas->xla"] == fallbacks0
        # cooldown elapses while the kernel is STILL broken: probe fails,
        # breaker reopens, later traffic short-circuits again
        clk.advance(5.0)
        assert serve_one().degraded  # the probe (correct, via ladder)
        assert svc._breakers["pallas"].state == OPEN
        assert telemetry.BREAKER_COUNTS["pallas:reopen"] == 1
    # kernel fixed + cooldown elapsed: probe succeeds, fast path re-admitted
    clk.advance(5.0)
    r = serve_one()
    assert r.backend == "pallas" and not r.degraded
    assert svc._breakers["pallas"].state == CLOSED
    assert telemetry.BREAKER_COUNTS["pallas:close"] == 1
    assert serve_one().backend == "pallas"
    assert svc.counters["degraded_dispatches"] == 3


def test_batched_groups_never_consult_breaker(ab):
    a, b = ab
    svc = SparseService(backend="pallas", max_batch=4)
    rs = [svc.submit(a, b) for _ in range(3)]
    with faults.failpoint("kernel:pallas"):
        svc.step()  # batched -> XLA vmap formulation, failpoint never hit
    assert all(r.ok and r.backend == "xla" for r in rs)
    assert svc._breakers["pallas"].state == CLOSED
    assert svc._breakers["pallas"].snapshot()["recent_failures"] == 0


# --------------------------------------------------------------------------
# Retry integration + telemetry satellites
# --------------------------------------------------------------------------


def test_retry_counts_tick_and_reset():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise OSError("transient")
        return "ok"

    assert retry_call(flaky, retries=3, label="t", sleep=lambda _: None) == "ok"
    assert telemetry.RETRY_COUNTS["t:attempt"] == 3
    assert telemetry.RETRY_COUNTS["t:retry"] == 2
    assert telemetry.RETRY_COUNTS["t:giveup"] == 0
    assert telemetry.ALL_COUNTERS["retry"] is telemetry.RETRY_COUNTS
    telemetry.reset_all()  # the conftest fixture's hygiene, asserted
    assert not telemetry.RETRY_COUNTS
    assert not telemetry.BREAKER_COUNTS
    assert not EVICT_COUNTS


def test_retry_label_defaults_to_fn_name():
    def transient_once():
        raise OSError("nope")

    with pytest.raises(Exception):
        retry_call(transient_once, retries=1, sleep=lambda _: None)
    assert telemetry.RETRY_COUNTS["transient_once:attempt"] == 2
    assert telemetry.RETRY_COUNTS["transient_once:giveup"] == 1


def test_service_dispatch_retries_transient_straggler(ab):
    # a kernel:xla failpoint that clears after the first hit models a
    # transient device hiccup: retry_call lands the second attempt
    a, b = ab
    svc = SparseService(max_batch=1, retries=2, sleep=lambda _: None)
    r = svc.submit(a, b)
    faults.arm("kernel:xla")
    orig_sleep = svc._sleep

    def disarm_then(dt):
        faults.disarm("kernel:xla")
        orig_sleep(dt)

    svc._sleep = disarm_then
    svc.step()
    assert r.ok
    assert telemetry.RETRY_COUNTS["serve.dispatch:retry"] == 1
    assert svc.stats()["retry"]["retries"] == 1


def test_service_dispatch_gives_up_typed(ab):
    a, b = ab
    svc = SparseService(max_batch=1, retries=1, sleep=lambda _: None)
    r = svc.submit(a, b)
    with faults.failpoint("kernel:xla"):
        svc.step()
    assert r.done and not r.ok
    assert isinstance(r.error, SpgemmError)  # typed, never a bare crash
    assert telemetry.RETRY_COUNTS["serve.dispatch:giveup"] == 1
    assert svc.counters["failed"] == 1


# --------------------------------------------------------------------------
# Warmer: traffic log, prefetch, eviction tolerance
# --------------------------------------------------------------------------


def test_traffic_log_counts_structures(ab):
    a, b = ab
    a2, b2 = random_csr(16, 24, 3.0, seed=7), random_csr(24, 8, 3.0, seed=8)
    log = TrafficLog()
    for _ in range(3):
        log.record(a, b)
    log.record(a2, b2)
    assert len(log) == 2
    top = log.top()
    assert top[0].count == 3 and top[1].count == 1
    assert log.top(1) == [top[0]]


def test_warm_plan_cache_prefetches(ab):
    a, b = ab
    log = TrafficLog()
    log.record(a, b)
    cache = PlanCache(capacity=8, name="warmtest")
    stats = warm_plan_cache(log, cache)
    assert stats == {"built": 1, "hits": 0, "failed": 0, "evictions": 0}
    # warming again is all hits; serving after warming never misses
    assert warm_plan_cache(log, cache)["hits"] == 1
    svc = SparseService(plan_cache=cache)
    misses0 = cache.stats()["misses"]  # the warm's own build was the miss
    r = svc.submit(a, b)
    svc.step()
    assert r.ok and cache.stats()["misses"] == misses0


def test_warm_detects_cache_thrash(ab):
    # a warm set bigger than the cache must finish AND report the churn
    mats = [(random_csr(8 + 4 * i, 16, 2.0, seed=10 + i),
             random_csr(16, 8, 2.0, seed=50 + i)) for i in range(4)]
    log = TrafficLog()
    for a, b in mats:
        log.record(a, b)
    cache = PlanCache(capacity=2, name="thrash")
    stats = warm_plan_cache(log, cache)
    assert stats["built"] == 4
    assert stats["evictions"] == 2  # 4 plans through a 2-entry LRU
    assert EVICT_COUNTS["thrash"] == 2


def test_service_warms_from_its_own_traffic(ab):
    a, b = ab
    svc = SparseService()
    r = svc.submit(a, b)
    svc.step()
    assert r.ok
    svc.plan_cache.clear()
    stats = svc.warm()  # rebuild from the log recorded at admission
    assert stats["built"] == 1
    # the warmed entry serves the next request as a pure hit
    misses0 = svc.plan_cache.stats()["misses"]
    svc.submit(a, b)
    svc.step()
    assert svc.plan_cache.stats()["misses"] == misses0


def test_admission_records_traffic_without_extra_hash(ab):
    from repro.core import telemetry

    a, b = ab
    svc = SparseService()
    svc.submit(a, b)
    before = telemetry.snapshot()
    svc.submit(a, b)  # second request: still exactly one hash each
    delta = telemetry.diff(before, telemetry.snapshot())
    assert delta.get("hash") == {"structure_key": 1}, delta
    assert svc.traffic_log.top()[0].count == 2


# --------------------------------------------------------------------------
# Config validation
# --------------------------------------------------------------------------


def test_bad_config_raises():
    with pytest.raises(ValueError, match="backend"):
        SparseService(backend="cuda")
    with pytest.raises(ValueError, match="max_queue"):
        SparseService(max_queue=0)
    with pytest.raises(ValueError, match="failure_threshold"):
        CircuitBreaker("k", failure_threshold=0)
