"""Multiplicity-aware HLO cost model for the dry-run roofline.

Why: XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE
(verified: an 8-step scanned matmul reports 1/8 of the unrolled FLOPs), and
our layer stacks / attention / SSD are scans. This parser walks the
compiled HLO text, recovers each while's trip count from its
``backend_config={"known_trip_count":{"n":...}}``, and propagates
multiplicities through the call graph (while bodies x trip count, fusion
bodies count their internal dot FLOPs but no internal HBM bytes, branches
x1). Dot FLOPs = 2 * prod(result dims) * prod(lhs contracting dims);
bytes = result + operand sizes of every materializing op; collective bytes
grouped by kind. Validated against cost_analysis() on unrolled programs in
tests/test_roofline.py.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
    "s2": 1, "u2": 1,
}

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(.*?\)|[\w\[\],\s]+?\{?[\d,]*\}?)\s+"
    r"([\w\-]+)\((.*?)\)(.*)$"
)
_SHAPE = re.compile(r"([a-z]\w*)\[([0-9,]*)\]")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS = re.compile(r"calls=%?([\w.\-]+)")
_BODY = re.compile(r"body=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERAND = re.compile(r"%([\w.\-]+)")

_ZERO_COST = {
    "get-tuple-element", "tuple", "parameter", "bitcast", "constant",
    "after-all", "partition-id", "replica-id", "iota",
}
_CONTROL = {"while", "conditional", "call", "fusion", "custom-call",
            "async-start", "async-done", "async-update"}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")


def _shape_info(type_str: str):
    """Return (total_bytes, [list of (dtype, dims)]) for a result type."""
    shapes = []
    total = 0
    for dtype, dims_s in _SHAPE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        dims = [int(d) for d in dims_s.split(",") if d]
        n = 1
        for d in dims:
            n *= d
        shapes.append((dtype, dims))
        total += n * _DTYPE_BYTES[dtype]
    return total, shapes


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    type_str: str
    operands: list
    attrs: str


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    ops: list
    shapes: dict  # op name -> type_str
    root: str | None = None  # name of the ROOT op


def parse_hlo(text: str):
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    entry = None
    for line in text.splitlines():
        stripped = line.strip()
        if cur is None:
            m = _COMP_HDR.match(stripped)
            if m:
                cur = Computation(
                    name=m.group(2), is_entry=bool(m.group(1)), ops=[], shapes={}
                )
                if cur.is_entry:
                    entry = cur.name
            continue
        if stripped == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_LINE.match(line)
        if not m:
            continue
        name, type_str, kind, operand_str, attrs = m.groups()
        operands = _OPERAND.findall(operand_str)
        cur.shapes[name] = type_str
        # parameters carry their type in the header; handle `parameter(0)`
        cur.ops.append(Op(name, kind, type_str, operands, attrs))
        if stripped.startswith("ROOT "):
            cur.root = name
    return comps, entry


def _fusion_write_bytes(comps, called: str, default: float) -> float:
    """Effective bytes WRITTEN by a fusion: if its root is an in-place
    dynamic-update-slice (or a tuple of them), only the update slices hit
    HBM, not the whole aliased buffer."""
    c = comps.get(called)
    if c is None or c.root is None:
        return default
    by_name = {op.name: op for op in c.ops}
    root = by_name.get(c.root)
    if root is None:
        return default

    def op_write(op) -> float:
        if op.kind == "dynamic-update-slice" and len(op.operands) > 1:
            return _shape_info(c.shapes.get(op.operands[1], ""))[0]
        return _shape_info(op.type_str)[0]

    if root.kind == "dynamic-update-slice":
        return op_write(root)
    if root.kind == "tuple":
        total = 0.0
        for o in root.operands:
            inner = by_name.get(o)
            total += op_write(inner) if inner is not None else \
                _shape_info(c.shapes.get(o, ""))[0]
        return total
    return default


def analyze_hlo(text: str) -> dict:
    comps, entry = parse_hlo(text)
    if entry is None:
        return {"flops": 0.0, "bytes": 0.0, "collectives": {"total": 0.0, "count": 0}}

    # multiplicities via BFS from entry
    mult: dict[str, float] = defaultdict(float)
    bytes_on: dict[str, bool] = {}
    mult[entry] = 1.0
    bytes_on[entry] = True
    queue = [entry]
    seen_edges = set()
    while queue:
        cname = queue.pop()
        c = comps.get(cname)
        if c is None:
            continue
        m = mult[cname]
        count_bytes = bytes_on.get(cname, True)
        for op in c.ops:
            key = (cname, op.name)
            if key in seen_edges:
                continue
            seen_edges.add(key)
            if op.kind == "while":
                trip_m = _TRIP.search(op.attrs)
                trip = int(trip_m.group(1)) if trip_m else 1
                for rx, tmult in ((_BODY, trip), (_COND, 0)):
                    mm = rx.search(op.attrs)
                    if mm and mm.group(1) in comps:
                        mult[mm.group(1)] += m * tmult
                        bytes_on[mm.group(1)] = count_bytes
                        queue.append(mm.group(1))
            elif op.kind == "fusion":
                mm = _CALLS.search(op.attrs)
                if mm and mm.group(1) in comps:
                    mult[mm.group(1)] += m
                    bytes_on[mm.group(1)] = False  # internals aren't HBM traffic
                    queue.append(mm.group(1))
            elif op.kind == "conditional":
                mm = _BRANCHES.search(op.attrs)
                if mm:
                    for b in _OPERAND.findall(mm.group(1)):
                        if b in comps:
                            mult[b] += m
                            bytes_on[b] = count_bytes
                            queue.append(b)
            elif op.kind in ("call", "async-start", "custom-call"):
                mm = _CALLS.search(op.attrs)
                if mm and mm.group(1) in comps:
                    mult[mm.group(1)] += m
                    bytes_on[mm.group(1)] = count_bytes
                    queue.append(mm.group(1))

    # Per-computation: which parameters are only ever sliced (a fusion that
    # dynamic-slices a stacked scan param reads the slice, not the array).
    _SLICE_KINDS = ("slice", "dynamic-slice", "gather")
    param_touch: dict[str, dict[int, float]] = {}
    for cname, c in comps.items():
        touches: dict[int, float] = {}
        params = []
        for op in c.ops:
            if op.kind == "parameter":
                params.append(op.name)
        for idx, pname in enumerate(params):
            uses = [o for o in c.ops if pname in o.operands]
            if uses and all(
                u.kind in _SLICE_KINDS and u.operands and u.operands[0] == pname
                for u in uses
            ):
                touches[idx] = sum(_shape_info(u.type_str)[0] for u in uses)
        param_touch[cname] = touches

    flops = 0.0
    byts = 0.0
    coll: dict[str, float] = defaultdict(float)
    coll_count = 0

    for cname, c in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        count_bytes = bytes_on.get(cname, True)
        for op in c.ops:
            rbytes, rshapes = _shape_info(op.type_str)
            if op.kind == "dot":
                cm = _CONTRACT.search(op.attrs)
                k = 1
                if cm and op.operands:
                    lhs_type = c.shapes.get(op.operands[0], "")
                    _, lhs_shapes = _shape_info(lhs_type)
                    if lhs_shapes:
                        dims = lhs_shapes[0][1]
                        for ci in cm.group(1).split(","):
                            if ci and int(ci) < len(dims):
                                k *= dims[int(ci)]
                n_out = 1
                for _, ds in rshapes:
                    for d in ds:
                        n_out *= d
                flops += m * 2.0 * n_out * k
            base_kind = op.kind[:-6] if op.kind.endswith("-start") else op.kind
            if base_kind in _COLLECTIVES:
                coll[base_kind] += m * rbytes
                coll_count += int(m)
            if count_bytes and (
                op.kind == "fusion"
                or (op.kind not in _ZERO_COST and op.kind not in _CONTROL
                    and not op.kind.endswith("-done"))
            ):
                if op.kind in ("slice", "dynamic-slice", "gather"):
                    # touches only the sliced extent: read + write = 2x result
                    byts += m * 2 * rbytes
                elif op.kind in ("dynamic-update-slice", "scatter"):
                    # in-place update: traffic ~ the update operand
                    upd = (
                        _shape_info(c.shapes.get(op.operands[1], ""))[0]
                        if len(op.operands) > 1 else rbytes
                    )
                    byts += m * 2 * upd
                elif op.kind == "fusion":
                    cm = _CALLS.search(op.attrs)
                    called = cm.group(1) if cm else ""
                    touches = param_touch.get(called, {})
                    obytes = 0.0
                    for i, o in enumerate(op.operands):
                        if i in touches:
                            obytes += touches[i]  # sliced-only param
                        else:
                            obytes += _shape_info(c.shapes.get(o, ""))[0]
                    wbytes = _fusion_write_bytes(comps, called, rbytes)
                    if wbytes < rbytes:
                        # in-place DUS fusion: the aliased buffer operand
                        # (full-size in the operand list) is read only at
                        # the update extent.
                        obytes = max(obytes - rbytes + wbytes, 0.0)
                    byts += m * (wbytes + obytes)
                else:
                    obytes = sum(
                        _shape_info(c.shapes.get(o, ""))[0] for o in op.operands
                    )
                    byts += m * (rbytes + obytes)

    out = dict(coll)
    out_total = sum(out.values())
    return {
        "flops": flops,
        "bytes": byts,
        "collectives": {**out, "total": out_total, "count": coll_count},
    }
