"""Two-phase SpGEMM (paper Alg. 2/3) adapted to XLA's static-shape regime.

Phase contract (identical to the paper's host/device split):
  1. ``symbolic``  — jitted; returns per-row nnz of C (no FLOPs). Uses the
     compressed matrix when the CF <= 0.85 rule fires.
  2. host         — materializes ``indptr`` and the concrete nnz(C).
  3. ``numeric``  — jitted at that size; fills C. The first run also emits a
     ``SpgemmPlan`` (structure + product->slot map). Re-running with new
     values but the same structure (the paper's *Reuse* case) is a pure
     gather/segment-sum — no hashing, no sort, no recompile.

Accumulation strategy per the TPU adaptation (DESIGN.md §2): sorted-segment
accumulation (Thread-Flat-Parallel semantics — associative, atomic-free) and
dense scatter accumulation (KKDENSE). Hash accumulators live in
``core/accumulators.py`` (jittable LL/LP ports) and ``kernels/`` (Pallas).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compression import (
    CompressedMatrix,
    compress_matrix,
    compression_decision,
    flops_stats,
)
from repro.core.utils import popcount, segmented_scan, segment_ends
from repro.sparse.formats import CSR, csr_row_ids


class ProductExpansion(NamedTuple):
    """Flattened multiplication space: the paper's Thread-Flat-Parallel view.

    Product t multiplies A-slot ``a_slot[t]`` with B-slot ``b_slot[t]`` and
    lands in C row ``row[t]``, column ``col[t]``. ``valid`` masks padding.
    """

    row: jax.Array
    col: jax.Array
    a_slot: jax.Array
    b_slot: jax.Array
    valid: jax.Array


class SpgemmPlan(NamedTuple):
    """Cached numeric plan enabling the Reuse fast path."""

    indptr: jax.Array  # (m+1,) int32 — C row pointers
    indices: jax.Array  # (nnz_cap,) int32 — C columns, sorted per row
    order: jax.Array  # (fm_cap,) int32 — product sort permutation
    seg_ids: jax.Array  # (fm_cap,) int32 — sorted product -> C slot
    a_slot: jax.Array  # (fm_cap,) int32
    b_slot: jax.Array  # (fm_cap,) int32
    valid: jax.Array  # (fm_cap,) bool
    shape: tuple  # (m, k) of C


@partial(jax.jit, static_argnames=("fm_cap",))
def expand_products(a: CSR, b: CSR, fm_cap: int) -> ProductExpansion:
    """Enumerate all f_m multiplications with static capacity ``fm_cap``.

    For product t: binary-search the owning A-slot in the exclusive prefix of
    per-A-slot product counts, then offset into B's row. Fully vectorized.
    """
    b_row_nnz = b.row_nnz()
    a_valid = a.valid_mask()
    per_slot = jnp.where(
        a_valid, b_row_nnz[jnp.minimum(a.indices, b.m - 1)], 0
    ).astype(jnp.int32)
    offsets = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(per_slot).astype(jnp.int32)]
    )  # (nnzA+1,)
    t = jnp.arange(fm_cap, dtype=jnp.int32)
    a_slot = (
        jnp.searchsorted(offsets, t, side="right").astype(jnp.int32) - 1
    ).clip(0, a.nnz_cap - 1)
    within = t - offsets[a_slot]
    valid = t < offsets[-1]
    j = a.indices[a_slot]
    b_slot = (b.indptr[jnp.minimum(j, b.m - 1)] + within).clip(0, b.nnz_cap - 1)
    rows = csr_row_ids(a.indptr, a.nnz_cap)[a_slot]
    col = b.indices[b_slot]
    return ProductExpansion(
        row=jnp.where(valid, rows, a.m),  # pad rows to m -> sorts to the end
        col=jnp.where(valid, col, 0),
        a_slot=a_slot,
        b_slot=b_slot,
        valid=valid,
    )


def host_fm_cap(a: CSR, b: CSR, pad_to: int = 8) -> int:
    """Host-side f_m (total products) rounded up — the static expansion size."""
    fm, _, _ = flops_stats(a, b.row_nnz())
    fm = int(fm)
    return max(-(-fm // pad_to) * pad_to, pad_to)


# --------------------------------------------------------------------------
# Symbolic phase
# --------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("fm_cap", "m"))
def _symbolic_sorted(rows, keys, payload, valid, m: int, fm_cap: int):
    """Shared core: sort (row, key) pairs, OR payloads per group, count groups
    per row (plain symbolic: payload == popcount 1 per distinct column)."""
    order = jnp.lexsort((keys, rows))
    rows_s, keys_s, valid_s = rows[order], keys[order], valid[order]
    pay_s = payload[order]
    heads = jnp.concatenate(
        [
            jnp.ones((1,), jnp.bool_),
            (rows_s[1:] != rows_s[:-1]) | (keys_s[1:] != keys_s[:-1]),
        ]
    )
    or_scan = segmented_scan(pay_s, heads, jnp.bitwise_or)
    ends = segment_ends(heads) & valid_s
    contrib = jnp.where(ends, popcount(or_scan), 0).astype(jnp.int32)
    sizes = jnp.zeros((m,), jnp.int32).at[jnp.minimum(rows_s, m - 1)].add(
        jnp.where(valid_s, contrib, 0), mode="drop"
    )
    return sizes


@partial(jax.jit, static_argnames=("fm_cap", "m"))
def symbolic_compressed(a: CSR, bc: CompressedMatrix, m: int, fm_cap: int) -> jax.Array:
    """Symbolic phase on the compressed B (paper §3.2): expand (row, CSI, CS)
    products, OR the CS masks per (row, CSI), sum popcounts per row."""
    bc_row_nnz = bc.row_nnz()
    a_valid = a.valid_mask()
    nb = bc.indptr.shape[0] - 1
    per_slot = jnp.where(
        a_valid, bc_row_nnz[jnp.minimum(a.indices, nb - 1)], 0
    ).astype(jnp.int32)
    offsets = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(per_slot).astype(jnp.int32)]
    )
    t = jnp.arange(fm_cap, dtype=jnp.int32)
    a_slot = (
        jnp.searchsorted(offsets, t, side="right").astype(jnp.int32) - 1
    ).clip(0, a.nnz_cap - 1)
    within = t - offsets[a_slot]
    valid = t < offsets[-1]
    j = jnp.minimum(a.indices[a_slot], nb - 1)
    cap = bc.csi.shape[0]
    b_slot = (bc.indptr[j] + within).clip(0, cap - 1)
    rows = jnp.where(valid, csr_row_ids(a.indptr, a.nnz_cap)[a_slot], m)
    keys = jnp.where(valid, bc.csi[b_slot], 0)
    cs = jnp.where(valid, bc.cs[b_slot], jnp.uint32(0))
    return _symbolic_sorted(rows, keys, cs, valid, m, fm_cap)


@partial(jax.jit, static_argnames=("fm_cap",))
def symbolic_plain(a: CSR, b: CSR, fm_cap: int) -> jax.Array:
    """Uncompressed symbolic: distinct-column count per row via sort."""
    ex = expand_products(a, b, fm_cap)
    ones = jnp.where(ex.valid, jnp.uint32(1), jnp.uint32(0))
    return _symbolic_sorted(ex.row, ex.col, ones, ex.valid, a.m, fm_cap)


@partial(jax.jit, static_argnames=("block_rows",))
def symbolic_dense_bitmask(a_ell, b_bitmask: jax.Array, block_rows: int = 64) -> jax.Array:
    """KKDENSE symbolic: per row-block, gather B's bitmask rows and OR-reduce
    into a dense (block_rows, ceil(k/32)) accumulator — the dense-accumulator
    symbolic with 32x compression. Memory-bounded via lax.map over blocks."""
    m = a_ell.m
    k32 = b_bitmask.shape[1]
    r_pad = a_ell.r_pad
    n_blocks = -(-m // block_rows)
    pad_m = n_blocks * block_rows
    idx = jnp.pad(a_ell.indices, ((0, pad_m - m), (0, 0)))
    rnnz = jnp.pad(a_ell.row_nnz, (0, pad_m - m))
    idx = idx.reshape(n_blocks, block_rows, r_pad)
    rnnz = rnnz.reshape(n_blocks, block_rows)

    def block(args):
        bi, brn = args  # (block_rows, r_pad), (block_rows,)
        masks = b_bitmask[bi.clip(0, b_bitmask.shape[0] - 1)]  # (BR, r_pad, k32)
        live = (
            jnp.arange(r_pad, dtype=jnp.int32)[None, :, None] < brn[:, None, None]
        )
        masks = jnp.where(live, masks, jnp.uint32(0))
        acc = jax.lax.reduce(
            masks, jnp.uint32(0), jnp.bitwise_or, dimensions=(1,)
        )  # (BR, k32)
        return jnp.sum(popcount(acc), axis=-1).astype(jnp.int32)

    sizes = jax.lax.map(block, (idx, rnnz))
    return sizes.reshape(pad_m)[:m]


# --------------------------------------------------------------------------
# Numeric phase
# --------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("fm_cap", "nnz_cap"))
def numeric_fresh(a: CSR, b: CSR, fm_cap: int, nnz_cap: int):
    """First numeric run: discovers C's structure and the product->slot map,
    computes values. Returns (CSR C, SpgemmPlan)."""
    ex = expand_products(a, b, fm_cap)
    order = jnp.lexsort((ex.col, ex.row)).astype(jnp.int32)
    rows_s = ex.row[order]
    cols_s = ex.col[order]
    valid_s = ex.valid[order]
    heads = jnp.concatenate(
        [
            jnp.ones((1,), jnp.bool_),
            (rows_s[1:] != rows_s[:-1]) | (cols_s[1:] != cols_s[:-1]),
        ]
    )
    heads = heads & valid_s  # padding (row==m) groups don't mint slots
    seg_ids = (jnp.cumsum(heads.astype(jnp.int32)) - 1).clip(0).astype(jnp.int32)

    # C structure: one slot per group head.
    c_indices = jnp.zeros((nnz_cap,), jnp.int32).at[seg_ids].max(
        jnp.where(heads, cols_s, 0), mode="drop"
    )
    row_sizes = jnp.zeros((a.m,), jnp.int32).at[jnp.minimum(rows_s, a.m - 1)].add(
        (heads & valid_s).astype(jnp.int32), mode="drop"
    )
    indptr = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(row_sizes).astype(jnp.int32)]
    )
    plan = SpgemmPlan(
        indptr=indptr,
        indices=c_indices,
        order=order,
        seg_ids=jnp.where(valid_s, seg_ids, nnz_cap),  # padded -> dropped
        a_slot=ex.a_slot,
        b_slot=ex.b_slot,
        valid=ex.valid,
        shape=(a.m, b.k),
    )
    values = numeric_reuse(plan, a.values, b.values)
    c = CSR(indptr=indptr, indices=c_indices, values=values, shape=(a.m, b.k))
    return c, plan


@jax.jit
def numeric_reuse(plan: SpgemmPlan, a_values: jax.Array, b_values: jax.Array) -> jax.Array:
    """The Reuse case: same structure, new values. Gather products in sorted
    order and segment-sum into C slots. No sort, no hash, no recompile."""
    prod = jnp.where(
        plan.valid, a_values[plan.a_slot] * b_values[plan.b_slot], 0
    ).astype(a_values.dtype)
    prod_sorted = prod[plan.order]
    nnz_cap = plan.indices.shape[0]
    return jnp.zeros((nnz_cap,), a_values.dtype).at[plan.seg_ids].add(
        prod_sorted, mode="drop", indices_are_sorted=True
    )


@partial(jax.jit, static_argnames=("fm_cap", "nnz_cap"))
def numeric_dense_acc(a: CSR, b: CSR, fm_cap: int, nnz_cap: int) -> CSR:
    """KKDENSE numeric: scatter all products into a dense (m, k) accumulator,
    then extract the CSR structure with a fixed-size nonzero scan. Chosen by
    the meta-algorithm when k is small (paper: k < 250k). O(m*k) memory —
    exactly the paper's dense-accumulator trade-off."""
    ex = expand_products(a, b, fm_cap)
    vals = jnp.where(ex.valid, a.values[ex.a_slot] * b.values[ex.b_slot], 0)
    dense = jnp.zeros((a.m, b.k), a.dtype)
    dense = dense.at[jnp.minimum(ex.row, a.m - 1), ex.col].add(
        jnp.where(ex.valid, vals, 0), mode="drop"
    )
    # structure mask must come from the *symbolic* structure, not value!=0
    # (cancellation must keep explicit zeros, like the paper's accumulators):
    occupied = jnp.zeros((a.m, b.k), jnp.int32)
    occupied = occupied.at[jnp.minimum(ex.row, a.m - 1), ex.col].max(
        ex.valid.astype(jnp.int32), mode="drop"
    )
    rr, cc = jnp.nonzero(occupied, size=nnz_cap, fill_value=0)
    got = jnp.arange(nnz_cap) < jnp.sum(occupied.astype(jnp.int32))
    values = jnp.where(got, dense[rr, cc], 0)
    indices = jnp.where(got, cc, 0).astype(jnp.int32)
    row_sizes = jnp.sum(occupied.astype(jnp.int32), axis=1)
    indptr = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(row_sizes).astype(jnp.int32)]
    )
    return CSR(indptr=indptr, indices=indices, values=values, shape=(a.m, b.k))


# --------------------------------------------------------------------------
# Host-level driver (the paper's Algorithm 2)
# --------------------------------------------------------------------------


class SpgemmResult(NamedTuple):
    c: CSR
    plan: SpgemmPlan | None
    stats: dict


def symbolic(a: CSR, b: CSR, compress: str = "auto"):
    """Paper Alg. 2 lines 1-3. Returns (row_sizes, stats). Host-mediated:
    decides compression by the CF<=0.85 rule and sizes the expansion."""
    stats: dict = {}
    fm, maxrf = (int(x) for x in _fm_scalars(a, b))
    stats["fm"] = fm
    stats["maxrf"] = maxrf
    use_c = False
    cf = cmrf = 1.0
    bc = None
    if compress in ("auto", "always"):
        bc = compress_matrix(b)
        cf, cmrf, use_c = compression_decision(a, b, bc)
        if compress == "always":
            use_c = True
    stats["cf"], stats["cmrf"], stats["compressed"] = cf, cmrf, use_c
    if use_c and bc is not None:
        fm_c = max(int(jnp.sum(_per_slot(a, bc.row_nnz(), bc.indptr.shape[0] - 1))), 1)
        cap = _round8(fm_c)
        sizes = symbolic_compressed(a, bc, a.m, cap)
    else:
        cap = _round8(fm)
        sizes = symbolic_plain(a, b, cap)
    return sizes, stats


def spgemm(a: CSR, b: CSR, method: str = "auto", compress: str = "auto") -> SpgemmResult:
    """Full two-phase SpGEMM with the KKSPGEMM meta-algorithm's method choice
    (see core/meta.py for the heuristics)."""
    from repro.core.meta import choose_method  # cycle-free late import

    sizes, stats = symbolic(a, b, compress=compress)
    nnz = int(jnp.sum(sizes))
    nnz_cap = max(_round8(nnz), 8)
    fm_cap = _round8(stats["fm"])
    if method == "auto":
        method = choose_method(a, b, stats)
    stats["method"] = method
    stats["nnz_c"] = nnz
    if method == "dense":
        c = numeric_dense_acc(a, b, fm_cap, nnz_cap)
        plan = None
    else:  # "sparse" — sorted-segment (flat-parallel semantics)
        c, plan = numeric_fresh(a, b, fm_cap, nnz_cap)
    return SpgemmResult(c=c, plan=plan, stats=stats)


def _round8(x: int) -> int:
    return max(-(-int(x) // 8) * 8, 8)


@jax.jit
def _fm_scalars(a: CSR, b: CSR):
    fm, _, maxrf = flops_stats(a, b.row_nnz())
    return fm, maxrf


@jax.jit
def _per_slot(a: CSR, row_nnz: jax.Array, nb: int):
    valid = a.valid_mask()
    return jnp.where(valid, row_nnz[jnp.minimum(a.indices, nb - 1)], 0)
