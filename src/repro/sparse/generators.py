"""Host-side (numpy) sparse matrix generators mirroring the paper's test suite.

The paper evaluates 83 multiplications: A*A on UF-collection matrices (power-law
graphs like RMAT/wikipedia, FEM matrices like audikw_1) and R*A*P Galerkin
triple products from multigrid. We generate structurally comparable synthetic
stand-ins: RMAT (power-law), banded/stencil (FEM-like), and aggregation-based
prolongators for triple products.
"""
from __future__ import annotations

import numpy as np

from repro.sparse.formats import CSR


def _dedupe_coo(rows, cols, vals, m, k):
    key = rows.astype(np.int64) * k + cols.astype(np.int64)
    order = np.argsort(key, kind="stable")
    key, rows, cols, vals = key[order], rows[order], cols[order], vals[order]
    keep = np.ones(len(key), bool)
    keep[1:] = key[1:] != key[:-1]
    # accumulate duplicate values into the kept slot
    seg = np.cumsum(keep) - 1
    out_vals = np.zeros(int(keep.sum()), vals.dtype)
    np.add.at(out_vals, seg, vals)
    return rows[keep], cols[keep], out_vals


def _coo_to_csr(rows, cols, vals, m, k, dtype=np.float32) -> CSR:
    rows, cols, vals = _dedupe_coo(rows, cols, vals.astype(dtype), m, k)
    indptr = np.zeros(m + 1, np.int32)
    np.add.at(indptr[1:], rows, 1)
    indptr = np.cumsum(indptr).astype(np.int32)
    return CSR.from_arrays(indptr, cols.astype(np.int32), vals, (m, k))


def random_csr(m: int, k: int, avg_nnz_per_row: float, seed: int = 0, dtype=np.float32) -> CSR:
    """Uniform random sparsity (Erdos-Renyi-like rows)."""
    rng = np.random.default_rng(seed)
    nnz = max(int(m * avg_nnz_per_row), 1)
    rows = rng.integers(0, m, nnz)
    cols = rng.integers(0, k, nnz)
    vals = rng.standard_normal(nnz)
    return _coo_to_csr(rows, cols, vals, m, k, dtype)


def rmat_csr(scale: int, edge_factor: int = 8, seed: int = 0,
             a: float = 0.57, b: float = 0.19, c: float = 0.19, dtype=np.float32) -> CSR:
    """RMAT power-law graph (the paper squares RMAT matrices; MAXRS ~ 95% of k)."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    nnz = n * edge_factor
    rows = np.zeros(nnz, np.int64)
    cols = np.zeros(nnz, np.int64)
    for bit in range(scale):
        r = rng.random(nnz)
        # quadrant probabilities a, b, c, d
        row_bit = (r >= a + b).astype(np.int64)
        col_bit = ((r >= a) & (r < a + b) | (r >= a + b + c)).astype(np.int64)
        rows |= row_bit << bit
        cols |= col_bit << bit
    vals = rng.standard_normal(nnz)
    return _coo_to_csr(rows, cols, vals, n, n, dtype)


def banded_csr(m: int, bandwidth: int, seed: int = 0, dtype=np.float32) -> CSR:
    """Banded matrix (FEM-like bounded row degree, e.g. audikw_1 family)."""
    rng = np.random.default_rng(seed)
    offsets = np.arange(-bandwidth, bandwidth + 1)
    rows = np.repeat(np.arange(m), len(offsets))
    cols = rows + np.tile(offsets, m)
    ok = (cols >= 0) & (cols < m)
    rows, cols = rows[ok], cols[ok]
    vals = rng.standard_normal(len(rows))
    return _coo_to_csr(rows, cols, vals, m, m, dtype)


def stencil2d_csr(nx: int, ny: int, dtype=np.float32) -> CSR:
    """5-point Poisson stencil on an nx*ny grid — the A_fine of multigrid."""
    n = nx * ny
    ii, jj = np.meshgrid(np.arange(nx), np.arange(ny), indexing="ij")
    idx = (ii * ny + jj).ravel()
    rows, cols, vals = [idx], [idx], [np.full(n, 4.0)]
    for di, dj in ((1, 0), (-1, 0), (0, 1), (0, -1)):
        ni, nj = ii + di, jj + dj
        ok = ((ni >= 0) & (ni < nx) & (nj >= 0) & (nj < ny)).ravel()
        rows.append(idx[ok])
        cols.append((ni * ny + nj).ravel()[ok])
        vals.append(np.full(int(ok.sum()), -1.0))
    rows = np.concatenate(rows)
    cols = np.concatenate(cols)
    vals = np.concatenate(vals)
    return _coo_to_csr(rows, cols, vals, n, n, dtype)


def aggregation_prolongator(n_fine: int, agg_size: int = 4, seed: int = 0, dtype=np.float32) -> CSR:
    """Piecewise-constant aggregation prolongator P (n_fine x n_coarse).

    Every ``agg_size`` consecutive fine points map to one coarse aggregate —
    the structure of smoothed-aggregation AMG's tentative prolongator, used to
    build the paper's R*A*P triple products.
    """
    n_coarse = (n_fine + agg_size - 1) // agg_size
    rows = np.arange(n_fine)
    cols = rows // agg_size
    vals = np.ones(n_fine)
    return _coo_to_csr(rows, cols, vals, n_fine, n_coarse, dtype)


def galerkin_triple(nx: int = 32, ny: int = 32, agg_size: int = 4, seed: int = 0):
    """Return (R, A, P) with R = P^T for a Galerkin coarse-grid product R*A*P."""
    a = stencil2d_csr(nx, ny)
    p = aggregation_prolongator(nx * ny, agg_size, seed)
    # R = P^T, host-side transpose
    pd = np.asarray(p.to_dense())
    r = CSR.from_dense(pd.T)
    return r, a, p
