"""Faithful, jittable ports of the paper's accumulator data structures (§3.1.2).

These are the semantic ground truth for the TPU kernels and the direct
implementation used by the row-level tests:

* ``LLHashmap``  — linked-list hashmap: 4 parallel arrays (Begins, Nexts, Ids,
  Values), power-of-2 ``&`` hashing, insertion at list head. The GPU version
  reserves slots with an atomic counter; here a grid step is the sole writer
  of its accumulator (Thread-Sequential semantics) so the counter is plain.
* ``LPHashmap``  — linear probing with the paper's 50% max-occupancy rule:
  beyond the cutoff, *new* keys are rejected (spill to L2) while existing
  keys still accumulate.
* two-level L1/L2 composition with L2 sized to hold all spills (CHUNKSIZE =
  MAXRF guarantee from the memory pool).

All functions are pure and sequential over the insert stream — accumulation
order is the only thing Gustavson's algorithm requires.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

MAX_OCCUPANCY = 0.5  # paper §3.1.2: LP slows down past 50% occupancy


class LLState(NamedTuple):
    begins: jax.Array  # (hash_size,) int32, -1 = empty list
    nexts: jax.Array  # (capacity,) int32, -1 = end of list
    ids: jax.Array  # (capacity,) int32
    values: jax.Array  # (capacity,) float
    used: jax.Array  # () int32


def ll_init(hash_size: int, capacity: int, dtype=jnp.float32) -> LLState:
    assert hash_size & (hash_size - 1) == 0, "hash size must be a power of 2"
    return LLState(
        begins=jnp.full((hash_size,), -1, jnp.int32),
        nexts=jnp.full((capacity,), -1, jnp.int32),
        ids=jnp.zeros((capacity,), jnp.int32),
        values=jnp.zeros((capacity,), dtype),
        used=jnp.zeros((), jnp.int32),
    )


def ll_insert(state: LLState, key: jax.Array, val: jax.Array):
    """Insert-or-accumulate one (key, val). Returns (state, accepted: bool).

    accepted=False == the paper's "FULL" return -> caller spills to L2.
    """
    mask = state.begins.shape[0] - 1
    h = key & mask

    def cond(carry):
        idx, found = carry
        return (idx != -1) & (found == -1)

    def body(carry):
        idx, _ = carry
        found = jnp.where(state.ids[idx] == key, idx, -1)
        nxt = jnp.where(found == -1, state.nexts[idx], idx)
        return nxt, found

    _, found = jax.lax.while_loop(cond, body, (state.begins[h], jnp.int32(-1)))

    def do_accumulate(s: LLState) -> LLState:
        return s._replace(values=s.values.at[found].add(val))

    def do_insert(s: LLState) -> LLState:
        slot = s.used
        return LLState(
            begins=s.begins.at[h].set(slot),
            nexts=s.nexts.at[slot].set(s.begins[h]),
            ids=s.ids.at[slot].set(key),
            values=s.values.at[slot].set(val),
            used=s.used + 1,
        )

    capacity = state.nexts.shape[0]
    full = (found == -1) & (state.used >= capacity)
    state = jax.lax.cond(
        found != -1,
        do_accumulate,
        lambda s: jax.lax.cond(full, lambda x: x, do_insert, s),
        state,
    )
    return state, ~full


class LPState(NamedTuple):
    ids: jax.Array  # (size,) int32, -1 = empty (paper Fig. 4c)
    values: jax.Array  # (size,) float
    used: jax.Array  # () int32


def lp_init(size: int, dtype=jnp.float32) -> LPState:
    assert size & (size - 1) == 0, "LP table size must be a power of 2"
    return LPState(
        ids=jnp.full((size,), -1, jnp.int32),
        values=jnp.zeros((size,), dtype),
        used=jnp.zeros((), jnp.int32),
    )


def lp_insert(state: LPState, key: jax.Array, val: jax.Array,
              max_occupancy: float = MAX_OCCUPANCY):
    """Linear-probing insert-or-accumulate with the max-occupancy cutoff.

    ``max_occupancy`` must lie in (0, 1], and the cutoff is clamped to
    ``size - 1``: at least one ``-1`` sentinel slot must survive, or a table
    filled with distinct keys would leave the probe loop no empty slot to
    stop at and it would spin forever.
    """
    if not 0.0 < max_occupancy <= 1.0:
        from repro.runtime.validate import SpgemmConfigError  # cycle-free
        raise SpgemmConfigError(
            f"max_occupancy must be in (0, 1]; got {max_occupancy!r}")
    size = state.ids.shape[0]
    mask = size - 1
    cutoff = jnp.int32(min(int(size * max_occupancy), size - 1))
    h = key & mask

    def cond(p):
        return (state.ids[p] != -1) & (state.ids[p] != key)

    def body(p):
        return (p + 1) & mask

    p = jax.lax.while_loop(cond, body, h)
    exists = state.ids[p] == key
    # New keys are rejected once occupancy exceeds the cutoff.
    accept_new = state.used < cutoff
    accepted = exists | accept_new

    def upd(s: LPState) -> LPState:
        return LPState(
            ids=s.ids.at[p].set(key),
            values=s.values.at[p].add(val),
            used=s.used + jnp.where(exists, 0, 1),
        )

    state = jax.lax.cond(accepted, upd, lambda s: s, state)
    return state, accepted


class TwoLevelResult(NamedTuple):
    l1: LPState | LLState
    l2: LLState
    l2_allocated: jax.Array  # () bool — whether any spill happened


@partial(jax.jit, static_argnames=("l1_hash", "l1_cap", "l2_cap", "kind"))
def accumulate_row(keys: jax.Array, vals: jax.Array, valid: jax.Array,
                   l1_hash: int, l1_cap: int, l2_cap: int, kind: str = "ll"):
    """Run a full insert stream through the two-level L1/L2 scheme (Alg. 3
    lines 7-10). L2 is an LL map sized to hold every spill (MAXRF bound).

    Returns (l1_state, l2_state, l2_allocated).
    """
    if kind == "ll":
        l1 = ll_init(l1_hash, l1_cap, vals.dtype)
        insert1 = ll_insert
    elif kind == "lp":
        l1 = lp_init(l1_cap, vals.dtype)
        insert1 = lp_insert
    else:
        from repro.runtime.validate import SpgemmConfigError  # cycle-free
        raise SpgemmConfigError(
            f"unknown accumulator kind {kind!r}; expected 'll' or 'lp'")
    l2_hash = max(1, l2_cap)
    l2_hash = 1 << (l2_hash - 1).bit_length()  # next pow2
    l2 = ll_init(l2_hash, l2_cap, vals.dtype)

    def step(i, carry):
        l1, l2, spilled = carry
        k, v, ok = keys[i], vals[i], valid[i]

        def live(args):
            l1, l2, spilled = args
            l1_new, accepted = insert1(l1, k, v)

            def spill(args2):
                _, l2 = args2
                l2_new, _ = ll_insert(l2, k, v)
                return l2_new

            l2_new = jax.lax.cond(
                accepted, lambda args2: args2[1], spill, (k, l2)
            )
            return l1_new, l2_new, spilled | ~accepted

        return jax.lax.cond(ok, live, lambda a: a, (l1, l2, spilled))

    l1, l2, spilled = jax.lax.fori_loop(
        0, keys.shape[0], step, (l1, l2, jnp.zeros((), jnp.bool_))
    )
    return l1, l2, spilled


def extract_sorted(ids: jax.Array, values: jax.Array, live: jax.Array):
    """Sort an accumulator's live (id, value) pairs by id (test helper).

    For LL maps pass ``live = arange(cap) < used``; for LP ``live = ids >= 0``.
    """
    key = jnp.where(live, ids, jnp.iinfo(jnp.int32).max)
    order = jnp.argsort(key)
    return key[order], values[order], live[order]
