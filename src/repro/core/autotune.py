"""Measured autotuner for the kkSpGEMM meta-algorithm.

The paper's central claim is that no single accumulator wins — but its
selection constants (``AVG_ROW_FLOPS_CUTOFF = 256``, ``DENSE_K_CUTOFF =
250_000`` in ``core/meta.py``) were calibrated for KNL/Pascal, and Nagasaka
et al. show the hash/dense crossover is architecture-dependent. This module
closes the loop two ways, in increasing order of precedence:

  static   — the paper's constants. Always available; the documented
             fallback and the default when no fit exists.
  fitted   — per-backend thresholds learned from measured crossover data:
             ``fit_thresholds`` ingests ``bench_accumulators`` rows (the
             ``BENCH_accum_<sha>.json`` CI artifact) and fits the
             dense-acc/LP-hash crossover per ``backend|platform`` key by
             minimizing total pick time over candidate cutoffs (the
             geometric midpoints between measured avg-row-flop points, plus
             0 and inf) — so on the sweep it was fitted from, the fitted
             rule is never slower in total than the static rule.
             ``set_tuned_thresholds`` activates a table;
             ``choose_kernel``/``choose_method`` consult it automatically.
  measured — opt-in first-sight micro-benchmarking (``spgemm(...,
             tune="measure")``, ``ReuseExecutor(tune="measure")``,
             ``numeric_values(..., tune="measure")``): on first sight of a
             structure-stats bucket (key = ``round_capacity``-bucketed
             ``(m, k, fm, avg_row_flops)`` + operand dtypes + backend +
             selection-table site), each eligible kernel from the selection
             table is timed on the real operands and the winner is cached —
             in the bucket table here and in the plan-cache entry — so
             replays and ``spgemm_grouped`` dispatch the measured winner
             with zero re-tuning.

Telemetry: ``TUNE_COUNTS`` counts ``micro_bench`` (a candidate sweep ran),
``bucket_hit`` (a cached bucket winner was reused) and ``plan_meta_hit`` (a
winner came back from a plan-cache entry), mirroring ``TRACE_COUNTS`` /
``HASH_COUNTS`` so tests can assert the zero-re-tuning contract.
"""
from __future__ import annotations

import json
import math
import time
from collections import Counter
from dataclasses import dataclass, field
from typing import Callable

import jax

from repro.core.meta import (
    AVG_ROW_FLOPS_CUTOFF,
    DENSE_K_CUTOFF,
    round_capacity,
)

# Opt-in empirical modes accepted by spgemm()/ReuseExecutor/numeric_values.
# None is the default: static constants, or the fitted table when one is
# active for the current backend.
TUNE_MODES = (None, "measure")

# The bench_accumulators arm names, and the arm each choose_kernel pick
# corresponds to (the fitter times picks through these columns).
ACCUM_ARMS = ("dense_acc", "segsum", "lp_hash")
ARM_OF_PICK = {"dense_acc": "dense_acc", "flat_lp": "lp_hash"}

# Micro-bench telemetry (see module docstring).
TUNE_COUNTS: Counter = Counter()

# First-sight bucket table: bucket_key -> winning kernel/backend name.
_MEASURED: dict[tuple, str] = {}

# The active fitted-thresholds table (None -> static constants).
_ACTIVE: "TunedThresholds | None" = None


def validate_tune(tune) -> None:
    if tune not in TUNE_MODES:
        from repro.runtime.validate import SpgemmConfigError  # cycle-free
        raise SpgemmConfigError(
            f"unknown tune mode {tune!r}; expected one of {TUNE_MODES} "
            f"(None = static/fitted thresholds, 'measure' = first-sight "
            f"micro-bench)")


def reset_tune_counts() -> None:
    TUNE_COUNTS.clear()


def reset_tuner() -> None:
    """Full tuner reset: counters, measured-winner buckets, fitted table.

    Test-isolation helper (conftest runs it per test): the registry and the
    bucket table are process-global, so a fitted table or measured winner
    must never leak across tests.
    """
    global _ACTIVE
    TUNE_COUNTS.clear()
    _MEASURED.clear()
    _ACTIVE = None


# --------------------------------------------------------------------------
# Fitted thresholds
# --------------------------------------------------------------------------


def backend_key() -> str:
    """The per-backend key fitted thresholds are stored under.

    ``backend|device_kind`` (e.g. ``cpu|cpu``, ``tpu|TPU v4``): the XLA
    backend name alone does not distinguish TPU generations, whose
    crossovers differ — exactly what static cutoffs can't capture.
    """
    dev = jax.devices()[0]
    return f"{jax.default_backend()}|{getattr(dev, 'device_kind', 'unknown')}"


@dataclass(frozen=True)
class BackendFit:
    """One backend's fitted crossover points.

    avg_row_flops_cutoff: fitted dense_acc/flat_lp crossover. May be 0.0
        (LP-hash always wins on this backend) or inf (dense-acc always wins
        — e.g. CPU CI, where the LP kernel pays interpret overhead).
    dense_k_cutoff: fitted KKDENSE k cutoff, or None to keep the paper's
        static constant (the accumulator sweep does not vary k today).
    points: the ``(avg_row_flops, winner)`` evidence the fit was made from.
    """

    avg_row_flops_cutoff: float
    dense_k_cutoff: int | None = None
    n_points: int = 0
    points: tuple = field(default_factory=tuple)


class TunedThresholds:
    """Per-backend fitted threshold table consulted by ``core.meta``.

    ``fits`` maps ``backend_key()`` strings to ``BackendFit``. A backend
    with no row falls back to the static paper constants — the fitted table
    only ever *narrows* behavior where there is measured evidence.
    """

    SCHEMA = 1

    def __init__(self, fits: dict[str, BackendFit] | None = None, *,
                 jax_version: str | None = None,
                 source: str | None = None):
        self.fits: dict[str, BackendFit] = dict(fits or {})
        self.jax_version = jax_version
        self.source = source

    def for_backend(self, key: str | None = None) -> BackendFit | None:
        """The fit for ``key`` (default: the current backend), or None.

        Falls back to a backend-name-only match (``cpu|*``) when exactly one
        fitted row shares the backend half of the key — older artifacts
        lack the device-kind stamp.
        """
        key = backend_key() if key is None else key
        fit = self.fits.get(key)
        if fit is not None:
            return fit
        base = key.split("|", 1)[0]
        matches = [f for k, f in self.fits.items()
                   if k.split("|", 1)[0] == base]
        return matches[0] if len(matches) == 1 else None

    def to_json(self) -> dict:
        return {
            "schema": self.SCHEMA,
            "kind": "tuned_thresholds",
            "jax_version": self.jax_version,
            "source": self.source,
            "fits": {
                k: {
                    # inf serialized as a string: portable JSON, exact
                    # round-trip (json's bare Infinity is non-standard)
                    "avg_row_flops_cutoff": (
                        f.avg_row_flops_cutoff
                        if math.isfinite(f.avg_row_flops_cutoff)
                        else "inf"),
                    "dense_k_cutoff": f.dense_k_cutoff,
                    "n_points": f.n_points,
                    "points": [list(p) for p in f.points],
                }
                for k, f in self.fits.items()
            },
        }

    @classmethod
    def from_json(cls, payload: dict) -> "TunedThresholds":
        if payload.get("kind") != "tuned_thresholds":
            from repro.runtime.validate import SpgemmConfigError  # cycle-free
            raise SpgemmConfigError(
                "not a tuned_thresholds payload (kind="
                f"{payload.get('kind')!r}) — pass the JSON written by "
                "TunedThresholds.save / benchmarks.run --fit-thresholds")
        fits = {}
        for k, f in payload.get("fits", {}).items():
            cutoff = f["avg_row_flops_cutoff"]
            fits[k] = BackendFit(
                avg_row_flops_cutoff=(
                    math.inf if cutoff == "inf" else float(cutoff)),
                dense_k_cutoff=(None if f.get("dense_k_cutoff") is None
                                else int(f["dense_k_cutoff"])),
                n_points=int(f.get("n_points", 0)),
                points=tuple(tuple(p) for p in f.get("points", ())),
            )
        return cls(fits, jax_version=payload.get("jax_version"),
                   source=payload.get("source"))

    def save(self, path: str) -> None:
        with open(path, "w") as fh:
            json.dump(self.to_json(), fh, indent=2)
            fh.write("\n")

    @classmethod
    def load(cls, path: str) -> "TunedThresholds":
        with open(path) as fh:
            return cls.from_json(json.load(fh))


def set_tuned_thresholds(table: TunedThresholds | None) -> TunedThresholds | None:
    """Activate a fitted table (None deactivates). Returns the previous one."""
    global _ACTIVE
    if table is not None and not isinstance(table, TunedThresholds):
        raise TypeError(
            f"expected TunedThresholds or None, got {type(table).__name__}")
    prev, _ACTIVE = _ACTIVE, table
    return prev


def get_tuned_thresholds() -> TunedThresholds | None:
    return _ACTIVE


def load_thresholds(path: str, *, activate: bool = True) -> TunedThresholds:
    """Load a saved fitted table, activating it by default."""
    table = TunedThresholds.load(path)
    if activate:
        set_tuned_thresholds(table)
    return table


def avg_row_flops_cutoff() -> tuple[float, str]:
    """The effective dense_acc/flat_lp cutoff: (value, source).

    source is "fitted" when the active table has a row for the current
    backend, "static" (the paper's 256) otherwise.
    """
    if _ACTIVE is not None:
        fit = _ACTIVE.for_backend()
        if fit is not None:
            return float(fit.avg_row_flops_cutoff), "fitted"
    return float(AVG_ROW_FLOPS_CUTOFF), "static"


def dense_k_cutoff() -> tuple[int, str]:
    """The effective KKDENSE k cutoff: (value, source)."""
    if _ACTIVE is not None:
        fit = _ACTIVE.for_backend()
        if fit is not None and fit.dense_k_cutoff is not None:
            return int(fit.dense_k_cutoff), "fitted"
    return DENSE_K_CUTOFF, "static"


def _fit_cutoff(points: list[tuple[float, float, float]],
                static_cutoff: float) -> float:
    """Threshold fit over ``(avg_row_flops, t_dense_acc, t_lp)`` points.

    Candidate cutoffs — 0, the geometric midpoints between consecutive
    points, inf — cover every pick-pattern a single threshold can realize
    on these points, so minimizing total pick time guarantees the fitted
    rule is never slower in total than the static one on this sweep (the
    static cutoff lies in one of the candidate regions). Ties break toward
    the candidate closest to the static cutoff in log space: no evidence,
    no movement.
    """
    pts = sorted(points)
    arfs = [p[0] for p in pts]
    cands = [0.0]
    for lo, hi in zip(arfs, arfs[1:]):
        if hi > lo:
            cands.append(math.sqrt(lo * hi))
    cands.append(math.inf)

    def total(c: float) -> float:
        return sum(td if arf < c else tl for arf, td, tl in pts)

    def log_dist(c: float) -> float:
        c = min(max(c, 1e-12), 1e12)
        return abs(math.log(c) - math.log(static_cutoff))

    return min(cands, key=lambda c: (total(c), log_dist(c)))


def fit_thresholds(payload_or_rows, *,
                   static_cutoff: float = float(AVG_ROW_FLOPS_CUTOFF),
                   source: str | None = None) -> TunedThresholds:
    """Fit per-backend thresholds from ``bench_accumulators`` rows.

    Accepts either a full ``--json`` benchmark payload (``{"rows": [...]}``)
    or a bare row list. Rows named ``accumulators/<regime>/<arm>`` with
    ``derived.avg_row_flops`` feed the fit; each row's ``backend``/
    ``platform`` stamps key the fit per backend (rows without stamps fall
    back to the payload's top-level backend). Regimes missing either the
    ``dense_acc`` or ``lp_hash`` arm are skipped — the fit compares the two
    arms ``choose_kernel`` actually picks between.
    """
    if isinstance(payload_or_rows, dict):
        rows = payload_or_rows.get("rows", [])
        default_bkey = (f"{payload_or_rows.get('backend', 'unknown')}|"
                        f"{payload_or_rows.get('platform', 'unknown')}")
        jax_version = payload_or_rows.get("jax_version")
    else:
        rows = list(payload_or_rows)
        default_bkey = "unknown|unknown"
        jax_version = None

    grouped: dict[str, dict[str, dict]] = {}
    for row in rows:
        parts = str(row.get("name", "")).split("/")
        if len(parts) != 3 or parts[0] != "accumulators":
            continue
        _, regime, arm = parts
        if arm not in ACCUM_ARMS:
            continue
        derived = row.get("derived", {})
        if "avg_row_flops" not in derived:
            continue
        if row.get("backend") is not None:
            bkey = f"{row['backend']}|{row.get('platform', 'unknown')}"
        else:
            bkey = default_bkey
        entry = grouped.setdefault(bkey, {}).setdefault(regime, {})
        entry["arf"] = float(derived["avg_row_flops"])
        entry[arm] = float(row["us_per_call"])

    fits: dict[str, BackendFit] = {}
    for bkey, regimes in grouped.items():
        points = sorted(
            (e["arf"], e["dense_acc"], e["lp_hash"])
            for e in regimes.values()
            if "dense_acc" in e and "lp_hash" in e
        )
        if not points:
            continue
        cutoff = _fit_cutoff(points, static_cutoff)
        fits[bkey] = BackendFit(
            avg_row_flops_cutoff=cutoff,
            dense_k_cutoff=None,
            n_points=len(points),
            points=tuple(
                (arf, "dense_acc" if td <= tl else "flat_lp")
                for arf, td, tl in points
            ),
        )
    return TunedThresholds(fits, jax_version=jax_version, source=source)


# --------------------------------------------------------------------------
# First-sight micro-bench ("measure" mode)
# --------------------------------------------------------------------------


def bucket_key(m: int, k: int, fm: int, a_dtype, b_dtype,
               table: str) -> tuple:
    """The structure-stats bucket a measured winner is cached under.

    ``round_capacity``-bucketed (m, k, fm, avg_row_flops) + operand dtypes
    + backend + ``table`` (the selection-table site the winner applies to:
    "replay" for plan-replay backends, "numeric" for the ELL numeric-phase
    kernels — the two sites have different candidate sets, so their winners
    must not collide). ``fm`` is bucketed with the same pow2 rule as
    ``fm_cap``, so callers holding either the true ``fm`` or the bucketed
    cap land in the same bucket; avg row flops derives from the bucketed
    fm for the same reason.
    """
    fm_b = round_capacity(max(int(fm), 1))
    arf_b = round_capacity(max(fm_b // max(int(m), 1), 1))
    return (table, backend_key(), round_capacity(max(int(m), 1)),
            round_capacity(max(int(k), 1)), fm_b, arf_b,
            str(a_dtype), str(b_dtype))


def lookup_measured(key: tuple) -> str | None:
    """Cached bucket winner, or None (bumps ``bucket_hit`` on a hit)."""
    winner = _MEASURED.get(key)
    if winner is not None:
        TUNE_COUNTS["bucket_hit"] += 1
    return winner


def record_measured(key: tuple, winner: str) -> None:
    _MEASURED[key] = winner


def measured_table_size() -> int:
    return len(_MEASURED)


def measure_candidates(candidates: dict[str, Callable[[], object]], *,
                       reps: int = 3) -> tuple[str, dict[str, float]]:
    """Time each candidate thunk and return (winner, times_us).

    Protocol mirrors the benchmark harness: one excluded warmup (which also
    pays any compile) + median of ``reps`` timed runs, ``block_until_ready``
    on every output so dispatch-only returns don't win by cheating. Bumps
    ``TUNE_COUNTS["micro_bench"]`` once per sweep.
    """
    if not candidates:
        from repro.runtime.validate import SpgemmConfigError  # cycle-free
        raise SpgemmConfigError(
            "measure_candidates needs at least one candidate")
    TUNE_COUNTS["micro_bench"] += 1
    times: dict[str, float] = {}
    for name, fn in candidates.items():
        jax.block_until_ready(fn())
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            ts.append(time.perf_counter() - t0)
        ts.sort()
        times[name] = ts[len(ts) // 2] * 1e6
    winner = min(times, key=times.get)
    return winner, times


def measure_and_record(key: tuple,
                       candidates: dict[str, Callable[[], object]], *,
                       reps: int = 3) -> tuple[str, dict[str, float]]:
    """``measure_candidates`` + cache the winner under ``key``."""
    winner, times = measure_candidates(candidates, reps=reps)
    record_measured(key, winner)
    return winner, times
