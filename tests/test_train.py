"""Training substrate: loss decreases, microbatch equivalence, optimizer
semantics, checkpoint save/restore/resume, data determinism + skip-ahead."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.ckpt import latest_step, restore, save
from repro.configs import get_config
from repro.data import SyntheticLMDataset
from repro.models import NO_SHARDING, init_params
from repro.train import AdamWConfig, adamw_init, make_train_step


def _setup(seed=0):
    cfg = get_config("llama3.2-1b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(seed))
    opt = adamw_init(params)
    data = SyntheticLMDataset(vocab_size=cfg.vocab_size, seq_len=32,
                              global_batch=4)
    return cfg, params, opt, data


def test_loss_decreases():
    cfg, params, opt, data = _setup()
    step = jax.jit(make_train_step(cfg, NO_SHARDING, AdamWConfig(lr=3e-3,
                                                                 warmup_steps=5)))
    first = last = None
    for s in range(30):
        batch = {k: jnp.asarray(v) for k, v in data.get_batch(s % 2).items()}
        params, opt, m = step(params, opt, batch)
        if first is None:
            first = float(m["loss"])
        last = float(m["loss"])
    assert np.isfinite(last)
    assert last < first - 0.5, (first, last)


def test_microbatch_equivalence():
    """num_microbatches=2 must give (near-)identical grads/update to 1."""
    cfg, params, opt, data = _setup()
    batch = {k: jnp.asarray(v) for k, v in data.get_batch(0).items()}
    p1, _, m1 = make_train_step(cfg, NO_SHARDING, AdamWConfig())(params, opt, batch)
    p2, _, m2 = make_train_step(cfg, NO_SHARDING, AdamWConfig(),
                                num_microbatches=2)(params, opt, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-2)
    l1 = jax.tree.leaves(p1)[0]
    l2 = jax.tree.leaves(p2)[0]
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-2,
                               atol=1e-4)


def test_grad_clip_fires():
    from repro.train.optim import adamw_update, global_norm

    cfg, params, opt, _ = _setup()
    big = jax.tree.map(lambda p: jnp.full(p.shape, 100.0, jnp.float32), params)
    _, _, m = adamw_update(big, opt, params, AdamWConfig(grad_clip=1.0))
    assert float(m["grad_norm"]) > 1.0  # raw norm reported, update clipped


def test_checkpoint_roundtrip(tmp_path):
    cfg, params, opt, data = _setup()
    d = str(tmp_path)
    save(d, 7, (params, opt), extra={"arch": "llama"})
    assert latest_step(d) == 7
    (p2, o2), manifest = restore(d, 7, (params, opt))
    assert manifest["extra"]["arch"] == "llama"
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_resume_exact(tmp_path):
    """Train 4 steps straight vs 2 steps + save/restore + 2 steps: identical
    final params (fault-tolerant restart is bit-exact)."""
    d = str(tmp_path)
    step_cfg = AdamWConfig(lr=1e-3)
    cfg, params, opt, data = _setup()
    step = jax.jit(make_train_step(cfg, NO_SHARDING, step_cfg))

    pa, oa = params, opt
    for s in range(4):
        batch = {k: jnp.asarray(v) for k, v in data.get_batch(s).items()}
        pa, oa, _ = step(pa, oa, batch)

    pb, ob = params, opt
    for s in range(2):
        batch = {k: jnp.asarray(v) for k, v in data.get_batch(s).items()}
        pb, ob, _ = step(pb, ob, batch)
    save(d, 2, (pb, ob))
    (pb, ob), _ = restore(d, 2, (pb, ob))
    for s in range(2, 4):  # data skip-ahead: same batches as the straight run
        batch = {k: jnp.asarray(v) for k, v in data.get_batch(s).items()}
        pb, ob, _ = step(pb, ob, batch)

    for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_data_determinism_and_sharding():
    d1 = SyntheticLMDataset(vocab_size=100, seq_len=16, global_batch=8)
    d2 = SyntheticLMDataset(vocab_size=100, seq_len=16, global_batch=8)
    np.testing.assert_array_equal(d1.get_batch(5)["tokens"],
                                  d2.get_batch(5)["tokens"])
    # process sharding partitions the global batch
    parts = [
        SyntheticLMDataset(vocab_size=100, seq_len=16, global_batch=8,
                           process_index=i, num_processes=2).get_batch(3)
        for i in range(2)
    ]
    full = d1.get_batch(3)
    np.testing.assert_array_equal(
        np.concatenate([p["tokens"] for p in parts]), full["tokens"]
    )


def test_atomic_checkpoint_overwrite(tmp_path):
    cfg, params, opt, _ = _setup()
    d = str(tmp_path)
    save(d, 1, params)
    save(d, 1, params)  # overwrite same step: must not corrupt
    restored, _ = restore(d, 1, params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_watchdog():
    import time

    from repro.runtime import StepWatchdog
    from repro.runtime.watchdog import StragglerDetected

    wd = StepWatchdog(deadline_s=0.01, policy="warn")
    with wd.step(0):
        time.sleep(0.02)
    assert wd.slow_steps and wd.slow_steps[0][0] == 0
    wd2 = StepWatchdog(deadline_s=0.01, policy="raise")
    try:
        with wd2.step(1):
            time.sleep(0.02)
        raise AssertionError("should have raised")
    except StragglerDetected:
        pass


def test_watchdog_records_raising_step():
    # regression: the yield used to be unwrapped, so a step body that raised
    # was never timed or recorded — slow failing steps vanished from telemetry
    import time

    from repro.runtime import StepWatchdog

    wd = StepWatchdog(deadline_s=0.01, policy="warn")
    with pytest.raises(RuntimeError, match="body failed"):
        with wd.step(3):
            time.sleep(0.02)
            raise RuntimeError("body failed")
    assert wd.slow_steps and wd.slow_steps[0][0] == 3


def test_watchdog_raise_policy_does_not_mask_body_exception():
    # a slow step whose body ALSO raised must propagate the body's error,
    # not replace it with StragglerDetected (the slow step is still recorded)
    import time

    from repro.runtime import StepWatchdog

    wd = StepWatchdog(deadline_s=0.01, policy="raise")
    with pytest.raises(RuntimeError, match="body failed"):
        with wd.step(4):
            time.sleep(0.02)
            raise RuntimeError("body failed")
    assert wd.slow_steps and wd.slow_steps[0][0] == 4


def test_watchdog_uses_monotonic_clock(monkeypatch):
    # wall-clock jumps (NTP slew) must not fire the deadline: freeze
    # time.time far in the future and verify the watchdog ignores it
    import time as _time

    from repro.runtime import StepWatchdog

    wd = StepWatchdog(deadline_s=10.0, policy="raise")
    monkeypatch.setattr(_time, "time", lambda: _time.monotonic() + 10_000.0)
    with wd.step(0):
        pass
    assert wd.slow_steps == []


def test_heartbeat_survives_write_errors(tmp_path):
    # regression: an OSError on the liveness write used to kill the daemon
    # thread silently — the beat must continue and the error be counted
    import os
    import time

    from repro.runtime import Heartbeat

    target_dir = tmp_path / "gone"
    target_dir.mkdir()
    hb = Heartbeat(str(target_dir / "live.json"), interval_s=0.01)
    hb.start()
    try:
        time.sleep(0.05)
        assert os.path.exists(hb.path)
        os.remove(hb.path)
        target_dir.rmdir()  # unlink the dir: every write now OSErrors
        time.sleep(0.05)
        assert hb._thread.is_alive()  # daemon kept beating through failures
    finally:
        errors = hb.stop()
    assert errors >= 1 and hb.write_errors == errors
