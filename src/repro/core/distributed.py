"""Partitioning / halo layer for distributed SpGEMM (1-D row decomposition).

This module is the *partitioning substrate* under the ``repro.dist``
subsystem: it owns the host-side row decomposition (``partition_rows`` /
``merge_shards``), the jittable shard-concat used after all-gathering B
(``concat_csr_shards``), the value-slot maps that let a pinned sharded plan
re-shard *values* without touching structure (``partition_value_map`` /
``allgather_value_perm``), and the from-scratch reference driver
``distributed_spgemm``. The plan-lifecycle layer — ``ShardedPlan``,
``ShardedReuseExecutor``, the mesh-aware plan cache — lives in
``repro.dist`` and composes these primitives; use it whenever the structure
is reused across numeric calls.

C's rows are partitioned over the ``data`` mesh axis (the paper's
first-level "team" partitioning lifted to devices). Two B placements:

* ``replicated`` — B lives on every shard (the common 1-D choice; the paper
  notes each row of B is read ~delta_A times, so replication trades memory
  for zero communication);
* ``allgather``  — B is row-sharded and all-gathered per step (halves
  at-rest memory, pays one all-gather; the collective shows up in the
  roofline term of the dry-run). Under ``repro.dist`` the *structure*
  all-gather is hoisted to pin time — replays only gather values.

The two-phase contract extends naturally: distributed symbolic returns the
sharded row sizes, the host syncs the max caps (one tiny host round-trip —
the same role as the paper's host-side allocation between phases), and the
distributed numeric runs with uniform static shapes on every shard. Every
static cap is bucketed through ``core.meta.round_capacity`` so shards share
capacity buckets — and therefore compiled executables — with the
single-device path.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core.meta import DEFAULT_PAD_POLICY, round_capacity
from repro.core.spgemm import numeric_fresh, symbolic_plain
from repro.sparse.formats import CSR


class ShardedCSR(NamedTuple):
    """Row-partitioned CSR with a leading shard axis on every array."""

    indptr: jax.Array  # (S, m_loc+1)
    indices: jax.Array  # (S, cap)
    values: jax.Array  # (S, cap)
    shape: tuple  # global (m, k)

    @property
    def num_shards(self) -> int:
        return self.indptr.shape[0]

    @property
    def m_loc(self) -> int:
        return self.indptr.shape[1] - 1


def row_block_bounds(a: CSR, num_shards: int) -> np.ndarray:
    """Host-side: (S+1,) nnz offsets of the contiguous row blocks of ``a``.

    Shard ``s`` owns rows ``[s*ceil(m/S), min((s+1)*ceil(m/S), m))`` and its
    values/indices live in the global buffers at ``[bounds[s], bounds[s+1])``.
    The same bounds drive ``partition_rows`` and ``partition_value_map``, so
    structure and value sharding can never disagree.
    """
    indptr = np.asarray(a.indptr)
    m = a.m
    m_loc = -(-m // num_shards)
    return np.asarray(
        [indptr[min(s * m_loc, m)] for s in range(num_shards + 1)], np.int64
    )


def shard_cap(a: CSR, num_shards: int, pad_policy: str | None = None) -> int:
    """Uniform per-shard nnz capacity, bucketed via ``round_capacity`` so
    shards share capacity buckets with the single-device path."""
    policy = DEFAULT_PAD_POLICY if pad_policy is None else pad_policy
    bounds = row_block_bounds(a, num_shards)
    return round_capacity(int(np.max(np.diff(bounds))), policy)


def partition_rows(a: CSR, num_shards: int,
                   pad_policy: str | None = None) -> ShardedCSR:
    """Host-side: split A into ``num_shards`` row blocks with uniform caps."""
    indptr = np.asarray(a.indptr)
    indices = np.asarray(a.indices)
    values = np.asarray(a.values)
    m = a.m
    m_loc = -(-m // num_shards)
    bounds = row_block_bounds(a, num_shards)
    cap = shard_cap(a, num_shards, pad_policy)
    ip = np.zeros((num_shards, m_loc + 1), np.int32)
    ix = np.zeros((num_shards, cap), np.int32)
    vl = np.zeros((num_shards, cap), values.dtype)
    for s in range(num_shards):
        # clamp both ends: when S > m whole shards fall past the last row
        # (rows == 0) and must come out empty, not negatively sliced
        r0, r1 = min(s * m_loc, m), min((s + 1) * m_loc, m)
        lo, hi = bounds[s], bounds[s + 1]
        ip[s, : r1 - r0 + 1] = indptr[r0 : r1 + 1] - lo
        ip[s, r1 - r0 + 1 :] = indptr[r1] - lo  # empty padded rows
        ix[s, : hi - lo] = indices[lo:hi]
        vl[s, : hi - lo] = values[lo:hi]
    return ShardedCSR(
        indptr=jnp.asarray(ip), indices=jnp.asarray(ix), values=jnp.asarray(vl),
        shape=a.shape,
    )


def merge_shards(c_sh: ShardedCSR, m: int) -> CSR:
    """Host-side inverse of partition_rows (drops row padding)."""
    S, m_loc1 = c_sh.indptr.shape
    m_loc = m_loc1 - 1
    ip = np.asarray(c_sh.indptr)
    ix = np.asarray(c_sh.indices)
    vl = np.asarray(c_sh.values)
    out_ip = [0]
    out_ix, out_vl = [], []
    for s in range(S):
        rows = min(m_loc, m - s * m_loc)
        if rows <= 0:
            break
        nnz = ip[s, rows]
        out_ix.append(ix[s, :nnz])
        out_vl.append(vl[s, :nnz])
        base = out_ip[-1]
        out_ip.extend((ip[s, 1 : rows + 1] + base).tolist())
    indices = np.concatenate(out_ix) if out_ix else np.zeros(0, np.int32)
    values = np.concatenate(out_vl) if out_vl else np.zeros(0, np.float32)
    return CSR.from_arrays(np.asarray(out_ip, np.int32), indices, values, (m, c_sh.shape[1]))


def partition_value_map(a: CSR, num_shards: int,
                        pad_policy: str | None = None) -> np.ndarray:
    """(S, cap) int32: global value slot feeding each shard value slot.

    ``values[perm]`` re-shards a *values* array exactly the way
    ``partition_rows`` sharded the structure — the device-side fast path a
    pinned sharded plan uses to ingest fresh operand values without
    re-partitioning structure. Padding slots point at clamped live slots;
    their products carry the sentinel ``seg_id`` and are dropped.
    """
    bounds = row_block_bounds(a, num_shards)
    cap = shard_cap(a, num_shards, pad_policy)
    base = bounds[:-1, None] + np.arange(cap, dtype=np.int64)[None, :]
    return np.minimum(base, max(a.nnz_cap - 1, 0)).astype(np.int32)


def allgather_value_perm(b_sh: ShardedCSR) -> np.ndarray:
    """(S*cap,) int32: flattened all-gather slot per global concat slot.

    ``all_gather(values).reshape(-1)[perm]`` reproduces the value layout of
    ``concat_csr_shards`` without re-concatenating structure — B's structure
    all-gather is paid once at plan-pin time, replays only move values.
    """
    S, cap = b_sh.indices.shape
    nnz_s = np.asarray(b_sh.indptr)[:, -1].astype(np.int64)
    offs = np.concatenate([[0], np.cumsum(nnz_s)[:-1]])
    perm = np.zeros(S * cap, np.int32)
    for s in range(S):
        n = int(nnz_s[s])
        perm[offs[s]: offs[s] + n] = s * cap + np.arange(n, dtype=np.int64)
    return perm


@partial(jax.jit, static_argnames=("k",))
def concat_csr_shards(indptrs, indices, values, k: int) -> CSR:
    """Jittable: rebuild a single global CSR from gathered row shards
    (used inside shard_map after all-gathering B)."""
    S, m_loc1 = indptrs.shape
    cap = indices.shape[1]
    nnzs = indptrs[:, -1]  # (S,)
    offs = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(nnzs)[:-1].astype(jnp.int32)])
    dest = offs[:, None] + jnp.arange(cap, dtype=jnp.int32)[None, :]
    valid = jnp.arange(cap, dtype=jnp.int32)[None, :] < nnzs[:, None]
    dest = jnp.where(valid, dest, S * cap)  # OOB -> dropped
    g_ix = jnp.zeros((S * cap,), jnp.int32).at[dest.reshape(-1)].set(
        indices.reshape(-1), mode="drop"
    )
    g_vl = jnp.zeros((S * cap,), values.dtype).at[dest.reshape(-1)].set(
        values.reshape(-1), mode="drop"
    )
    g_ip = (offs[:, None] + indptrs[:, :-1]).reshape(-1)
    total = offs[-1] + nnzs[-1]
    g_ip = jnp.concatenate([g_ip, total[None].astype(jnp.int32)])
    m = S * (m_loc1 - 1)
    return CSR(indptr=g_ip, indices=g_ix, values=g_vl, shape=(m, k))


def _local_csr(indptr, indices, values, shape) -> CSR:
    return CSR(indptr=indptr, indices=indices, values=values, shape=shape)


def dist_symbolic(a_sh: ShardedCSR, b: CSR | ShardedCSR, mesh, axis: str, fm_cap: int):
    """shard_map'ed symbolic phase -> (S, m_loc) row sizes of C."""
    m_loc = a_sh.m_loc
    k = b.shape[1]
    replicated = isinstance(b, CSR)

    if replicated:

        def fn(ip, ix, vl, b_ip, b_ix, b_vl):
            a_loc = _local_csr(ip[0], ix[0], vl[0], (m_loc, a_sh.shape[1]))
            b_loc = _local_csr(b_ip, b_ix, b_vl, b.shape)
            return symbolic_plain(a_loc, b_loc, fm_cap)[None]

        return shard_map(
            fn,
            mesh=mesh,
            in_specs=(P(axis), P(axis), P(axis), P(), P(), P()),
            out_specs=P(axis),
        )(a_sh.indptr, a_sh.indices, a_sh.values, b.indptr, b.indices, b.values)

    def fn(ip, ix, vl, b_ip, b_ix, b_vl):
        b_ips = jax.lax.all_gather(b_ip[0], axis)
        b_ixs = jax.lax.all_gather(b_ix[0], axis)
        b_vls = jax.lax.all_gather(b_vl[0], axis)
        b_glob = concat_csr_shards(b_ips, b_ixs, b_vls, k)
        a_loc = _local_csr(ip[0], ix[0], vl[0], (m_loc, a_sh.shape[1]))
        return symbolic_plain(a_loc, b_glob, fm_cap)[None]

    return shard_map(
        fn,
        mesh=mesh,
        in_specs=(P(axis),) * 6,
        out_specs=P(axis),
    )(a_sh.indptr, a_sh.indices, a_sh.values, b.indptr, b.indices, b.values)


def dist_numeric(a_sh: ShardedCSR, b: CSR | ShardedCSR, mesh, axis: str,
                 fm_cap: int, nnz_cap: int) -> ShardedCSR:
    """shard_map'ed numeric phase with uniform static caps on every shard."""
    m_loc = a_sh.m_loc
    k = b.shape[1]
    replicated = isinstance(b, CSR)

    def numeric_local(a_loc: CSR, b_loc: CSR):
        c, _ = numeric_fresh(a_loc, b_loc, fm_cap, nnz_cap)
        return c.indptr[None], c.indices[None], c.values[None]

    if replicated:

        def fn(ip, ix, vl, b_ip, b_ix, b_vl):
            a_loc = _local_csr(ip[0], ix[0], vl[0], (m_loc, a_sh.shape[1]))
            b_loc = _local_csr(b_ip, b_ix, b_vl, b.shape)
            return numeric_local(a_loc, b_loc)

        specs_in = (P(axis), P(axis), P(axis), P(), P(), P())
    else:

        def fn(ip, ix, vl, b_ip, b_ix, b_vl):
            b_ips = jax.lax.all_gather(b_ip[0], axis)
            b_ixs = jax.lax.all_gather(b_ix[0], axis)
            b_vls = jax.lax.all_gather(b_vl[0], axis)
            b_glob = concat_csr_shards(b_ips, b_ixs, b_vls, k)
            a_loc = _local_csr(ip[0], ix[0], vl[0], (m_loc, a_sh.shape[1]))
            return numeric_local(a_loc, b_glob)

        specs_in = (P(axis),) * 6

    out = shard_map(
        fn, mesh=mesh, in_specs=specs_in, out_specs=(P(axis), P(axis), P(axis))
    )(a_sh.indptr, a_sh.indices, a_sh.values, b.indptr, b.indices, b.values)
    return ShardedCSR(indptr=out[0], indices=out[1], values=out[2],
                      shape=(a_sh.shape[0], k))


def shard_fm_cap(a_sh: ShardedCSR, b: CSR,
                 pad_policy: str | None = None) -> int:
    """Host-side uniform per-shard f_m capacity (max over shards, bucketed)."""
    policy = DEFAULT_PAD_POLICY if pad_policy is None else pad_policy
    b_rn = np.diff(np.asarray(b.indptr))
    a_ix = np.asarray(a_sh.indices)
    a_ip = np.asarray(a_sh.indptr)
    fm_cap = 1
    for s in range(a_sh.num_shards):
        nnz_s = a_ip[s, -1]
        fm_s = int(b_rn[a_ix[s, :nnz_s]].sum()) if nnz_s else 0
        fm_cap = max(fm_cap, fm_s)
    return round_capacity(fm_cap, policy)


def distributed_spgemm(a: CSR, b: CSR, mesh, axis: str = "data",
                       b_placement: str = "replicated",
                       pad_policy: str | None = None) -> CSR:
    """Host driver: partition -> symbolic -> sync caps -> numeric -> merge.

    The from-scratch reference path: every call re-runs both phases. When
    the structure repeats across calls, pin it once with
    ``repro.dist.ShardedReuseExecutor`` (or ``spgemm(..., mesh=...)``, which
    caches sharded plans) and replay only the numeric phase.
    """
    policy = DEFAULT_PAD_POLICY if pad_policy is None else pad_policy
    num = mesh.shape[axis]
    a_sh = partition_rows(a, num, policy)
    if b_placement == "replicated":
        b_in: CSR | ShardedCSR = b
    elif b_placement == "allgather":
        b_in = partition_rows(b, num, policy)
    else:
        from repro.runtime.validate import SpgemmConfigError  # cycle-free
        raise SpgemmConfigError(
            f"unknown b_placement {b_placement!r}; expected 'replicated' "
            f"or 'allgather'")

    fm_cap = shard_fm_cap(a_sh, b, policy)
    sizes = dist_symbolic(a_sh, b_in, mesh, axis, fm_cap)  # (S, m_loc)
    nnz_cap = round_capacity(int(jnp.max(jnp.sum(sizes, axis=1))), policy)
    c_sh = dist_numeric(a_sh, b_in, mesh, axis, fm_cap, nnz_cap)
    return merge_shards(c_sh, a.m)
