"""Autotuner tests: selection boundaries, threshold fitting, measure mode.

Covers the static/fitted/measured precedence end to end:
  * the exact tie directions of the static rules (choose_kernel at
    avg_row_flops == 256, choose_method at dense_bytes == budget) and the
    round_capacity bucket edges the tuner keys on,
  * fit_thresholds on synthetic sweep rows (+ save/load round-trip, backend
    fallback when no fitted row covers the current backend),
  * tune="measure" through every entry point — spgemm, ReuseExecutor,
    spgemm_grouped, numeric_values — with the zero-re-tuning contract
    asserted through TUNE_COUNTS/TRACE_COUNTS telemetry.
"""
import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AVG_ROW_FLOPS_CUTOFF,
    BackendFit,
    PlanCache,
    ReuseExecutor,
    TUNE_COUNTS,
    TunedThresholds,
    choose_kernel,
    choose_method,
    fit_thresholds,
    round_capacity,
    set_tuned_thresholds,
    spgemm,
    spgemm_grouped,
)
from repro.core import autotune, telemetry
from repro.core.meta import CAPACITY_FLOOR, DENSE_BYTES_BUDGET
from repro.core.plan_cache import HASH_COUNTS
from repro.core.spgemm import TRACE_COUNTS
from repro.kernels.ops import (
    KERNEL_COUNTS,
    numeric_values,
    resolve_numeric_kernel,
)
from repro.sparse import (
    CSR,
    dense_spgemm_oracle,
    gustavson_ell_structure,
    random_csr,
)


# ---------------------------------------------------------------- boundaries


def test_round_capacity_floor_and_pow2_edges():
    # CAPACITY_FLOOR clamps tiny sizes under both policies
    assert round_capacity(1, "pow2") == CAPACITY_FLOOR
    assert round_capacity(1, "exact8") == CAPACITY_FLOOR
    assert CAPACITY_FLOOR == 8
    # pow2: exact powers stay put, +1 doubles
    assert round_capacity(8, "pow2") == 8
    assert round_capacity(9, "pow2") == 16
    assert round_capacity(16, "pow2") == 16
    assert round_capacity(17, "pow2") == 32
    # exact8: next multiple of 8
    assert round_capacity(9, "exact8") == 16
    assert round_capacity(16, "exact8") == 16
    assert round_capacity(17, "exact8") == 24


def test_choose_kernel_tie_at_cutoff_selects_flat_lp():
    """avg_row_flops == 256 exactly -> flat_lp (the rule is `< cutoff` ->
    dense_acc; the boundary belongs to the LP side). Documented contract."""
    a = random_csr(8, 16, 2.0, 1)
    b = random_csr(16, 16, 2.0, 2)
    stats = {"fm": AVG_ROW_FLOPS_CUTOFF * a.m}
    assert choose_kernel(a, b, stats) == "flat_lp"
    assert stats["avg_row_flops"] == float(AVG_ROW_FLOPS_CUTOFF)
    assert stats["kernel_source"] == "static"
    # one flop below the boundary flips to dense_acc
    below = {"fm": AVG_ROW_FLOPS_CUTOFF * a.m - 1}
    assert choose_kernel(a, b, below) == "dense_acc"


def test_choose_method_tie_at_dense_bytes_budget():
    """dense_bytes == DENSE_BYTES_BUDGET exactly is still 'dense' (the guard
    is `<= budget`); one more row tips over to 'sparse'."""
    base = random_csr(4, 8, 2.0, 3)  # f32; only shapes/dtypes matter below
    m, k = 4096, 32768
    assert m * k * (4 + 4) == DENSE_BYTES_BUDGET
    a = CSR(base.indptr, base.indices, base.values, shape=(m, 64))
    b = CSR(base.indptr, base.indices, base.values, shape=(64, k))
    stats = {}
    assert choose_method(a, b, stats) == "dense"
    assert stats["dense_bytes"] == DENSE_BYTES_BUDGET
    assert stats["method_source"] == "static"
    a2 = CSR(base.indptr, base.indices, base.values, shape=(m + 1, 64))
    assert choose_method(a2, b, {}) == "sparse"


# ------------------------------------------------------------------- fitting


def _sweep_rows(backend="cpu", platform="cpu"):
    """Synthetic accumulator sweep: dense wins below ~32 arf, LP above."""
    rows = []
    for regime, arf, t_dense, t_lp in [
        ("lo", 8.0, 10.0, 30.0),
        ("mid", 64.0, 25.0, 12.0),
        ("hi", 512.0, 80.0, 9.0),
    ]:
        for arm, us in (("dense_acc", t_dense), ("segsum", 999.0),
                        ("lp_hash", t_lp)):
            rows.append({
                "name": f"accumulators/{regime}/{arm}", "us_per_call": us,
                "backend": backend, "platform": platform,
                "derived": {"avg_row_flops": arf},
            })
    return rows


def test_fit_thresholds_finds_crossover_and_round_trips(tmp_path):
    table = fit_thresholds({"rows": _sweep_rows(), "backend": "cpu",
                            "platform": "cpu", "jax_version": "test"})
    fit = table.fits["cpu|cpu"]
    # crossover between 8 and 64 -> geometric midpoint sqrt(8*64)
    assert fit.avg_row_flops_cutoff == pytest.approx(math.sqrt(8 * 64))
    assert fit.n_points == 3
    assert fit.points == ((8.0, "dense_acc"), (64.0, "flat_lp"),
                          (512.0, "flat_lp"))
    # fitted-by-construction: total picked time <= static rule's total
    static_total = 10.0 + 25.0 + 9.0  # static 256: dense, dense, lp
    fitted_total = 10.0 + 12.0 + 9.0
    assert fitted_total <= static_total

    path = tmp_path / "tuned.json"
    table.save(str(path))
    loaded = TunedThresholds.load(str(path))
    assert loaded.fits == table.fits
    assert TunedThresholds.from_json(table.to_json()).fits == table.fits


def test_fit_thresholds_inf_cutoff_serializes():
    """A backend where dense always wins fits cutoff=inf; 'inf' must
    survive the JSON round-trip (bare Infinity is non-standard JSON)."""
    rows = [r for r in _sweep_rows() if "lo" in r["name"]]
    for r in rows:  # make LP lose even at high arf
        if r["name"].endswith("lp_hash"):
            r["us_per_call"] = 500.0
    table = fit_thresholds({"rows": rows})
    assert math.isinf(table.fits["cpu|cpu"].avg_row_flops_cutoff)
    rt = TunedThresholds.from_json(table.to_json())
    assert math.isinf(rt.fits["cpu|cpu"].avg_row_flops_cutoff)


def test_fitted_cutoff_consulted_by_choose_kernel():
    """An active fitted row for this backend overrides the static 256."""
    key = autotune.backend_key()
    set_tuned_thresholds(TunedThresholds(
        {key: BackendFit(avg_row_flops_cutoff=1.0)}))
    a = random_csr(24, 30, 3.0, 7)
    b = random_csr(30, 20, 2.0, 8)
    stats = {"fm": 4 * a.m}  # modest rows: static rule says dense_acc
    assert choose_kernel(a, b, stats) == "flat_lp"  # fitted cutoff 1.0
    assert stats["kernel_source"] == "fitted"
    # flows through spgemm stats too
    res = spgemm(a, b, method="sparse", plan_cache=PlanCache())
    assert res.stats["kernel"] == "flat_lp"
    assert res.stats["kernel_source"] == "fitted"
    set_tuned_thresholds(None)
    assert choose_kernel(a, b, dict(stats)) == "dense_acc"


def test_tuner_fallback_without_backend_row():
    """A fitted table covering only some other backend leaves this backend
    on the static constants (the documented fallback)."""
    set_tuned_thresholds(TunedThresholds(
        {"tpu|TPU v4": BackendFit(avg_row_flops_cutoff=1.0)}))
    cutoff, source = autotune.avg_row_flops_cutoff()
    assert (cutoff, source) == (float(AVG_ROW_FLOPS_CUTOFF), "static")
    a = random_csr(24, 30, 3.0, 7)
    b = random_csr(30, 20, 2.0, 8)
    stats = {"fm": 4 * a.m}
    assert choose_kernel(a, b, stats) == "dense_acc"
    assert stats["kernel_source"] == "static"


def test_backend_prefix_fallback_match():
    """Older artifacts keyed by backend name only: a unique backend-prefix
    row matches; ambiguity (two rows, same prefix) does not."""
    key = autotune.backend_key()
    base = key.split("|", 1)[0]
    tab = TunedThresholds({f"{base}|some-other-kind":
                           BackendFit(avg_row_flops_cutoff=7.0)})
    assert tab.for_backend(key).avg_row_flops_cutoff == 7.0
    tab.fits[f"{base}|third-kind"] = BackendFit(avg_row_flops_cutoff=9.0)
    if key not in tab.fits:  # ambiguous prefix -> no match
        assert tab.for_backend(key) is None


# -------------------------------------------------------------- measure mode


def test_spgemm_measure_first_sight_and_replay():
    """First sight pays exactly one micro-bench; the pinned-plan replay
    re-dispatches the cached winner with zero re-tuning and zero retraces."""
    cache = PlanCache()
    a = random_csr(32, 40, 3.0, 11)
    b = random_csr(40, 36, 2.5, 12)
    res = spgemm(a, b, method="sparse", plan_cache=cache, tune="measure")
    assert TUNE_COUNTS["micro_bench"] == 1
    assert res.stats["kernel_source"] == "measured"
    winner = res.stats["replay_backend"]
    assert winner in ("xla", "pallas", "pallas_lp")
    np.testing.assert_allclose(np.asarray(res.c.to_dense()),
                               dense_spgemm_oracle(a, b),
                               rtol=1e-4, atol=1e-4)

    # replay: same structure, new values -> cached winner, no re-tuning
    rng = np.random.default_rng(0)
    a2 = CSR(a.indptr, a.indices,
             jnp.asarray(rng.standard_normal(a.nnz_cap), jnp.float32),
             a.shape)
    traces0 = sum(TRACE_COUNTS.values())
    res2 = spgemm(a2, b, method="sparse", plan_cache=cache, tune="measure")
    assert res2.stats["cache"] == "hit"
    assert res2.stats["replay_backend"] == winner
    assert TUNE_COUNTS["micro_bench"] == 1  # no second sweep
    assert TUNE_COUNTS["plan_meta_hit"] >= 1
    assert sum(TRACE_COUNTS.values()) == traces0  # zero retraces
    np.testing.assert_allclose(np.asarray(res2.c.to_dense()),
                               dense_spgemm_oracle(a2, b),
                               rtol=1e-4, atol=1e-4)


def test_spgemm_measure_without_cache_uses_bucket_table():
    """plan_cache=False still avoids re-tuning: the bucket table catches the
    second sighting of the same structure-stats bucket."""
    a = random_csr(32, 40, 3.0, 11)
    b = random_csr(40, 36, 2.5, 12)
    spgemm(a, b, method="sparse", plan_cache=False, tune="measure")
    assert TUNE_COUNTS["micro_bench"] == 1
    spgemm(a, b, method="sparse", plan_cache=False, tune="measure")
    assert TUNE_COUNTS["micro_bench"] == 1
    assert TUNE_COUNTS["bucket_hit"] == 1


def test_executor_measure_mode():
    """ReuseExecutor(tune='measure'): one sweep on first apply, pinned
    winner after; a second same-bucket executor reuses the bucket entry."""
    a = random_csr(48, 48, 3.0, 21)
    b = random_csr(48, 48, 3.0, 22)
    ex = ReuseExecutor.from_matrices(a, b, plan_cache=PlanCache(),
                                     tune="measure")
    assert ex.kernel_source == "static"  # nothing measured yet
    out1 = ex.apply(a.values, b.values)
    assert TUNE_COUNTS["micro_bench"] == 1
    assert ex.kernel_source == "measured"
    winner = ex.backend
    # oracle correctness for whatever won
    ref = ReuseExecutor(ex.plan, backend="xla").apply(a.values, b.values)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)

    traces0 = sum(TRACE_COUNTS.values())
    hashes0 = sum(HASH_COUNTS.values())
    for _ in range(3):
        ex.apply(a.values, b.values)
    assert TUNE_COUNTS["micro_bench"] == 1  # zero re-tuning across replays
    assert sum(TRACE_COUNTS.values()) == traces0  # zero retraces
    assert sum(HASH_COUNTS.values()) == hashes0  # zero re-hashes

    ex2 = ReuseExecutor(ex.plan, tune="measure")
    ex2.apply(a.values, b.values)
    assert TUNE_COUNTS["micro_bench"] == 1  # bucket hit, no new sweep
    assert TUNE_COUNTS["bucket_hit"] >= 1
    assert ex2.backend == winner


def test_executor_measure_rejects_explicit_backend():
    a = random_csr(16, 16, 2.0, 1)
    b = random_csr(16, 16, 2.0, 2)
    res = spgemm(a, b, method="sparse", plan_cache=PlanCache())
    with pytest.raises(ValueError, match="requires backend='auto'"):
        ReuseExecutor(res.plan, backend="pallas", tune="measure")
    with pytest.raises(ValueError, match="unknown tune mode"):
        ReuseExecutor(res.plan, tune="always")


def test_numeric_values_measure_and_resolver_precedence():
    """numeric_values(tune='measure') sweeps the ELL-table kernels once;
    resolve_numeric_kernel then dispatches the measured winner (measured
    beats the threshold rule)."""
    a = random_csr(24, 30, 3.0, 7)
    b = random_csr(30, 20, 2.0, 8)
    c_idx, c_nnz = (jnp.asarray(x) for x in gustavson_ell_structure(a, b))
    got = numeric_values(a, b, c_idx, c_nnz, tune="measure")
    assert TUNE_COUNTS["micro_bench"] == 1
    winner = [k for k, v in KERNEL_COUNTS.items() if v][0]
    assert winner in ("dense_acc", "flat_lp", "xla")
    dense = np.zeros((a.m, b.k), np.float32)
    g, ci, cn = np.asarray(got), np.asarray(c_idx), np.asarray(c_nnz)
    for i in range(a.m):
        dense[i, ci[i, : cn[i]]] = g[i, : cn[i]]
    np.testing.assert_allclose(dense, dense_spgemm_oracle(a, b),
                               rtol=1e-4, atol=1e-4)
    # the resolver consults the measured bucket before the threshold rule
    assert resolve_numeric_kernel(a, b) == winner
    assert TUNE_COUNTS["bucket_hit"] >= 1
    # second call re-dispatches without a second sweep
    numeric_values(a, b, c_idx, c_nnz, tune="measure")
    assert TUNE_COUNTS["micro_bench"] == 1
    with pytest.raises(ValueError, match="requires kernel='auto'"):
        numeric_values(a, b, c_idx, c_nnz, kernel="xla", tune="measure")


def test_spgemm_grouped_measure_reuses_plan_meta():
    """Grouped singleton dispatch measures once; the next grouped call finds
    the winner in the plan-cache entry (plan_meta_hit, no new sweep)."""
    cache = PlanCache()
    a = random_csr(32, 32, 3.0, 31)
    b = random_csr(32, 32, 3.0, 32)
    out1 = spgemm_grouped([(a, b)], plan_cache=cache, tune="measure")
    assert TUNE_COUNTS["micro_bench"] == 1
    out2 = spgemm_grouped([(a, b)], plan_cache=cache, tune="measure")
    assert TUNE_COUNTS["micro_bench"] == 1  # zero re-tuning
    assert TUNE_COUNTS["plan_meta_hit"] >= 1
    np.testing.assert_allclose(np.asarray(out2[0].to_dense()),
                               dense_spgemm_oracle(a, b),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(out1[0].to_dense()),
                               np.asarray(out2[0].to_dense()),
                               rtol=1e-5, atol=1e-5)
    with pytest.raises(ValueError, match="requires backend='auto'"):
        spgemm_grouped([(a, b)], plan_cache=cache, backend="xla",
                       tune="measure")


def test_measure_mode_validation_errors():
    a = random_csr(16, 16, 2.0, 1)
    b = random_csr(16, 16, 2.0, 2)
    with pytest.raises(ValueError, match="unknown tune mode"):
        spgemm(a, b, tune="nope")
    with pytest.raises(ValueError, match="does not compose with method='lp'"):
        spgemm(a, b, method="lp", tune="measure")
    from repro.compat import make_mesh

    mesh = make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="does not support mesh"):
        spgemm(a, b, mesh=mesh, tune="measure")


def test_measure_respects_dtype_guard():
    """int operands: measure mode must only sweep the XLA candidate — the
    f32-accumulating kernels are ineligible, so the winner is 'xla'."""
    a = random_csr(16, 16, 2.0, 1)
    b = random_csr(16, 16, 2.0, 2)
    ai = CSR(a.indptr, a.indices, jnp.ones(a.nnz_cap, jnp.int32), a.shape)
    bi = CSR(b.indptr, b.indices, jnp.ones(b.nnz_cap, jnp.int32), b.shape)
    res = spgemm(ai, bi, method="sparse", plan_cache=PlanCache(),
                 tune="measure")
    assert res.stats["replay_backend"] == "xla"


# ----------------------------------------------------- plan-cache meta + hygiene


def test_plan_cache_meta_lifecycle():
    cache = PlanCache(capacity=1)
    cache.put("k1", {"dummy": np.zeros(4)})  # plan contents irrelevant here
    assert cache.set_meta("k1", "winner", "xla")
    assert cache.get_meta("k1", "winner") == "xla"
    # non-resident key: set refuses, get returns default
    assert not cache.set_meta("k2", "winner", "pallas")
    assert cache.get_meta("k2", "winner", default="none") == "none"
    # eviction drops the sidecar meta with the entry
    cache.put("k2", {"dummy": np.zeros(4)})  # capacity 1 -> evicts k1
    assert "k1" not in cache
    assert cache.get_meta("k1", "winner") is None
    cache.set_meta("k2", "winner", "pallas")
    cache.clear()
    assert cache.get_meta("k2", "winner") is None


def test_telemetry_reset_all():
    a = random_csr(16, 16, 2.0, 1)
    b = random_csr(16, 16, 2.0, 2)
    spgemm(a, b, method="sparse", plan_cache=PlanCache(), tune="measure")
    snap = telemetry.snapshot()
    assert snap["hash"] and snap["tune"]  # something was counted
    telemetry.reset_all()
    assert all(not c for c in telemetry.snapshot().values())
    # reset_all clears counters but NOT the measured-winner buckets
    assert autotune.measured_table_size() >= 1
    autotune.reset_tuner()
    assert autotune.measured_table_size() == 0
