"""Sparse container + generator tests (formats roundtrips, invariants)."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.sparse import (
    CSR,
    banded_csr,
    csr_to_ell,
    dense_spgemm_oracle,
    ell_to_csr,
    galerkin_triple,
    gustavson_numpy,
    random_csr,
    rmat_csr,
    stencil2d_csr,
)


def test_csr_dense_roundtrip():
    x = np.random.randn(17, 23) * (np.random.rand(17, 23) < 0.3)
    a = CSR.from_dense(x.astype(np.float32))
    np.testing.assert_allclose(np.asarray(a.to_dense()), x, rtol=1e-6)


def test_csr_nnz_cap_padding():
    x = np.eye(4, dtype=np.float32)
    a = CSR.from_dense(x, nnz_cap=16)
    assert a.nnz_cap == 16
    assert int(a.nnz()) == 4
    np.testing.assert_allclose(np.asarray(a.to_dense()), x)


def test_ell_roundtrip():
    a = random_csr(40, 30, 3.0, seed=5)
    e = csr_to_ell(a)
    np.testing.assert_allclose(
        np.asarray(e.to_dense()), np.asarray(a.to_dense()), rtol=1e-6
    )
    back = ell_to_csr(e)
    np.testing.assert_allclose(
        np.asarray(back.to_dense()), np.asarray(a.to_dense()), rtol=1e-6
    )


@pytest.mark.parametrize("gen", [
    lambda: random_csr(30, 40, 2.5, 1),
    lambda: rmat_csr(5, 4, 2),
    lambda: banded_csr(32, 2, 3),
    lambda: stencil2d_csr(6, 6),
])
def test_generator_invariants(gen):
    a = gen()
    indptr = np.asarray(a.indptr)
    assert indptr[0] == 0
    assert np.all(np.diff(indptr) >= 0)
    assert indptr[-1] <= a.nnz_cap
    idx = np.asarray(a.indices)[: indptr[-1]]
    assert idx.min() >= 0 and idx.max() < a.k
    # column indices sorted + unique per row
    for i in range(a.m):
        row = idx[indptr[i]: indptr[i + 1]]
        assert np.all(np.diff(row) > 0)


def test_galerkin_shapes():
    r, a, p = galerkin_triple(8, 8, 4)
    assert r.shape == (16, 64) and a.shape == (64, 64) and p.shape == (64, 16)
    # R = P^T
    np.testing.assert_allclose(
        np.asarray(r.to_dense()), np.asarray(p.to_dense()).T
    )


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(2, 20), k=st.integers(2, 20),
    density=st.floats(0.05, 0.5), seed=st.integers(0, 10_000),
)
def test_from_dense_to_dense_property(m, k, density, seed):
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal((m, k)) * (rng.random((m, k)) < density)).astype(
        np.float32
    )
    a = CSR.from_dense(x)
    np.testing.assert_allclose(np.asarray(a.to_dense()), x, rtol=1e-6)


def test_gustavson_matches_dense():
    a = random_csr(25, 30, 3.0, 7)
    b = random_csr(30, 20, 2.0, 8)
    ip, ind, val, rf = gustavson_numpy(a, b)
    dense = np.zeros((25, 20), np.float32)
    for i in range(25):
        dense[i, ind[ip[i]: ip[i + 1]]] = val[ip[i]: ip[i + 1]]
    np.testing.assert_allclose(dense, dense_spgemm_oracle(a, b), rtol=1e-5,
                               atol=1e-5)
