"""One-stop registry for the repo's dispatch/trace telemetry counters.

Every subsystem keeps its own module-level ``Counter`` next to the code it
instruments (retrace counts in ``core.spgemm``, structure-hash counts in
``core.plan_cache``, executor dispatches in ``core.executor``, numeric-kernel
picks in ``kernels.ops``, autotuner activity in ``core.autotune``). This
module aggregates them so tests and benchmarks can snapshot or reset *all*
instrumentation in one call instead of each fixture hand-clearing whichever
counters it happens to know about.

``reset_all()`` clears counters only — it does not touch the autotuner's
fitted-threshold registry or measured-winner buckets (that's
``autotune.reset_tuner()``, which conftest composes with this).
"""
from __future__ import annotations

from collections import Counter

from repro.core.autotune import TUNE_COUNTS, reset_tune_counts
from repro.core.executor import DISPATCH_COUNTS, reset_dispatch_counts
from repro.core.plan_cache import (EVICT_COUNTS, HASH_COUNTS,
                                   reset_evict_counts, reset_hash_counts)
from repro.core.spgemm import TRACE_COUNTS, reset_trace_counts
from repro.kernels.ops import KERNEL_COUNTS, reset_kernel_counts

# Degradation-ladder / guard events (PR 7). Lives here (not in a dispatch
# module) because three subsystems bump it — kernels.ops ladder steps,
# executor fault fallbacks, the NaN guard — and they all import telemetry
# lazily inside functions (this module imports them at module level).
# Key conventions:
#   "fault:<kernel>-><next>"   ladder step after a kernel exception
#   "dtype:<site>->xla"        f32-accumulation guard rerouted to XLA
#   "nan_guard:rerun"          guard saw non-finite output, reran oracle
#   "nan_guard:recovered"      oracle rerun was finite (kernel-side fault)
#   "nan_guard:data"           oracle rerun still non-finite (operand NaN)
FALLBACK_COUNTS: Counter = Counter()


def reset_fallback_counts() -> None:
    FALLBACK_COUNTS.clear()


# Retry telemetry (PR 8). Bumped by ``runtime.retry.retry_call`` (lazy import
# there; this module must not import runtime). Keys, per callsite label:
#   "<label>:attempt"  every execution of the wrapped callable
#   "<label>:retry"    a failed attempt that will be retried (backoff taken)
#   "<label>:giveup"   the bound was hit: RetryExhaustedError raised
# The serving tier reports retry rates straight off these (retry/attempt).
RETRY_COUNTS: Counter = Counter()


def reset_retry_counts() -> None:
    RETRY_COUNTS.clear()


# Circuit-breaker telemetry (PR 8). Bumped by ``serve.breaker`` on every
# state transition, keyed "<breaker name>:<event>":
#   "<name>:open"           closed -> open (failure threshold hit in window)
#   "<name>:half_open"      open -> half-open (cooldown elapsed, probe next)
#   "<name>:close"          half-open -> closed (probe succeeded)
#   "<name>:reopen"         half-open -> open (probe failed)
#   "<name>:short_circuit"  a dispatch was routed to the safe kernel because
#                           the breaker was open (traffic the fast path never
#                           saw — the load-shedding half of the story)
BREAKER_COUNTS: Counter = Counter()


def reset_breaker_counts() -> None:
    BREAKER_COUNTS.clear()


# Machine-readable key grammars, one family per registered counter. ``{}``
# is a wildcard segment (kernel names, callsite labels, breaker names).
# This is the single source of truth the static analyzer
# (``python -m repro.analysis``, rule ``telemetry-key``) checks every
# counter-mutation site against — the prose comments above are commentary,
# this dict is the contract. Extend it in the same commit that introduces
# a new key shape, or the analysis CI job fails.
KEY_FAMILIES: dict[str, tuple[str, ...]] = {
    "trace": ("{}",),
    "hash": ("structure_key",),
    "dispatch": ("apply", "apply_batched", "dist_apply", "dist_apply_batched"),
    "kernel": ("{}",),
    "tune": ("micro_bench", "bucket_hit", "plan_meta_hit"),
    "fallback": ("fault:{}->{}", "dtype:{}->xla", "nan_guard:rerun",
                 "nan_guard:recovered", "nan_guard:data"),
    "evict": ("{}",),
    "retry": ("{}:attempt", "{}:retry", "{}:giveup"),
    "breaker": ("{}:open", "{}:half_open", "{}:close", "{}:reopen",
                "{}:short_circuit"),
}


def key_matches_family(family: str, key: str) -> bool:
    """Does ``key`` fit one of ``family``'s grammar templates?

    Runtime twin of the static check, for tests that want to assert a
    counter key conforms without re-listing the grammar inline.
    """
    import re
    for template in KEY_FAMILIES.get(family, ()):
        pattern = "^" + ".+".join(
            re.escape(part) for part in template.split("{}")) + "$"
        if re.match(pattern, key):
            return True
    return False


# name -> live Counter object (shared with the owning module, not copies)
ALL_COUNTERS: dict[str, Counter] = {
    "trace": TRACE_COUNTS,
    "hash": HASH_COUNTS,
    "dispatch": DISPATCH_COUNTS,
    "kernel": KERNEL_COUNTS,
    "tune": TUNE_COUNTS,
    "fallback": FALLBACK_COUNTS,
    "evict": EVICT_COUNTS,
    "retry": RETRY_COUNTS,
    "breaker": BREAKER_COUNTS,
}

_RESETS = (
    reset_trace_counts,
    reset_hash_counts,
    reset_dispatch_counts,
    reset_kernel_counts,
    reset_tune_counts,
    reset_fallback_counts,
    reset_evict_counts,
    reset_retry_counts,
    reset_breaker_counts,
)


def snapshot() -> dict[str, dict[str, int]]:
    """A plain-dict copy of every counter, for diffing across a region."""
    return {name: dict(c) for name, c in ALL_COUNTERS.items()}


def diff(before: dict[str, dict[str, int]],
         after: dict[str, dict[str, int]]) -> dict[str, dict[str, int]]:
    """Nonzero deltas between two ``snapshot()``s, same nested shape.

    Groups with no change are omitted entirely, so "this region bumped
    nothing" is the single assertion ``assert not telemetry.diff(a, b)`` —
    and "this region added exactly one structure hash" is
    ``diff(a, b) == {"hash": {"structure_key": 1}}``. Keys that vanished
    between snapshots (a reset mid-region) show up as negative deltas.
    """
    out: dict[str, dict[str, int]] = {}
    for group in before.keys() | after.keys():
        b = before.get(group, {})
        a = after.get(group, {})
        deltas = {key: a.get(key, 0) - b.get(key, 0)
                  for key in b.keys() | a.keys()
                  if a.get(key, 0) != b.get(key, 0)}
        if deltas:
            out[group] = deltas
    return out


def reset_all() -> None:
    """Clear every registered telemetry counter."""
    for reset in _RESETS:
        reset()
