from repro.runtime.watchdog import Heartbeat, StepWatchdog

__all__ = ["StepWatchdog", "Heartbeat"]
