"""qwen2-7b [dense] — arXiv:2407.10671, hf:Qwen/Qwen2-7B.

28L, d_model=3584, 28 heads (GQA kv=4), d_ff=18944, vocab=152064, QKV bias.
SpGEMM applicability: none. long_500k: skipped (pure full attention).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b",
    family="dense",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    d_ff=18_944,
    vocab_size=152_064,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="qwen2-7b-smoke",
    family="dense",
    num_layers=2,
    d_model=56,
    num_heads=4,
    num_kv_heads=2,
    d_ff=96,
    vocab_size=256,
    head_dim=16,
    qkv_bias=True,
)

SKIP_SHAPES = {"long_500k": "pure full-attention arch (per-spec skip)"}
