"""Version-portable aliases for jax's distribution APIs.

The distribution layer targets the modern spellings (``jax.shard_map``,
``jax.make_mesh(..., axis_types=...)``, ``jax.set_mesh``); older jax
releases (<= 0.4.x) ship the same functionality under
``jax.experimental.shard_map`` / positional ``make_mesh`` / the ``Mesh``
context manager. Routing every call site through this module keeps the rest
of the codebase on one spelling and makes the distributed paths run on
whichever jax the container bakes in.
"""
from __future__ import annotations

import contextlib

import jax


def shard_map(f=None, *, mesh, in_specs, out_specs):
    """``jax.shard_map`` where available, else the experimental spelling.

    Usable exactly like the modern API: ``shard_map(f, mesh=..., ...)`` or
    as a partial ``shard_map(mesh=..., ...)(f)``.
    """
    if f is None:
        return lambda g: shard_map(g, mesh=mesh, in_specs=in_specs,
                                   out_specs=out_specs)
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs)
    from jax.experimental.shard_map import shard_map as _shard_map

    # check_rep=False: the old checker rejects some valid collective
    # patterns (gather-then-reduce) that the modern one accepts.
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` with explicit-Auto axis types when supported."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(axis_shapes, axis_names,
                             axis_types=(axis_type.Auto,) * len(axis_names))
    return jax.make_mesh(axis_shapes, axis_names)


def use_mesh(mesh):
    """Context manager binding ``mesh`` for jitted sharded computations:
    ``jax.set_mesh`` on modern jax, the ``Mesh`` context manager before it.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return contextlib.nullcontext() if mesh is None else mesh
