"""Phase-level tracing: near-zero-overhead-when-off spans + Chrome export.

The repo's nine telemetry counters answer "how many times did X happen";
nothing answered "where did the time go inside one call" — expand vs sort vs
plan-build vs numeric dispatch is exactly the attribution the paper's
reuse-vs-rebuild argument needs (Kokkos Kernels' own SpGEMM work leans on a
per-phase timer hierarchy for the same reason). This module is that layer:

  * ``with span("plan.build"): ...`` — a nesting span API instrumenting the
    phases of ``core/spgemm.py``, ``core/executor.py``, ``dist/executor.py``,
    ``kernels/ops.py`` and ``serve/spgemm_service.py``.
  * **Off by default, and off means OFF**: a disabled ``span()`` returns a
    shared no-op context manager — no event, no timestamp, no histogram
    observation, no counter bump — so the pinned-replay hot path stays
    dispatch-identical to the untraced build (telemetry-asserted in
    tests/test_obs.py; priced in ``benchmarks.run --bench obs``).
  * Modes mirror ``$REPRO_VALIDATE``: ``spgemm(trace=...)`` takes
    ``None | bool | "off" | "on" | "xprof"``; ``None`` defers to the
    ``$REPRO_TRACE`` environment variable (else "off"). "xprof" additionally
    wraps every span in ``jax.profiler.TraceAnnotation`` so the phases land
    inside XLA device profiles.
  * **Trace-ID propagation**: ``trace_context(tid)`` sets the ambient request
    id; every span records it, so a ``SparseService`` request's id travels
    from admission through grouping, ``resolve_plan``, executor dispatch and
    the retry/breaker path into the exported trace.
  * ``export_chrome_trace(path)`` writes Chrome trace-event JSON ("X"
    complete events) loadable in chrome://tracing / Perfetto.

Completed spans also feed ``obs.metrics`` latency histograms keyed by span
name (plus a ``<name>[<kernel>]`` variant when the span carries a ``kernel``
attr), which is where the per-phase / per-kernel p50/p95/p99 distributions
come from. Spans time the *host side* of a dispatch — JAX async dispatch is
never blocked on; device time belongs to the "xprof" mode's annotations.

Single-threaded by design, like the serving tier: the span stack and the
ambient trace id are plain module state, deterministic under the chaos
suite's injected clocks.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any

TRACE_MODES = ("off", "on", "xprof")

# Environment override consulted when the mode is unset / trace=None: mirrors
# $REPRO_VALIDATE so obs CI can force tracing across a run without touching
# call sites.
TRACE_ENV_VAR = "REPRO_TRACE"

# Bound on buffered span events: a runaway traced loop must degrade to
# dropped events (counted), never to unbounded host memory.
MAX_EVENTS = 100_000

# The span taxonomy. Every ``span("...")`` literal in the stack must come
# from this set — dashboards, the flight recorder, the latency histograms
# and the ROADMAP phase table all key on these exact strings, so a
# free-typed name silently drops out of the phase-latency story. Enforced
# statically by ``python -m repro.analysis`` (rule ``span``); extend the
# set (and the ROADMAP table) in the same commit that adds a new phase.
SPAN_NAMES = frozenset({
    "spgemm.prepare",     # operand normalization + structure hash
    "spgemm.symbolic",    # symbolic phase: sizes + plan expansion
    "plan.build",         # plan assembly (sort, seg ids, slot maps)
    "numeric.dispatch",   # executor-level replay dispatch
    "numeric.kernel",     # one numeric kernel execution
    "dist.replay",        # sharded replay under shard_map
    "serve.admit",        # serving-tier admission decision
    "serve.dispatch",     # serving-tier batch dispatch
})


def resolve_trace_mode(mode: str | bool | None) -> str:
    """Normalize a ``trace=`` argument to a concrete mode.

    ``None`` defers to ``$REPRO_TRACE`` (else "off"); booleans map to
    "on"/"off"; anything outside ``TRACE_MODES`` is a loud
    ``SpgemmConfigError`` (a typo'd mode silently tracing nothing would
    defeat the layer).
    """
    from repro.runtime.validate import SpgemmConfigError  # cycle-free

    if mode is None:
        raw = os.environ.get(TRACE_ENV_VAR, "off") or "off"
        lowered = raw.strip().lower()
        aliases = {"": "off", "0": "off", "false": "off", "off": "off",
                   "1": "on", "true": "on", "on": "on", "xprof": "xprof"}
        if lowered not in aliases:
            raise SpgemmConfigError(
                f"unknown ${TRACE_ENV_VAR} value {raw!r}; expected one of "
                f"{TRACE_MODES} (or 0/1/true/false)")
        return aliases[lowered]
    if mode is True:
        return "on"
    if mode is False:
        return "off"
    if mode not in TRACE_MODES:
        raise SpgemmConfigError(
            f"unknown trace mode {mode!r}; expected one of {TRACE_MODES} "
            f"(or True/False/None)")
    return mode


class _TraceState:
    """Module-global tracer state (single-threaded, reset per test)."""

    __slots__ = ("mode", "events", "depth", "trace_id", "t0", "dropped",
                 "next_id")

    def __init__(self):
        self.mode: str | None = None  # None = resolve $REPRO_TRACE lazily
        self.events: list[dict] = []
        self.depth: int = 0
        self.trace_id: str | None = None
        self.t0: float = time.perf_counter()
        self.dropped: int = 0
        self.next_id: int = 0


_STATE = _TraceState()


def _mode() -> str:
    m = _STATE.mode
    if m is None:
        m = resolve_trace_mode(None)
        _STATE.mode = m
    return m


def enabled() -> bool:
    """True when spans record (mode "on"/"xprof"). The hot-path check."""
    return _mode() != "off"


def set_tracing(mode: str | bool | None) -> str:
    """Set the global trace mode; ``None`` re-defers to ``$REPRO_TRACE``.
    Returns the concrete mode now in effect."""
    _STATE.mode = None if mode is None else resolve_trace_mode(mode)
    return _mode()


def new_trace_id(prefix: str = "trace") -> str:
    """A fresh process-unique trace id (counter-based, deterministic)."""
    _STATE.next_id += 1
    return f"{prefix}-{_STATE.next_id}"


def current_trace_id() -> str | None:
    """The ambient request trace id set by ``trace_context`` (None outside)."""
    return _STATE.trace_id


class _Noop:
    """The disabled path: one shared instance, every method a no-op."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, key: str, value: Any) -> None:
        pass


_NOOP = _Noop()


class _Span:
    """One live span: records a Chrome "X" event + a histogram observation on
    exit. Only ever constructed when tracing is enabled."""

    __slots__ = ("name", "attrs", "_start", "_annotation")

    def __init__(self, name: str, attrs: dict):
        self.name = name
        self.attrs = attrs
        self._start = 0.0
        self._annotation = None

    def set(self, key: str, value: Any) -> None:
        """Attach an attribute discovered mid-span (e.g. a resolved method)."""
        self.attrs[key] = value

    def __enter__(self):
        if _mode() == "xprof":
            try:
                from jax.profiler import TraceAnnotation

                self._annotation = TraceAnnotation(self.name)
                self._annotation.__enter__()
            # observability must never fail the observed call: a missing or
            # broken profiler hook degrades to "no annotation", by design
            # repro: allow[taxonomy] intentional silent degradation
            except Exception:
                self._annotation = None  # profiling must never fail the call
        _STATE.depth += 1
        self._start = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb):
        end = time.perf_counter()
        _STATE.depth -= 1
        if self._annotation is not None:
            self._annotation.__exit__(exc_type, exc, tb)
        dur_s = end - self._start
        args = dict(self.attrs)
        tid = _STATE.trace_id
        if tid is not None and "trace_id" not in args:
            args["trace_id"] = tid
        if exc_type is not None:
            args["error"] = exc_type.__name__
        if len(_STATE.events) < MAX_EVENTS:
            _STATE.events.append({
                "name": self.name,
                "ts": (self._start - _STATE.t0) * 1e6,  # Chrome wants us
                "dur": dur_s * 1e6,
                "depth": _STATE.depth,
                "args": args,
            })
        else:
            _STATE.dropped += 1
        from repro.obs import metrics  # lazy: metrics pulls telemetry

        metrics.observe(self.name, dur_s)
        kernel = self.attrs.get("kernel")
        if kernel is not None:
            metrics.observe(f"{self.name}[{kernel}]", dur_s)
        return False


def span(name: str, **attrs):
    """Open a phase span: ``with span("plan.build", fm_cap=cap): ...``.

    Disabled tracing returns a shared no-op context manager — the call costs
    one mode check and nothing else (no event, no clock read, no histogram).
    Attrs land in the exported event's ``args``; a ``kernel=`` attr
    additionally routes the duration into that kernel's histogram.
    """
    if not enabled():
        return _NOOP
    return _Span(name, attrs)


class _TraceContext:
    __slots__ = ("tid", "prev")

    def __init__(self, tid: str | None):
        self.tid = tid
        self.prev = None

    def __enter__(self):
        self.prev = _STATE.trace_id
        _STATE.trace_id = self.tid
        return self

    def __exit__(self, *exc):
        _STATE.trace_id = self.prev
        return False


def trace_context(trace_id: str | None):
    """Set the ambient request trace id for the enclosed spans.

    The propagation mechanism: ``SparseService`` enters this around each
    group dispatch, so the nested ``plan.build`` / ``numeric.dispatch`` /
    retry spans all carry the request's id end-to-end. No-op when tracing is
    off (the id would have nowhere to land).
    """
    if not enabled():
        return _NOOP
    return _TraceContext(trace_id)


class _TraceScope:
    __slots__ = ("mode", "prev")

    def __init__(self, mode: str):
        self.mode = mode
        self.prev = None

    def __enter__(self):
        self.prev = _STATE.mode
        _STATE.mode = self.mode
        return self

    def __exit__(self, *exc):
        _STATE.mode = self.prev
        return False


def trace_scope(mode: str | bool | None):
    """Temporarily override the trace mode for one call.

    The mechanism behind ``spgemm(trace=...)``: ``None`` is a no-op (the
    ambient mode — ultimately ``$REPRO_TRACE`` — stays in charge), anything
    else pins the mode for the scope's duration and restores on exit.
    """
    if mode is None:
        return _NOOP
    return _TraceScope(resolve_trace_mode(mode))


def events() -> list[dict]:
    """The buffered span events (raw internal form; see export_chrome_trace)."""
    return list(_STATE.events)


def clear() -> None:
    """Drop buffered events and reset the clock origin (mode unchanged)."""
    _STATE.events.clear()
    _STATE.dropped = 0
    _STATE.t0 = time.perf_counter()


def export_chrome_trace(path: str | None = None) -> dict:
    """Render buffered spans as Chrome trace-event JSON.

    Returns the payload (``{"traceEvents": [...complete "X" events...]}``);
    when ``path`` is given, also writes it there. Load the file in
    chrome://tracing or https://ui.perfetto.dev. Span attrs (including the
    propagated ``trace_id``) are in each event's ``args``.
    """
    trace_events = [
        {
            "name": ev["name"],
            "cat": "repro",
            "ph": "X",
            "ts": round(ev["ts"], 3),
            "dur": round(ev["dur"], 3),
            "pid": 1,
            "tid": 1,
            "args": ev["args"],
        }
        for ev in _STATE.events
    ]
    payload = {
        "traceEvents": trace_events,
        "displayTimeUnit": "ms",
        "otherData": {"dropped_events": _STATE.dropped},
    }
    if path is not None:
        with open(path, "w") as f:
            json.dump(payload, f)
    return payload


def reset_tracing() -> None:
    """Full reset (tests): mode back to lazy-$REPRO_TRACE, buffers cleared."""
    _STATE.mode = None
    _STATE.trace_id = None
    _STATE.depth = 0
    _STATE.next_id = 0
    clear()
