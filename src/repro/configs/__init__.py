"""Architecture registry: ``--arch <id>`` resolves here."""
from __future__ import annotations

import importlib

from repro.configs.base import (
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    SHAPES,
    TRAIN_4K,
    ModelConfig,
    ShapeConfig,
)

_MODULES = {
    "llama3.2-1b": "repro.configs.llama3_2_1b",
    "qwen2-7b": "repro.configs.qwen2_7b",
    "codeqwen1.5-7b": "repro.configs.codeqwen1_5_7b",
    "gemma2-9b": "repro.configs.gemma2_9b",
    "mamba2-2.7b": "repro.configs.mamba2_2_7b",
    "recurrentgemma-9b": "repro.configs.recurrentgemma_9b",
    "phi-3-vision-4.2b": "repro.configs.phi3_vision_4_2b",
    "hubert-xlarge": "repro.configs.hubert_xlarge",
    "qwen3-moe-235b-a22b": "repro.configs.qwen3_moe_235b_a22b",
    "qwen3-moe-30b-a3b": "repro.configs.qwen3_moe_30b_a3b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(_MODULES[arch])
    return mod.SMOKE if smoke else mod.CONFIG


def skip_reason(arch: str, shape: str) -> str | None:
    """Non-None if this (arch, shape) cell is skipped (with the reason)."""
    mod = importlib.import_module(_MODULES[arch])
    return mod.SKIP_SHAPES.get(shape)


def all_cells():
    """Yield every runnable (arch, shape) dry-run cell."""
    for arch in ARCH_IDS:
        for shape in SHAPES:
            if skip_reason(arch, shape) is None:
                yield arch, shape


__all__ = [
    "ARCH_IDS",
    "get_config",
    "skip_reason",
    "all_cells",
    "ModelConfig",
    "ShapeConfig",
    "SHAPES",
    "TRAIN_4K",
    "PREFILL_32K",
    "DECODE_32K",
    "LONG_500K",
]
